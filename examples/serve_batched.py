"""End-to-end driver: batched serving with AIMC-accelerated weights.

    PYTHONPATH=src python examples/serve_batched.py

ALPINE is an inference paper, so the end-to-end example is a serving run:
a batch of requests is prefilled and decoded against a KV cache, once with
digital weights and once through the simulated AIMC crossbars. The AIMC run
uses the program-once/apply-many path (`core.program`): the network is
programmed ONCE — CM_INITIALIZE is outside the serving loop — then every
token pays only queue/process/dequeue, and the CM_* totals are printed from
the program's static accounting. (`--reprogram` would restore the legacy
per-token re-programming path for A/B timing.) Output agreement and the
analytical latency/energy estimate for the paper's hardware are reported.

This drives the same `repro.launch.serve` module a production launch uses;
scale up by dropping --smoke and pointing --mesh at a pod.
"""

from repro.launch import serve

print("=" * 64)
print("digital serving (CPU/SIMD baseline)")
print("=" * 64)
rep_dig = serve.main(["--arch", "granite-8b", "--smoke", "--requests", "8",
                      "--prompt-len", "16", "--gen", "8", "--seed", "7"])

print()
print("=" * 64)
print("AIMC serving (weights stationary in crossbars)")
print("=" * 64)
rep_ana = serve.main(["--arch", "granite-8b", "--smoke", "--requests", "8",
                      "--prompt-len", "16", "--gen", "8", "--seed", "7",
                      "--exec", "aimc"])

# serve.main returns the engine's ServeReport: compare per-request tokens
pairs = [(rep_dig.tokens(rid), rep_ana.tokens(rid))
         for rid in sorted(rep_dig.records)]
n_tok = sum(len(d) for d, _ in pairs)
n_same = sum(sum(1 for x, y in zip(d, a) if x == y) for d, a in pairs)
agree = n_same / max(n_tok, 1)
print(f"\ntoken agreement digital vs AIMC: {agree:.0%} "
      f"(untrained weights -> near-uniform logits; trained models match "
      f"to >99% in the iso-accuracy studies the paper cites)")

# analytical serving cost on the paper's hardware (per generated token)
from repro.core.costmodel import HIGH_POWER, Op, Stage, Workload, evaluate

# a granite-8b-like layer stack: 7 [4096x4096]-equivalent MVMs per token
tok_dig = evaluate(
    Workload("tok_dig", phases=((Stage(
        ops=(Op("mvm", k=4096, n=4096, count=7),),
        weights_bytes=7 * 4096 * 4096),),)),
    HIGH_POWER)
tok_ana = evaluate(
    Workload("tok_ana", phases=((Stage(
        ops=(Op("mvm", k=4096, n=4096, count=7, aimc=True),),),),)),
    HIGH_POWER)
print(f"analytical per-token cost, granite-8b-like layer stack on the "
      f"paper's high-power system:\n"
      f"  digital: {tok_dig.time_s * 1e3:.2f} ms, {tok_dig.energy_j:.3f} J\n"
      f"  AIMC:    {tok_ana.time_s * 1e3:.2f} ms, {tok_ana.energy_j:.3f} J "
      f"({tok_dig.time_s / tok_ana.time_s:.1f}x / "
      f"{tok_dig.energy_j / tok_ana.energy_j:.1f}x)")
