"""Noise-aware training (paper §III-C, citing Joshi et al. [16]).

    PYTHONPATH=src python examples/noise_aware_training.py

PCM crossbars perturb the programmed weights; the countermeasure the paper
points to is training WITH noise injection so the learned weights are robust
at deployment. This example trains the paper's 2-layer MLP on a synthetic
classification task three ways and evaluates all three on a NOISY crossbar:

  A. digital training, digital eval             (reference ceiling)
  B. digital training, noisy AIMC eval          (naive deployment)
  C. noise-aware training (AIMC STE), noisy eval (the paper's fix)

C recovers most of the gap between B and A.
"""

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcConfig, aimc_linear_ste, program_linear, \
    aimc_apply
from repro.core.noise import NoiseModel

KEY = jax.random.PRNGKey(0)
N_IN, N_H, N_CLS = 256, 256, 10
_NOISE = NoiseModel(sigma_prog_min=0.08, sigma_prog_max=0.20,
                    sigma_read=0.03, drift_t_ratio=1e3)
# training injects the programming-type noise at the deployment level but a
# gentler read noise — the recipe in Joshi et al. [16]
TRAIN_CFG = AimcConfig(tile_rows=256, impl="ref",
                       noise=NoiseModel(sigma_prog_min=0.08,
                                        sigma_prog_max=0.20,
                                        sigma_read=0.01))
EVAL_CFG = AimcConfig(tile_rows=256, impl="ref", noise=_NOISE)


W_TRUE = jax.random.normal(jax.random.fold_in(KEY, 99), (N_IN, N_CLS))


def make_data(key, n=4096):
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, (n, N_IN))
    y = jnp.argmax(x @ W_TRUE + 0.1 * jax.random.normal(kn, (n, N_CLS)), -1)
    return x, y


def init_params(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (N_IN, N_H)) * (2 / N_IN) ** 0.5,
            "w2": jax.random.normal(k2, (N_H, N_CLS)) * (2 / N_H) ** 0.5}


def forward_digital(p, x):
    return jax.nn.relu(x @ p["w1"]) @ p["w2"]


def forward_aimc_ste(p, x, key):
    k1, k2 = jax.random.split(key)
    h = jax.nn.relu(aimc_linear_ste(x, p["w1"], k1, TRAIN_CFG))
    return aimc_linear_ste(h, p["w2"], k2, TRAIN_CFG)


def forward_aimc_eval(p, x, key):
    """Deployment: program once with noise+drift, then run."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s1 = program_linear(p["w1"], EVAL_CFG, k1)
    s2 = program_linear(p["w2"], EVAL_CFG, k2)
    h = jax.nn.relu(aimc_apply(s1, x, EVAL_CFG, k3))
    return aimc_apply(s2, h, EVAL_CFG, k4)


def xent(logits, y):
    return jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])


def train(fwd, steps=300, lr=0.05, noisy=False):
    params = init_params(jax.random.fold_in(KEY, 1))
    x, y = make_data(jax.random.fold_in(KEY, 2))

    @jax.jit
    def step(p, i):
        k = jax.random.fold_in(KEY, i)
        def loss(pp):
            logits = fwd(pp, x, k) if noisy else fwd(pp, x)
            return xent(logits, y)
        g = jax.grad(loss)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for i in range(steps):
        params = step(params, i)
    return params


def accuracy(logits, y):
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def noisy_accuracy(p, x, y, seeds=8):
    """Mean accuracy over several programming-noise draws (each draw is a
    fresh chip programming; single draws have multi-point variance)."""
    accs = [accuracy(forward_aimc_eval(p, x, jax.random.fold_in(KEY, 100 + i)),
                     y) for i in range(seeds)]
    return sum(accs) / len(accs)


x_te, y_te = make_data(jax.random.fold_in(KEY, 3), n=2048)

p_dig = train(forward_digital)
acc_a = accuracy(forward_digital(p_dig, x_te), y_te)
acc_b = noisy_accuracy(p_dig, x_te, y_te)

p_naw = train(forward_aimc_ste, steps=600, noisy=True)
acc_c = noisy_accuracy(p_naw, x_te, y_te)

print(f"A. digital train  -> digital eval:        {acc_a:.1%}")
print(f"B. digital train  -> noisy crossbar eval: {acc_b:.1%}")
print(f"C. noise-aware    -> noisy crossbar eval: {acc_c:.1%}")
gap = acc_a - acc_b
rec = acc_c - acc_b
print(f"noise-aware training recovers {rec / gap:.0%} of the deployment gap"
      if gap > 1e-4 else "no deployment gap at this noise level")
assert acc_c >= acc_b - 0.01, "noise-aware training should not hurt"
