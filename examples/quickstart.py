"""Quickstart: the ALPINE programming model in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's Fig. 4 C++ sample in JAX: map a weight matrix onto
crossbars (CM_INITIALIZE), queue an input vector (CM_QUEUE), run the analog
MVM (CM_PROCESS), dequeue the result (CM_DEQUEUE) — then the fused `linear`
path every real model uses, PCM noise, and the tile-packing view.
"""

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcConfig
from repro.core.aimclib import AimcContext
from repro.core.noise import NoiseModel

M, N = 1024, 1024
key = jax.random.PRNGKey(0)

# -- a fully-connected layer and one inference input -------------------------
w = jax.random.normal(key, (M, N)) * 0.02
x = jax.random.normal(jax.random.fold_in(key, 1), (4, M))

# -- 1. program the crossbars (CM_INITIALIZE) --------------------------------
cfg = AimcConfig(tile_rows=512, tile_cols=512,
                 noise=NoiseModel(sigma_read=0.003))
ctx = AimcContext(cfg, key)
ctx.map_matrix("fc1", w)
print(f"programmed 'fc1' [{M}x{N}] onto {ctx.tile_map().n_tiles} tiles "
      f"(512x512), utilization {ctx.tile_map().utilization:.0%}")

# -- 2. the instruction-level flow (paper Fig. 4) -----------------------------
ctx.queue_vector("fc1", x)          # CM_QUEUE: DAC-quantize into input memory
ctx.process("fc1")                  # CM_PROCESS: analog MVM, 100 ns
y = ctx.dequeue_vector("fc1")       # CM_DEQUEUE: ADC codes -> digital
print(f"y = AIMC(x @ W): {y.shape}, CM_* issued so far: "
      f"{ctx.instruction_counts()}")

# -- 3. the fused path + fidelity ---------------------------------------------
y_fused = ctx.linear("fc1", x)
y_exact = x @ w
rel = float(jnp.linalg.norm(y_fused - y_exact) / jnp.linalg.norm(y_exact))
print(f"relative error vs fp32 matmul: {rel:.3%}  "
      f"(8-bit DAC/ADC + PCM noise)")

# -- 4. the LSTM gate trick (paper §VIII-D) -----------------------------------
gates = [jax.random.normal(jax.random.fold_in(key, i), (306, 256)) * 0.05
         for i in range(4)]
ctx2 = AimcContext(AimcConfig(tile_rows=612, tile_cols=1074))
ctx2.map_gates("cell", gates)
h_x = jax.random.normal(jax.random.fold_in(key, 9), (1, 306))
all_gates = ctx2.linear("cell", h_x)       # ONE process -> all four gates
print(f"four LSTM gates in one CM_PROCESS: {all_gates.shape} "
      f"on {ctx2.tile_map().n_tiles} tile(s)")

# -- 5. every model in the zoo runs this as an execution mode ----------------
from repro.configs import get_arch
from repro.models.layers import Execution

spec = get_arch("llama3.2-3b")
model = spec.model_module()
params = model.init(key, spec.smoke_cfg)
toks = jnp.ones((2, 16), jnp.int32)
exe = Execution(mode="aimc", aimc=AimcConfig(impl="ref"),
                compute_dtype="float32")
logits, _ = model.forward(params, toks, spec.smoke_cfg, exe,
                          jax.random.PRNGKey(2))
print(f"llama3.2-3b (smoke cfg) forward through simulated crossbars: "
      f"logits {logits.shape}, finite={bool(jnp.all(jnp.isfinite(logits)))}")

# -- 6. program once, apply many (the deployment model) -----------------------
# The forward above re-programs every weight on every call (the noise-aware
# TRAINING path). Serving programs the whole network ONCE — program_model
# walks the param tree, maps every stationary projection per the MappingPlan,
# and install() substitutes the programmed states so the same model code runs
# apply-only (CM_INITIALIZE leaves the hot path entirely).
from repro.core.program import MappingPlan, program_model

serve_cfg = AimcConfig(impl="ref")
program = program_model(params, MappingPlan(), serve_cfg,
                        jax.random.PRNGKey(3))
print(program.summary())
served = program.install(params)
exe_srv = Execution(mode="aimc", aimc=serve_cfg, compute_dtype="float32",
                    programmed=True)
logits2, _ = model.forward(served, toks, spec.smoke_cfg, exe_srv)
print(f"programmed forward (no re-programming): logits {logits2.shape}; "
      f"CM_INITIALIZE stays {program.initialize_counts().initialize} "
      f"no matter how many tokens follow")
