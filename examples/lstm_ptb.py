"""The paper's LSTM exploration, end to end (paper §VIII).

    PYTHONPATH=src python examples/lstm_ptb.py

Trains the paper's character-level LSTM (one cell layer + dense softmax
head) on a synthetic Penn-Treebank-like character stream, then runs
inference in digital and AIMC modes — gates tiled side by side so ONE
CM_PROCESS computes all four gate MVMs (§VIII-D) — and reports the
analytical run-time/energy on the paper's two system configurations for
every n_h in the paper's Table II.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aimc import AimcConfig
from repro.core.costmodel import HIGH_POWER, LOW_POWER, evaluate, speedup
from repro.core.workloads import lstm_workloads
from repro.models import paper_nets

KEY = jax.random.PRNGKey(0)
VOCAB = 50                                  # printable chars, as in PTB char
NH = 256                                    # train the smallest variant here


def synthetic_ptb(key, n_seq=64, seq_len=40):
    """Markov-ish character stream: enough structure to learn."""
    trans = jax.nn.softmax(4.0 * jax.random.normal(key, (VOCAB, VOCAB)), -1)
    seqs = [jnp.zeros((n_seq,), jnp.int32)]
    k = key
    for _ in range(seq_len):
        k = jax.random.fold_in(k, 0)
        probs = trans[seqs[-1]]
        seqs.append(jax.random.categorical(k, jnp.log(probs + 1e-9), axis=-1))
    return jnp.stack(seqs, 1)               # [n_seq, seq_len+1]


def one_hot_seq(toks):
    oh = jax.nn.one_hot(toks, VOCAB)
    return jnp.moveaxis(oh, 1, 0)           # [T, B, vocab]


print(f"training the paper's LSTM (n_h={NH}) on synthetic PTB chars...")
data = synthetic_ptb(KEY)
xs = one_hot_seq(data[:, :-1])              # [T, B, 50]
ys = jnp.moveaxis(data[:, 1:], 1, 0)        # [T, B]
params = paper_nets.lstm_init(jax.random.fold_in(KEY, 1), NH, VOCAB, VOCAB)


@jax.jit
def step(p, lr=0.5):
    def loss(pp):
        out = paper_nets.lstm_forward_digital(pp, xs, NH)  # [T,B,V] softmax
        gold = jnp.take_along_axis(out, ys[..., None], -1)[..., 0]
        return -jnp.mean(jnp.log(gold + 1e-9))
    l, g = jax.value_and_grad(loss)(p)
    return jax.tree.map(lambda a, b: a - lr * b, p, g), l


for i in range(60):
    params, l = step(params)
    if i % 20 == 0:
        print(f"  step {i:3d}  char NLL {float(l):.3f}")
print(f"  final    char NLL {float(l):.3f}")

# ---- inference: digital vs AIMC (gates side by side, §VIII-D) ---------------
cfg = AimcConfig(tile_rows=NH + VOCAB + 50, tile_cols=4 * NH + 64, impl="ref")
y_dig = paper_nets.lstm_forward_digital(params, xs[:, :4], NH)
y_ana, ctx = paper_nets.lstm_forward_aimc(params, xs[:, :4], NH, cfg,
                                          jax.random.fold_in(KEY, 2))
agree = float(jnp.mean((jnp.argmax(y_dig, -1)
                        == jnp.argmax(y_ana, -1)).astype(jnp.float32)))
print(f"\nAIMC inference: next-char agreement with digital = {agree:.0%}")
print(f"CM_* instruction counts for {xs.shape[0]} steps x 4 seqs: "
      f"{ctx.instruction_counts()}")

# ---- the paper's timing/energy exploration (Fig. 10) ------------------------
print("\nanalytical per-inference cost (paper Table II sizes):")
for nh in (256, 512, 750):
    w = lstm_workloads(nh)
    for sysc in (HIGH_POWER, LOW_POWER):
        dig = evaluate(w["dig_1c"], sysc)
        ana = evaluate(w["ana_case1"], sysc)
        s, e = speedup(dig, ana)
        print(f"  n_h={nh:3d} {sysc.name:10s}: digital "
              f"{dig.time_s * 1e6:7.1f}us -> AIMC {ana.time_s * 1e6:6.1f}us "
              f"({s:4.1f}x perf, {e:4.1f}x energy)")
