"""Train a language model end-to-end with the production launcher.

    PYTHONPATH=src python examples/train_lm.py            # CPU-sized demo
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M-param run

Drives `repro.launch.train` — the same module a pod launch uses — through
the full substrate: deterministic sharded data, FSDP+TP step function with
gradient accumulation and remat, atomic checkpointing with auto-resume
(kill it mid-run and re-launch: it continues), straggler monitor, heartbeat.

The demo run uses the llama3.2-3b reduced config for a quick loss curve;
--full trains a ~100M-parameter llama-family config for a few hundred steps
(hours on this single-core container, minutes on real hardware — identical
code path either way).
"""

import shutil
import sys
import tempfile

from repro.launch import train

full = "--full" in sys.argv
ckpt = tempfile.mkdtemp(prefix="alpine_train_")
try:
    if full:
        # ~100M params: 12L x 768d x 12H, 3072 ff, 32k vocab — registered as
        # a one-off config through the same ArchSpec machinery.
        import dataclasses
        import repro.configs.llama32_3b as l3
        from repro.configs import ArchSpec
        from repro.models.transformer import TransformerConfig
        cfg100m = TransformerConfig(
            name="lm_100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=12, d_ff=3072, vocab=32000, q_chunk=256, kv_chunk=256)
        spec = dataclasses.replace(l3.ARCH, model_cfg=cfg100m,
                                   smoke_cfg=cfg100m)
        # monkey-patch the registry entry for this process only
        import repro.configs as configs
        orig = configs.get_arch
        configs.get_arch = lambda a: spec if a == "lm_100m" else orig(a)
        train.main(["--arch", "lm_100m", "--smoke", "--steps", "300",
                    "--global-batch", "8", "--seq-len", "512",
                    "--ckpt-dir", ckpt, "--ckpt-every", "100",
                    "--log-every", "10"])
    else:
        train.main(["--arch", "llama3.2-3b", "--smoke", "--steps", "60",
                    "--global-batch", "8", "--seq-len", "64",
                    "--ckpt-dir", ckpt, "--ckpt-every", "30",
                    "--log-every", "10"])
        print("\nresuming from the checkpoint to prove restart-exactness...")
        train.main(["--arch", "llama3.2-3b", "--smoke", "--steps", "70",
                    "--global-batch", "8", "--seq-len", "64",
                    "--ckpt-dir", ckpt, "--ckpt-every", "100",
                    "--log-every", "5"])
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
