PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 test bench serve-aimc serve-aimc-reprogram

# Tier-1 verify: the gate every PR must keep green.
tier1:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

bench:
	$(PY) -m benchmarks.run

# Program-once AIMC serving vs the legacy per-call-reprogram path (A/B for
# the program API speedup; see DESIGN.md §2).
serve-aimc:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc

serve-aimc-reprogram:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc --reprogram
