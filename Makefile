PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 test test-fast test-all bench bench-pipeline bench-json \
        bench-serving bench-server serve-aimc serve-aimc-reprogram \
        serve-aimc-multicore serve-smoke serve-sharded serve-multi \
        serve-chaos serve-drift serve-paged serve-auto docs-check

# Tier-1 verify: the gate every PR must keep green (runs everything).
tier1:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

# Tier split (pytest markers, see pyproject.toml): `test-fast` skips the
# slow interpret-mode Pallas sweeps and multi-process system tests for a
# quick inner loop; `test-all` is the full tier (identical scope to tier1,
# without -x so every failure reports).
test-fast:
	$(PY) -m pytest -q -m "not pallas and not slow"

test-all:
	$(PY) -m pytest -q

bench:
	$(PY) -m benchmarks.run

# Multi-core schedule benchmarks alone (measured vs predicted).
bench-pipeline:
	$(PY) -m benchmarks.bench_pipeline

# Machine-readable benchmark artifact: per-case wall-clock, modeled latency
# and check pass/fail (the cross-PR perf-trajectory record). The full suite
# writes BENCH_all.json — including the sharded-engine serving checks on a
# forced 2-device mesh; the kernel perf-smoke alone writes
# BENCH_kernels.json (same artifact ci.sh --fast produces). A partial run
# (crashed sub-bench, --only) refuses to overwrite a complete BENCH_all.json.
bench-json:
	$(PY) -m benchmarks.run --mesh data:2,model:1 --json BENCH_all.json
	$(PY) -m benchmarks.bench_kernels --json BENCH_kernels.json

# Serving-engine benchmark alone (continuous batching vs static batch,
# PLUS the sharded engine vs single-device on a forced 2-device
# host-platform mesh: bit-equality + ledger reconciliation are the bar).
bench-serving:
	$(PY) -m benchmarks.bench_serving --mesh data:2,model:1 \
	    --json BENCH_serving.json

# Multi-tenant server benchmark alone (two models on one crossbar pool:
# per-tenant tok/s + TTFT/TPOT percentiles, quota fairness under a
# saturated contended window, exact per-tenant ledger reconciliation).
bench-server:
	$(PY) -m benchmarks.bench_server --json BENCH_server.json

# Docs link-rot gate: every file path README/DESIGN/EXPERIMENTS/ROADMAP
# mention must exist (tools/docs_check.py; part of ci.sh --fast).
docs-check:
	$(PY) tools/docs_check.py

# Continuous-batching engine smoke: a ragged Poisson trace through the
# programmed AIMC path (the ci.sh --fast engine smoke, runnable alone).
serve-smoke:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --requests 4 \
	    --prompt-len 8 --gen 4 --slots 2 --trace poisson:300 --exec aimc

# Program-once AIMC serving vs the legacy per-call-reprogram path (A/B for
# the program API speedup; see DESIGN.md §2).
serve-aimc:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc

serve-aimc-reprogram:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc --reprogram

# Multi-core AIMC serving: matrices spread over 4 per-core tile contexts,
# per-core CM_*/comm ledgers + modeled latency reported (core.schedule).
serve-aimc-multicore:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc --cores 4

# Sharded serving smoke: the continuous-batching engine over a forced
# 2-device host-platform mesh (slots over data, crossbar bit lines over
# model; DESIGN.md §11) with per-device ledger reporting.
serve-sharded:
	XLA_FLAGS="--xla_force_host_platform_device_count=2 $(XLA_FLAGS)" \
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --requests 4 \
	    --prompt-len 8 --gen 4 --slots 2 --trace poisson:300 --exec aimc \
	    --cores 2 --mesh data:2,model:1

# Chaos smoke: deterministic mid-trace faults (tile corruption at chunk 1,
# core kill at chunk 3) through the drift/health/chaos tick (DESIGN.md §14).
# The engine must detect via probe, drain the dead core onto its peer,
# hot-reprogram bit-exactly, and close the CM_* + recal-CM_INITIALIZE books
# exactly — exits nonzero on a lost request, an unfired fault, or ledger
# drift. Same invocation as the ci.sh --fast chaos smoke.
serve-chaos:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --requests 6 \
	    --prompt-len 8 --gen 6 --slots 3 --trace poisson:300 --exec aimc \
	    --cores 2 --decode-chunk 2 --chaos "corrupt:0@1:0.5,kill:1@3"

# Drift-aware serving smoke: power-law conductance decay on the serve clock
# with online probes and threshold-triggered hot recalibration.
serve-drift:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --requests 6 \
	    --prompt-len 8 --gen 8 --slots 3 --trace poisson:300 --exec aimc \
	    --cores 2 --decode-chunk 2 --drift 0.3 --drift-t0 0.01

# Paged-engine smoke: fixed-size KV pages + content-hashed prefix cache on
# a shared-system-prompt trace (DESIGN.md §15). --paged-verify exits
# nonzero unless the shared span is prefilled exactly once, the page
# ledger reconciles exactly, and nothing recompiles after warmup. Same
# invocation as the ci.sh --fast paged smoke.
serve-paged:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --requests 8 \
	    --prompt-len 12 --gen 6 --slots 4 --exec aimc \
	    --page-size 4 --prefix-cache --shared-prefix 8 --paged-verify

# Auto-placement smoke: the cost-model placer picks the analog/digital
# split under a 2-tile budget — the smoke model overflows, so serving
# time-multiplexes a 2-state rotation plan, billing CM_INITIALIZE per
# swap (DESIGN.md §16). --placement-verify exits nonzero unless tokens
# are bit-equal to the all-digital oracle, every state packs within
# budget, and the swap books reconcile. Same invocation as the ci.sh
# --fast placement smoke.
serve-auto:
	$(PY) -m repro.launch.serve --arch granite-8b --smoke --exec aimc \
	    --placement auto:2 --tile-rows 64 --adc-alpha 0.5 --requests 4 \
	    --prompt-len 8 --gen 6 --seed 89 --placement-verify

# Multi-tenant serving smoke: two models resident in one process (granite
# co-programmed on the shared TilePool, xlstm digital), interleaved
# Poisson traffic with weighted tenant quotas (DESIGN.md §12); exits
# nonzero on ledger-reconciliation failure or a starved tenant.
serve-multi:
	$(PY) -m repro.launch.serve --smoke \
	    --models granite-8b:aimc,xlstm-350m:digital \
	    --tenants premium:granite-8b:2,standard:granite-8b:1:sjf,batch:xlstm-350m \
	    --requests 8 --prompt-len 8 --gen 4 --slots 2 --trace poisson:200
