"""Docs link-rot gate: every file path the front-door docs mention must
exist (``make docs-check``; the README acceptance bar of ISSUE 5).

Scans README.md / DESIGN.md / EXPERIMENTS.md / ROADMAP.md for repo-path
lookalikes — tokens with a known source extension or a path into a
first-level repo directory — and fails listing any that do not resolve.
Conservative on purpose: URLs, placeholders (``*``, ``<``, ``{``) and
section references (``file.py::symbol`` keeps only the file part) are
skipped, so a miss means a genuinely dead reference, not a style choice.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md")
EXTS = (".py", ".md", ".sh", ".json", ".toml", ".txt")
# only paths under these roots are checked (bare filenames too ambiguous)
DIRS = ("src/", "tests/", "benchmarks/", "examples/", "tools/")
TOKEN = re.compile(r"[A-Za-z0-9_./-]+")
SKIP_SUBSTR = ("http://", "https://", "*", "<", "{")


def candidates(text: str):
    for tok in TOKEN.findall(text):
        tok = tok.split("::")[0].rstrip(".")          # file.py::symbol, "x."
        if any(s in tok for s in SKIP_SUBSTR):
            continue
        if tok.startswith(".") or tok.endswith(("_", "/")):
            continue                                  # glob/prefix fragments
        if tok.startswith(DIRS) or tok.endswith(EXTS):
            yield tok


def main() -> int:
    # bare filenames (the architecture diagram names modules without their
    # directory) resolve against every basename in the tree; qualified
    # paths must resolve exactly
    basenames = {p.name for p in ROOT.rglob("*")
                 if p.is_file() and ".git" not in p.parts}
    missing = []
    for doc in DOCS:
        path = ROOT / doc
        if not path.exists():
            missing.append((doc, "(the doc itself is missing)"))
            continue
        for tok in set(candidates(path.read_text())):
            if "/" in tok:
                # repo-rooted, or package-relative (core/aimc.py — the
                # docs' convention for modules under src/repro/)
                ok = ((ROOT / tok).exists()
                      or (ROOT / "src" / "repro" / tok).exists())
            else:
                ok = tok in basenames
            if not ok:
                missing.append((doc, tok))
    if missing:
        print("dead file references in docs:")
        for doc, tok in sorted(missing):
            print(f"  {doc}: {tok}")
        return 1
    print(f"docs-check OK: all file references in {', '.join(DOCS)} resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
