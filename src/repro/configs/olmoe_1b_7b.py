"""olmoe-1b-7b — 64-expert top-8 MoE.

[arXiv:2409.02060; hf]. 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="olmoe_1b_7b",
    family="moe",
    module="transformer",
    model_cfg=TransformerConfig(
        name="olmoe_1b_7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1024, vocab=50304, n_experts=64, top_k=8,
        rope_theta=1e4),
    smoke_cfg=TransformerConfig(
        name="olmoe_1b_7b_smoke", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=4, d_ff=32, vocab=128, n_experts=8, top_k=2,
        q_chunk=16, kv_chunk=16),
    source="arXiv:2409.02060; hf",
    # 1.3B active params: the whole per-shard batch fits one microbatch, so
    # FSDP gathers weights ONCE per step instead of 16x (§Perf iteration)
    microbatch=4,
)
