"""granite-8b (code) — llama-architecture dense decoder.

[arXiv:2405.04324; hf]. 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="granite_8b",
    family="dense",
    module="transformer",
    model_cfg=TransformerConfig(
        name="granite_8b", n_layers=36, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=49152, rope_theta=1e7),
    smoke_cfg=TransformerConfig(
        name="granite_8b_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=128, q_chunk=16, kv_chunk=16),
    source="arXiv:2405.04324; hf",
)
