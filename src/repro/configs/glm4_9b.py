"""glm4-9b — dense decoder, RoPE, aggressive GQA (kv=2).

[hf:THUDM/glm-4-9b; hf]. 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="glm4_9b",
    family="dense",
    module="transformer",
    model_cfg=TransformerConfig(
        name="glm4_9b", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=2, d_ff=13696, vocab=151552, rope_theta=1e4),
    smoke_cfg=TransformerConfig(
        name="glm4_9b_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=160, vocab=128, q_chunk=16, kv_chunk=16),
    source="hf:THUDM/glm-4-9b; hf",
)
