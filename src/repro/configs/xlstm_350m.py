"""xlstm-350m — alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517; unverified]. 24L d_model=1024 4H vocab=50304, d_ff=0
(projections live inside the xLSTM blocks). O(1) recurrent state -> runs the
long_500k cell. Direct descendant of the ALPINE paper's LSTM exploration.
"""
from repro.configs import ArchSpec
from repro.models.xlstm import XlstmConfig

ARCH = ArchSpec(
    arch_id="xlstm_350m",
    family="ssm",
    module="xlstm",
    model_cfg=XlstmConfig(
        name="xlstm_350m", n_layers=24, d_model=1024, n_heads=4,
        vocab=50304, chunk=512),
    smoke_cfg=XlstmConfig(
        name="xlstm_350m_smoke", n_layers=4, d_model=32, n_heads=2,
        vocab=128, chunk=8),
    source="arXiv:2405.04517; unverified",
    supports_long=True,
)
