"""arctic-480b — 128-expert top-2 MoE with dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf]. 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual. Adafactor keeps the
~half-terabyte of expert parameters trainable inside v5e HBM.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="arctic_480b",
    family="moe",
    module="transformer",
    model_cfg=TransformerConfig(
        name="arctic_480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=4864, vocab=32000, n_experts=128, top_k=2,
        moe_dense_residual=True, moe_dense_ff=4864, rope_theta=1e6),
    smoke_cfg=TransformerConfig(
        name="arctic_480b_smoke", n_layers=2, d_model=56, n_heads=7,
        n_kv_heads=1, d_ff=64, vocab=128, n_experts=8, top_k=2,
        moe_dense_residual=True, moe_dense_ff=64, q_chunk=16, kv_chunk=16),
    source="hf:Snowflake/snowflake-arctic-base; hf",
    optimizer="adafactor",
    param_dtype="bfloat16",
    # FSDP re-gathers every weight per microbatch (x3 with remat recompute);
    # microbatch=2 halves that wire at ~6 GB more activation memory (§Perf).
    microbatch=2,
)
