"""qwen1.5-110b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]. 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064. Largest dense model in the pool: Adafactor optimizer and
a model-axis-sharded KV cache keep it inside v5e HBM.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="qwen15_110b",
    family="dense",
    module="transformer",
    model_cfg=TransformerConfig(
        name="qwen15_110b", n_layers=80, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=49152, vocab=152064, qkv_bias=True,
        rope_theta=1e6),
    smoke_cfg=TransformerConfig(
        name="qwen15_110b_smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab=128, qkv_bias=True,
        q_chunk=16, kv_chunk=16),
    source="hf:Qwen/Qwen1.5-0.5B; hf",

    optimizer="adafactor",
)
