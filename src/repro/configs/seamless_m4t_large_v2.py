"""seamless-m4t-large-v2 — encoder-decoder multimodal transformer backbone.

[arXiv:2308.11596; hf]. 24(+24)L d_model=1024 16H d_ff=8192 vocab=256206.
Audio frontend is a STUB: precomputed frame embeddings feed the encoder.
Shape policy (DESIGN.md §4): train/prefill cells use seq_len encoder frames
and seq_len/4 decoder tokens; decode cells use a seq_len decoder cache with
cross-attention K/V from seq_len/4 encoder frames.
"""
from repro.configs import ArchSpec
from repro.models.encdec import EncDecConfig

ARCH = ArchSpec(
    arch_id="seamless_m4t_large_v2",
    family="audio",
    module="encdec",
    model_cfg=EncDecConfig(
        name="seamless_m4t_large_v2", n_enc_layers=24, n_dec_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206),
    smoke_cfg=EncDecConfig(
        name="seamless_smoke", n_enc_layers=2, n_dec_layers=2, d_model=32,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=128, q_chunk=16, kv_chunk=16),
    source="arXiv:2308.11596; hf",
    tgt_ratio=4,
)
