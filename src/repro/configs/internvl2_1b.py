"""internvl2-1b — InternViT frontend (STUB) + InternLM2-1B LM backbone.

[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. The vision frontend supplies 256 precomputed patch embeddings
per sample (positions [0, 256) of the sequence), per the frontend-STUB rule.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="internvl2_1b",
    family="vlm",
    module="transformer",
    model_cfg=TransformerConfig(
        name="internvl2_1b", n_layers=24, d_model=896, n_heads=14,
        n_kv_heads=2, d_ff=4864, vocab=151655, rope_theta=1e6,
        n_patches=256),
    smoke_cfg=TransformerConfig(
        name="internvl2_1b_smoke", n_layers=2, d_model=56, n_heads=7,
        n_kv_heads=1, d_ff=112, vocab=128, n_patches=8,
        q_chunk=16, kv_chunk=16),
    source="arXiv:2404.16821; hf",
    n_patches=256,
)
