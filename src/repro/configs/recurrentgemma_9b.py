"""recurrentgemma-9b — RG-LRU + local attention hybrid (Griffin), 2:1.

[arXiv:2402.19427; unverified]. 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, window 2048. O(1) recurrent state + bounded window cache ->
runs the long_500k cell.
"""
from repro.configs import ArchSpec
from repro.models.rglru import RglruConfig

ARCH = ArchSpec(
    arch_id="recurrentgemma_9b",
    family="hybrid",
    module="rglru",
    model_cfg=RglruConfig(
        name="recurrentgemma_9b", n_layers=38, d_model=4096, n_heads=16,
        n_kv_heads=1, d_ff=12288, vocab=256000, window=2048),
    smoke_cfg=RglruConfig(
        name="recurrentgemma_9b_smoke", n_layers=5, d_model=48, n_heads=4,
        n_kv_heads=1, d_ff=96, vocab=128, window=16, conv_width=4,
        q_chunk=16, kv_chunk=16),
    source="arXiv:2402.19427; unverified",
    supports_long=True,
)
