"""llama3.2-3b — small llama3 dense decoder.

[hf:meta-llama/Llama-3.2-1B; unverified]. 28L d_model=3072 24H (GQA kv=8)
d_ff=8192 vocab=128256.
"""
from repro.configs import ArchSpec
from repro.models.transformer import TransformerConfig

ARCH = ArchSpec(
    arch_id="llama32_3b",
    family="dense",
    module="transformer",
    model_cfg=TransformerConfig(
        name="llama32_3b", n_layers=28, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=8192, vocab=128256, rope_theta=5e5,
        tie_embeddings=True),
    smoke_cfg=TransformerConfig(
        name="llama32_3b_smoke", n_layers=2, d_model=48, n_heads=6,
        n_kv_heads=2, d_ff=128, vocab=128, tie_embeddings=True,
        q_chunk=16, kv_chunk=16),
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)
