"""Architecture registry: the ten assigned architectures + paper workloads.

Each ``configs/<arch>.py`` exports an ``ARCH: ArchSpec`` with the exact
published configuration, a reduced same-family smoke config, and serving
metadata. ``get_arch`` / ``list_archs`` are the front door used by the
launcher (``--arch <id>``), the dry-run, tests and benchmarks.

Input-shape cells (assignment):
  train_4k     seq 4,096   global batch 256   (training)
  prefill_32k  seq 32,768  global batch 32    (inference prefill)
  decode_32k   seq 32,768  global batch 128   (one token vs KV cache)
  long_500k    seq 524,288 global batch 1     (long-context decode;
               sub-quadratic state only: rglru + xlstm — DESIGN.md §4)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    module: str                    # repro.models.<module>
    model_cfg: Any
    smoke_cfg: Any
    source: str                    # provenance note from the assignment
    supports_long: bool = False    # long_500k runs only for sub-quadratic archs
    supports_decode: bool = True
    cache_dtype: str = "bfloat16"  # KV/state cache dtype for serving
    optimizer: str = "adamw"       # adamw | adafactor (giant models)
    param_dtype: str = "float32"   # bfloat16 for the largest models
    microbatch: int = 1            # per-data-shard microbatch (grad accum)
    # enc-dec / vlm frontend metadata
    tgt_ratio: int = 0             # enc-dec: tgt_len = seq_len // tgt_ratio
    n_patches: int = 0             # vlm: image patch positions (stub embeds)

    def model_module(self):
        return importlib.import_module(f"repro.models.{self.module}")


_ARCH_IDS = [
    "internvl2_1b", "granite_8b", "llama32_3b", "qwen15_110b", "glm4_9b",
    "arctic_480b", "olmoe_1b_7b", "recurrentgemma_9b", "xlstm_350m",
    "seamless_m4t_large_v2",
]

ALIASES = {i.replace("_", "-"): i for i in _ARCH_IDS}
ALIASES |= {"internvl2-1b": "internvl2_1b", "llama3.2-3b": "llama32_3b",
            "qwen1.5-110b": "qwen15_110b", "olmoe-1b-7b": "olmoe_1b_7b",
            "seamless-m4t-large-v2": "seamless_m4t_large_v2"}


def list_archs() -> list[str]:
    return list(_ARCH_IDS)


def get_arch(arch_id: str) -> ArchSpec:
    key = ALIASES.get(arch_id, arch_id)
    if key not in _ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {_ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.ARCH


def cells(arch_id: str) -> list[tuple[str, str]]:
    """All (arch, shape) cells for an arch, honouring the skip rules."""
    spec = get_arch(arch_id)
    out = []
    for name, cell in SHAPES.items():
        if cell.kind == "decode" and not spec.supports_decode:
            continue
        if name == "long_500k" and not spec.supports_long:
            continue
        out.append((spec.arch_id, name))
    return out


def all_cells() -> list[tuple[str, str]]:
    return [c for a in _ARCH_IDS for c in cells(a)]
