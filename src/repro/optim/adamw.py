"""AdamW in pure JAX (functional: init / update), sharding-transparent.

Optimizer state mirrors the parameter pytree, so the same PartitionSpecs
shard both (FSDP); `update` is pure and scan/pjit friendly.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # bf16 halves optimizer HBM (large models)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(grads, state: AdamWState, params, cfg: AdamWConfig,
           lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        p32 = p.astype(jnp.float32)
        upd_ = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd_ = upd_ + cfg.weight_decay * p32
        return ((p32 - lr * upd_).astype(p.dtype), mu32.astype(dt),
                nu32.astype(dt))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
