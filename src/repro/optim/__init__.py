"""Pure-JAX optimizers: AdamW, Adafactor (+ LR schedules).

`make_optimizer(name)` returns (init_fn, update_fn, cfg) for the launcher.
"""

from __future__ import annotations

from repro.optim import adafactor, adamw, schedule


def make_optimizer(name: str, **overrides):
    if name == "adamw":
        cfg = adamw.AdamWConfig(**overrides)
        return (lambda p: adamw.init(p, cfg),
                lambda g, s, p, lr=1.0: adamw.update(g, s, p, cfg, lr), cfg)
    if name == "adafactor":
        cfg = adafactor.AdafactorConfig(**overrides)
        return (lambda p: adafactor.init(p, cfg),
                lambda g, s, p, lr=1.0: adafactor.update(g, s, p, cfg, lr), cfg)
    raise ValueError(f"unknown optimizer {name!r}")
