"""Adafactor (factored second moment, no first moment) in pure JAX.

Used by the giant assigned models (qwen1.5-110b, arctic-480b): the factored
second moment stores O(rows + cols) instead of O(rows * cols), cutting
optimizer HBM from 8 bytes/param (Adam moments) to ~0, which is the
difference between fitting and not fitting 480B trainable parameters on a
256-chip v5e pod (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8              # t^-decay second-moment decay schedule
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    grad_clip: float = 1.0


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: dict   # row second moments (factored; full v for <2D leaves)
    vc: dict   # col second moments (zeros placeholder for <2D leaves)


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params, cfg: AdafactorConfig) -> AdafactorState:
    def vr_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros_like(p, dtype=jnp.float32)

    def vc_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_init, params),
                          vc=jax.tree.map(vc_init, params))


def update(grads, state: AdafactorState, params, cfg: AdafactorConfig,
           lr_scale: jnp.ndarray | float = 1.0):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr = cfg.lr * lr_scale

    def upd(p, g, vr, vc):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps1
        if _factored(p):
            vr_new = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc_new = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr_new / jnp.maximum(
                jnp.mean(vr_new, axis=-1, keepdims=True), cfg.eps1)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                       + cfg.eps1)
        else:
            vr_new = beta2 * vr + (1 - beta2) * g2
            vc_new = vc
            u = g32 / (jnp.sqrt(vr_new) + cfg.eps1)
        # update clipping (RMS(u) <= clip_threshold)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        p32 = p.astype(jnp.float32)
        scale = jnp.maximum(jnp.sqrt(jnp.mean(p32 * p32)), cfg.eps2)
        new_p = p32 - lr * scale * u
        if cfg.weight_decay and p.ndim >= 2:
            new_p = new_p - lr * cfg.weight_decay * p32
        return new_p.astype(p.dtype), vr_new, vc_new

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    is_t = lambda t_: isinstance(t_, tuple)
    new_params = jax.tree.map(lambda t_: t_[0], out, is_leaf=is_t)
    new_vr = jax.tree.map(lambda t_: t_[1], out, is_leaf=is_t)
    new_vc = jax.tree.map(lambda t_: t_[2], out, is_leaf=is_t)
    return new_params, AdafactorState(step, new_vr, new_vc), {"grad_norm": gnorm}
