"""Version portability shims for the jax API surface we depend on.

The framework targets the modern jax API (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``jax.shard_map`` with ``check_vma``);
the pinned toolchain ships jax 0.4.37, where those spellings either do not
exist yet or live under different names. Everything version-sensitive is
funneled through this module so call sites stay on the modern spelling:

  * ``use_mesh(mesh)``      — context manager activating a mesh for both
    ``with_sharding_constraint`` and ``shard_map`` (``jax.set_mesh`` on new
    jax; the ``Mesh`` context manager — thread_resources — on 0.4.x).
  * ``current_mesh()``      — the active concrete mesh or ``None``; works
    inside and outside jit on both API generations.
  * ``shard_map(...)``      — ``jax.shard_map`` / ``jax.experimental``
    dispatch, translating ``check_vma`` <-> ``check_rep``.
"""

from __future__ import annotations

import jax


def use_mesh(mesh):
    """Activate `mesh` for the duration of a ``with`` block."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    # jax 0.4.x: Mesh is itself a context manager feeding thread_resources.
    return mesh


def current_mesh():
    """The active mesh, or None when no mesh context is active."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        return None if m is None or m.empty else m
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (0.4.x wraps it in a
    one-element-per-device list; newer jax returns the dict directly)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over (the flag's semantics are identical for our uses)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
