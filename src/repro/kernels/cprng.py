"""Counter-based Gaussian PRNG shared by the Pallas kernel and the oracle.

Kernel v2 generates read noise *inside* the fused MVM kernel, so the
`[KB, B, Np]` f32 noise tensor of kernel v1 — often larger than the int8
weight panel it rode along with — never exists in HBM. Two generators back
that contract:

  * counter mode (this module) — every noise element is a pure function of
    (seed, global element counter): a lowbias32 integer hash feeding a
    Box-Muller transform. The math is plain `jnp` elementwise arithmetic on
    `uint32`/`float32`, legal both inside a Pallas kernel body and as a bulk
    array computation, so `kernels/ref.py` and the interpret-mode kernel
    produce BIT-IDENTICAL noise for the same seed — block shape and grid
    layout cannot change a single draw. This is the default and the one CI
    exercises.
  * hardware mode (`kernels/aimc_mvm.py`, TPU only) — `pltpu.prng_seed` /
    `pltpu.prng_random_bits`, seeded per grid cell. Faster on silicon, but
    only statistically equivalent to the oracle; gated behind
    `noise_source="hw"` + the compiled TPU impl.

The element counter of a `[KB, B, Np]` noise tensor is the row-major flat
index `(k * B + b) * Np + c` in uint32 (wrapping) arithmetic; gate `g` of a
stacked multi-MVM re-seeds via `stack_seed(seed, g)`, so the fused stack and
per-gate calls with the derived seeds draw identical noise.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

# 2^32 / phi — the classic Weyl increment, used to decorrelate seed streams.
GOLDEN = 0x9E3779B9
_U24 = float(2 ** -24)
_TWO_PI = 6.283185307179586


def _u32(v) -> jnp.ndarray:
    return jnp.asarray(v).astype(jnp.uint32)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """lowbias32 (Degski/Evensen) avalanche hash on uint32 lanes.

    Elementwise xor/shift/multiply only — everything Mosaic and the
    interpreter lower identically, with deterministic uint32 wraparound.
    """
    x = _u32(x)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def stack_seed(seed: jnp.ndarray, g) -> jnp.ndarray:
    """Per-gate seed of slice `g` in a stacked multi-MVM.

    A per-gate single-matrix call with `stack_seed(seed, g)` draws exactly
    the noise the fused `[G, ...]` stack draws for slice g."""
    return mix32(_u32(seed) ^ (_u32(g) + jnp.uint32(1)) * jnp.uint32(GOLDEN))


def gauss_from_counter(seed: jnp.ndarray, ctr: jnp.ndarray) -> jnp.ndarray:
    """Standard-normal f32 draws, one per uint32 counter element.

    Two chained hash streams feed a Box-Muller transform; u1 lands in
    (0, 1] (so the log is finite) and u2 in [0, 1). 24-bit uniforms are
    exact in f32.
    """
    h1 = mix32(_u32(ctr) ^ _u32(seed))
    h2 = mix32(h1 + jnp.uint32(GOLDEN))
    u1 = ((h1 >> 8).astype(jnp.float32) + 1.0) * jnp.float32(_U24)
    u2 = (h2 >> 8).astype(jnp.float32) * jnp.float32(_U24)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(_TWO_PI) * u2)


def noise_tile(seed, k, row0, col0, bb: int, bn: int,
               b_total: int, n_total: int) -> jnp.ndarray:
    """One `[bb, bn]` tile of the virtual `[KB, B, Np]` noise tensor.

    `k` is the row-block index (traced), `row0`/`col0` the tile's global
    batch/column offsets. Counters address the LOGICAL tensor (`b_total` =
    unpadded batch, `n_total` = padded column count), so any block shape —
    and the bulk oracle below — reads the same draws; batch-padding rows
    beyond `b_total` alias other counters but are sliced away by the caller.
    """
    rows = row0 + lax.broadcasted_iota(jnp.uint32, (bb, bn), 0)
    cols = col0 + lax.broadcasted_iota(jnp.uint32, (bb, bn), 1)
    ctr = (_u32(k) * jnp.uint32(b_total) + rows) * jnp.uint32(n_total) + cols
    return gauss_from_counter(seed, ctr)


def read_noise_array(seed, kb: int, b: int, np_: int) -> jnp.ndarray:
    """The full `[KB, B, Np]` standard-normal tensor, counter-addressed.

    The oracle (`kernels/ref.py`) and the moment/parity tests materialize
    noise through this; the Pallas kernel never does."""
    ctr = lax.broadcasted_iota(jnp.uint32, (kb, b, np_), 0)
    ctr = ctr * jnp.uint32(b) + lax.broadcasted_iota(jnp.uint32, (kb, b, np_), 1)
    ctr = ctr * jnp.uint32(np_) + lax.broadcasted_iota(jnp.uint32, (kb, b, np_), 2)
    return gauss_from_counter(seed, ctr)
