"""Pallas TPU kernel: fused AIMC crossbar matmul.

This is the "tightly-coupled" execution of the paper translated to TPU terms:
DAC quantization, the int8 crossbar MAC, bit-line read noise, ADC quantization
and the digital per-row-block accumulation all happen in ONE kernel, so no
analog-domain intermediate (x_q, bit-line accumulations, ADC codes) ever
round-trips to HBM — the TPU analogue of not crossing the I/O bus.

Grid: (B/bB, Np/bN, KB) with the row-block dimension innermost so the f32
output block [bB, bN] is revisited consecutively and accumulated in place.
The int8 weight row-block panel [1, M, bN] is the *stationary* operand: it is
2-4x smaller than a bf16/fp32 weight panel would be (the TPU mirror of the
paper's working-set collapse), and for decode (B <= bB) it is streamed from
HBM exactly once.

MXU alignment: M (tile rows) and bN are multiples of 128; the int8 x int8
contraction uses preferred_element_type=int32 to engage the MXU int8 path.
VMEM working set per step: x block bB*M f32 + weight panel M*bN int8 +
noise/out blocks — sized well under 16 MB for the default (bB=128, M=512,
bN=512).

Validated against kernels/ref.py in interpret mode (CPU container); on real
TPU hardware drop interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import QMAX, QMIN


def _aimc_mvm_kernel(x_ref, w_ref, sw_ref, sx_ref, noise_ref, o_ref, *, adc_step: float):
    k = pl.program_id(2)

    # ---- DAC: signed-8-bit input quantization (CM_QUEUE) -------------------
    s_x = sx_ref[0, 0]
    x_q = jnp.clip(jnp.round(x_ref[...] / s_x), QMIN, QMAX).astype(jnp.int8)

    # ---- crossbar: int8 x int8 -> int32 bit-line MAC (CM_PROCESS) ----------
    acc = jax.lax.dot_general(
        x_q,
        w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    acc = acc + noise_ref[0]

    # ---- ADC: signed-8-bit output quantization ------------------------------
    codes = jnp.clip(jnp.round(acc / adc_step), QMIN, QMAX)

    # ---- digital: dequant + per-row-block accumulate (CM_DEQUEUE + cast) ----
    contrib = codes * (sw_ref[0] * (adc_step * s_x))[None, :]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("adc_step", "block_b", "block_n", "interpret"),
)
def aimc_matmul_pallas(
    x, w_q, s_w, s_x, read_noise, *,
    adc_step: float,
    block_b: int = 128,
    block_n: int = 512,
    interpret: bool = True,
):
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    bb = min(block_b, b)
    bn = min(block_n, np_)
    if b % bb or np_ % bn:
        raise ValueError(f"B={b} / Np={np_} not divisible by blocks ({bb},{bn})")

    grid = (b // bb, np_ // bn, kb)
    return pl.pallas_call(
        functools.partial(_aimc_mvm_kernel, adc_step=float(adc_step)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i, j, k: (i, k)),          # x
            pl.BlockSpec((1, m, bn), lambda i, j, k: (k, 0, j)),    # w_q (stationary panel)
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),          # s_w
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),           # s_x
            pl.BlockSpec((1, bb, bn), lambda i, j, k: (k, i, j)),   # read noise
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w_q, s_w, s_x, read_noise)
