"""Pallas TPU kernels: fused AIMC crossbar matmul (v1 legacy + kernel v2).

This is the "tightly-coupled" execution of the paper translated to TPU terms:
DAC quantization, the int8 crossbar MAC, bit-line read noise, ADC quantization
and the digital per-row-block accumulation all happen in ONE kernel, so no
analog-domain intermediate (x_q, bit-line accumulations, ADC codes) ever
round-trips to HBM — the TPU analogue of not crossing the I/O bus.

Kernel v2 (`aimc_matmul_pallas_v2`, `aimc_matmul_pallas_stacked`) closes the
three leaks v1 still had around the fused MAC:

  * in-kernel read noise — v1 streamed a `[KB, B, Np]` f32 noise tensor from
    HBM (4x the bytes of the int8 weight panel at square shapes, streamed
    even as zeros when noise was off). v2 takes a scalar-prefetched uint32
    seed instead and draws the noise in VMEM: counter mode (`kernels/cprng`,
    bit-identical to the oracle, the CI path) or the TPU hardware PRNG
    (`pltpu.prng_seed`/`prng_random_bits`, seeded per grid cell;
    `noise_source="hw"`, compiled TPU only).
  * fused epilogue — bias add + a statically-selected activation
    (`relu`/`sigmoid`/`tanh`/`none`) run on the last row-block grid step,
    while the output block is still VMEM-resident, so the per-layer output
    leaves the kernel finished instead of round-tripping through a separate
    XLA bias/activation op.
  * gate-fused multi-MVM — a `[G, KB, M, Np]` stack (LSTM's four gates,
    attention QKV, gate/up FFN pairs) runs as ONE weight-stationary
    `pallas_call` sharing the input and its single DAC scale, with a per-gate
    epilogue. Slice g draws noise under `cprng.stack_seed(seed, g)`, so the
    stack is bit-equal to per-gate v2 calls.

Grid: (B/bB, Np/bN, KB) — (G, B/bB, Np/bN, KB) stacked — with the row-block
dimension innermost so the f32 output block [bB, bN] is revisited
consecutively and accumulated in place. The int8 weight row-block panel
[1, M, bN] is the *stationary* operand: 2-4x smaller than a bf16/fp32 weight
panel (the TPU mirror of the paper's working-set collapse), and for decode
(B <= bB) it is streamed from HBM exactly once.

MXU alignment: M (tile rows) and bN are multiples of 128; the int8 x int8
contraction uses preferred_element_type=int32 to engage the MXU int8 path.
VMEM working set per step: x block bB*M f32 + weight panel M*bN int8 + out
block — v2 carries no noise block — sized well under 16 MB for the default
(bB=128, M=512, bN=512).

Validated against kernels/ref.py in interpret mode (CPU container); on real
TPU hardware drop interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only module; present in the baked toolchain, absent on bare CPU
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core.quant import QMAX, QMIN
from repro.kernels import cprng
# One epilogue table for kernel and oracle: what the kernel applies on its
# last grid step is literally what the unfused fallback applies after it.
from repro.kernels.ref import EPILOGUE_FNS as _ACT_FNS

EPILOGUES = ("none", "relu", "sigmoid", "tanh")
NOISE_SOURCES = ("counter", "hw")


def _check_epilogue(activation: str) -> None:
    if activation not in EPILOGUES:
        raise ValueError(
            f"unknown epilogue {activation!r}; expected one of {EPILOGUES}")


# ---------------------------------------------------------------------------
# v1 kernel — legacy contract with an explicit HBM noise operand. Kept for
# the staged/loose comparisons and the v1 differential tests; the execution
# path (`core.aimc.aimc_apply`) no longer uses it.
# ---------------------------------------------------------------------------


def _aimc_mvm_kernel(x_ref, w_ref, sw_ref, sx_ref, noise_ref, o_ref, *, adc_step: float):
    k = pl.program_id(2)

    # ---- DAC: signed-8-bit input quantization (CM_QUEUE) -------------------
    s_x = sx_ref[0, 0]
    x_q = jnp.clip(jnp.round(x_ref[...] / s_x), QMIN, QMAX).astype(jnp.int8)

    # ---- crossbar: int8 x int8 -> int32 bit-line MAC (CM_PROCESS) ----------
    acc = jax.lax.dot_general(
        x_q,
        w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    acc = acc + noise_ref[0]

    # ---- ADC: signed-8-bit output quantization ------------------------------
    codes = jnp.clip(jnp.round(acc / adc_step), QMIN, QMAX)

    # ---- digital: dequant + per-row-block accumulate (CM_DEQUEUE + cast) ----
    contrib = codes * (sw_ref[0] * (adc_step * s_x))[None, :]

    @pl.when(k == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("adc_step", "block_b", "block_n", "interpret"),
)
def aimc_matmul_pallas(
    x, w_q, s_w, s_x, read_noise, *,
    adc_step: float,
    block_b: int = 128,
    block_n: int = 512,
    interpret: bool = True,
):
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    bb = min(block_b, b)
    bn = min(block_n, np_)
    if b % bb or np_ % bn:
        raise ValueError(f"B={b} / Np={np_} not divisible by blocks ({bb},{bn})")

    grid = (b // bb, np_ // bn, kb)
    return pl.pallas_call(
        functools.partial(_aimc_mvm_kernel, adc_step=float(adc_step)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, m), lambda i, j, k: (i, k)),          # x
            pl.BlockSpec((1, m, bn), lambda i, j, k: (k, 0, j)),    # w_q (stationary panel)
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),          # s_w
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),           # s_x
            pl.BlockSpec((1, bb, bn), lambda i, j, k: (k, i, j)),   # read noise
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w_q, s_w, s_x, read_noise)


# ---------------------------------------------------------------------------
# kernel v2 — in-kernel PRNG noise + fused epilogue
# ---------------------------------------------------------------------------


def _in_kernel_noise(seed, k, i, j, grid_dims, bb: int, bn: int,
                     b_total: int, n_total: int, noise_source: str):
    """One [bb, bn] tile of read noise, generated in VMEM (never from HBM)."""
    if noise_source == "counter":
        return cprng.noise_tile(seed, k, i * bb, j * bn, bb, bn,
                                b_total, n_total)
    # hardware PRNG (compiled TPU only): a distinct stream per grid cell.
    cell = jnp.int32(0)
    for pid, extent in grid_dims:
        cell = cell * jnp.int32(extent) + pid
    pltpu.prng_seed(seed.astype(jnp.int32) + cell)
    h1 = pltpu.bitcast(pltpu.prng_random_bits((bb, bn)), jnp.uint32)
    h2 = pltpu.bitcast(pltpu.prng_random_bits((bb, bn)), jnp.uint32)
    u1 = ((h1 >> 8).astype(jnp.float32) + 1.0) * jnp.float32(2 ** -24)
    u2 = (h2 >> 8).astype(jnp.float32) * jnp.float32(2 ** -24)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(
        jnp.float32(6.283185307179586) * u2)


def _mac_adc_contrib(x_blk, w_panel, sw_row, s_x, noise, adc_step: float):
    """DAC -> int8 MAC -> (+noise) -> ADC -> dequant: one row-block contrib."""
    x_q = jnp.clip(jnp.round(x_blk / s_x), QMIN, QMAX).astype(jnp.int8)
    acc = jax.lax.dot_general(
        x_q, w_panel, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    if noise is not None:
        acc = acc + noise
    codes = jnp.clip(jnp.round(acc / adc_step), QMIN, QMAX)
    return codes * (sw_row * (adc_step * s_x))[None, :]


def _aimc_mvm_kernel_v2(seed_ref, x_ref, w_ref, sw_ref, sx_ref, *rest, adc_step: float,
                        sigma: float, activation: str, has_bias: bool,
                        grid_bij: tuple[int, int, int], b_total: int,
                        n_total: int, noise_source: str):
    bias_ref = rest[0] if has_bias else None
    o_ref = rest[-1]
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    kb = grid_bij[2]
    bb, bn = o_ref.shape

    noise = None
    if sigma > 0.0:
        grid_dims = ((i, grid_bij[0]), (j, grid_bij[1]), (k, kb))
        noise = sigma * _in_kernel_noise(seed_ref[0], k, i, j, grid_dims,
                                         bb, bn, b_total, n_total,
                                         noise_source)
    s_x = sx_ref[0, 0]
    contrib = _mac_adc_contrib(x_ref[...], w_ref[0], sw_ref[0], s_x, noise,
                               adc_step)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += contrib

    if has_bias or activation != "none":
        @pl.when(k == kb - 1)
        def _epilogue():
            y = o_ref[...]
            if has_bias:
                y = y + bias_ref[...]
            o_ref[...] = _ACT_FNS[activation](y)


@functools.partial(
    jax.jit,
    static_argnames=("adc_step", "sigma", "activation", "block_b", "block_n",
                     "noise_source", "interpret", "b_logical"),
)
def aimc_matmul_pallas_v2(
    x, w_q, s_w, s_x, seed=None, bias=None, *,
    adc_step: float,
    sigma: float = 0.0,
    activation: str = "none",
    block_b: int = 128,
    block_n: int = 512,
    noise_source: str = "counter",
    interpret: bool = True,
    b_logical: int | None = None,
):
    """Kernel v2 front door (block-aligned shapes; `ops.aimc_matmul_v2` pads).

    `seed` is a scalar uint32 array consumed via scalar prefetch; `sigma` the
    static read-noise std in accumulator LSBs (0.0 compiles the noise code
    out entirely). `bias` is a `[1, Np]` f32 row added on the last row-block
    step; `activation` one of `EPILOGUES`. `b_logical` is the pre-padding
    batch, addressing noise counters so padded rows never shift real draws.
    """
    _check_epilogue(activation)
    if noise_source not in NOISE_SOURCES:
        raise ValueError(f"unknown noise_source {noise_source!r}")
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    bb = min(block_b, b)
    bn = min(block_n, np_)
    if b % bb or np_ % bn:
        raise ValueError(f"B={b} / Np={np_} not divisible by blocks ({bb},{bn})")
    if seed is None:
        if sigma > 0.0:
            raise ValueError("sigma > 0 requires a seed")
        seed = jnp.zeros((1,), jnp.uint32)
    else:
        seed = jnp.asarray(seed).reshape((1,)).astype(jnp.uint32)

    grid = (b // bb, np_ // bn, kb)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((bb, m), lambda i, j, k, s: (i, k)),          # x
        pl.BlockSpec((1, m, bn), lambda i, j, k, s: (k, 0, j)),    # w_q panel
        pl.BlockSpec((1, bn), lambda i, j, k, s: (k, j)),          # s_w
        pl.BlockSpec((1, 1), lambda i, j, k, s: (0, 0)),           # s_x
    ]
    operands = [x.astype(jnp.float32), w_q, s_w, s_x]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k, s: (0, j)))
        operands.append(bias.reshape(1, np_).astype(jnp.float32))

    kernel = functools.partial(
        _aimc_mvm_kernel_v2,
        adc_step=float(adc_step), sigma=float(sigma), activation=activation,
        has_bias=has_bias, grid_bij=grid,
        b_total=int(b_logical if b_logical is not None else b),
        n_total=np_, noise_source=noise_source)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k, s: (i, j)))
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, np_), jnp.float32),
        interpret=interpret,
    )(seed, *operands)


# ---------------------------------------------------------------------------
# kernel v2 — gate-fused stacked multi-MVM
# ---------------------------------------------------------------------------


def _aimc_mvm_kernel_stacked(seed_ref, x_ref, w_ref, sw_ref, sx_ref, *rest,
                             adc_step: float, sigma: float,
                             activations: tuple[str, ...], has_bias: bool,
                             grid_gbij: tuple[int, int, int, int],
                             b_total: int, n_total: int, noise_source: str):
    bias_ref = rest[0] if has_bias else None
    o_ref = rest[-1]
    g, i, j, k = (pl.program_id(0), pl.program_id(1), pl.program_id(2),
                  pl.program_id(3))
    kb = grid_gbij[3]
    _, bb, bn = o_ref.shape

    noise = None
    if sigma > 0.0:
        seed_g = cprng.stack_seed(seed_ref[0], g)
        grid_dims = ((g, grid_gbij[0]), (i, grid_gbij[1]),
                     (j, grid_gbij[2]), (k, kb))
        noise = sigma * _in_kernel_noise(seed_g, k, i, j, grid_dims, bb, bn,
                                         b_total, n_total, noise_source)
    s_x = sx_ref[0, 0]
    contrib = _mac_adc_contrib(x_ref[...], w_ref[0, 0], sw_ref[0, 0], s_x,
                               noise, adc_step)

    @pl.when(k == 0)
    def _init():
        o_ref[0] = contrib

    @pl.when(k > 0)
    def _acc():
        o_ref[0] += contrib

    if has_bias or any(a != "none" for a in activations):
        @pl.when(k == kb - 1)
        def _epilogue():
            y = o_ref[0]
            if has_bias:
                y = y + bias_ref[0]
            if len(set(activations)) == 1:
                o_ref[0] = _ACT_FNS[activations[0]](y)
            else:
                # per-gate epilogue: one guarded write per distinct gate
                for gi, act in enumerate(activations):
                    @pl.when(g == gi)
                    def _write(y=y, act=act):
                        o_ref[0] = _ACT_FNS[act](y)


@functools.partial(
    jax.jit,
    static_argnames=("adc_step", "sigma", "activations", "block_b", "block_n",
                     "noise_source", "interpret", "b_logical"),
)
def aimc_matmul_pallas_stacked(
    x, w_q, s_w, s_x, seed=None, bias=None, *,
    adc_step: float,
    sigma: float = 0.0,
    activations: tuple[str, ...] | str = "none",
    block_b: int = 128,
    block_n: int = 512,
    noise_source: str = "counter",
    interpret: bool = True,
    b_logical: int | None = None,
):
    """Gate-fused multi-MVM: `[G, KB, M, Np]` weights, one shared `[B, K]`
    input and DAC scale, `[G, B, Np]` out — ONE weight-stationary
    `pallas_call` for the whole gate/head stack. `activations` is one
    epilogue for all gates or a per-gate tuple of length G; slice g draws
    noise under `cprng.stack_seed(seed, g)`."""
    g_, kb, m, np_ = w_q.shape
    if isinstance(activations, str):
        activations = (activations,) * g_
    activations = tuple(activations)
    if len(activations) != g_:
        raise ValueError(f"{len(activations)} activations for G={g_} gates")
    for a in activations:
        _check_epilogue(a)
    if noise_source not in NOISE_SOURCES:
        raise ValueError(f"unknown noise_source {noise_source!r}")
    b = x.shape[0]
    bb = min(block_b, b)
    bn = min(block_n, np_)
    if b % bb or np_ % bn:
        raise ValueError(f"B={b} / Np={np_} not divisible by blocks ({bb},{bn})")
    if seed is None:
        if sigma > 0.0:
            raise ValueError("sigma > 0 requires a seed")
        seed = jnp.zeros((1,), jnp.uint32)
    else:
        seed = jnp.asarray(seed).reshape((1,)).astype(jnp.uint32)

    grid = (g_, b // bb, np_ // bn, kb)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((bb, m), lambda g, i, j, k, s: (i, k)),           # x (shared)
        pl.BlockSpec((1, 1, m, bn), lambda g, i, j, k, s: (g, k, 0, j)),
        pl.BlockSpec((1, 1, bn), lambda g, i, j, k, s: (g, k, j)),     # s_w
        pl.BlockSpec((1, 1), lambda g, i, j, k, s: (0, 0)),            # s_x
    ]
    operands = [x.astype(jnp.float32), w_q, s_w, s_x]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda g, i, j, k, s: (g, j)))
        operands.append(bias.reshape(g_, np_).astype(jnp.float32))

    kernel = functools.partial(
        _aimc_mvm_kernel_stacked,
        adc_step=float(adc_step), sigma=float(sigma), activations=activations,
        has_bias=has_bias, grid_gbij=grid,
        b_total=int(b_logical if b_logical is not None else b),
        n_total=np_, noise_source=noise_source)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bb, bn), lambda g, i, j, k, s: (g, i, j)))
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g_, b, np_), jnp.float32),
        interpret=interpret,
    )(seed, *operands)
