"""Pure-jnp oracle for the AIMC crossbar MVM (the reference the Pallas kernel
must match bit-for-bit in tests).

Interface contract (shared with kernels/aimc_mvm.py and kernels/ops.py):

  x          f32/bf16 [B, KB*M]   activations, K already zero-padded to a
                                  whole number of row blocks
  w_q        int8     [KB, M, Np] programmed conductance codes, one row block
                                  per physical-tile row span (zero padded)
  s_w        f32      [KB, Np]    per (row-block, bit-line) weight scale, with
                                  drift gain / compensation already folded in
  s_x        f32      [1, 1]      DAC input scale (fixed or per-call max-abs)
  read_noise f32      [KB, B, Np] additive bit-line noise in accumulator LSBs
                                  (zeros when the noise model is disabled)
  adc_step   float    (static)    ADC step in accumulator LSBs (quant.adc_step_lsb)

Returns f32 [B, Np]:  sum over row blocks of
    ADC8(x_q_block @ w_q_block + noise) * adc_step * s_x * s_w_block
which is exactly the paper's data flow: CM_QUEUE (DAC quantize) ->
CM_PROCESS (analog MAC + ADC) -> CM_DEQUEUE + digital accumulate/cast.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant import adc_quantize, quantize


def aimc_matmul_ref(x, w_q, s_w, s_x, read_noise, *, adc_step: float) -> jnp.ndarray:
    if x.ndim != 2 or w_q.ndim != 3:
        raise ValueError(f"bad ranks: x{x.shape} w_q{w_q.shape}")
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    if x.shape[1] != kb * m:
        raise ValueError(f"x K={x.shape[1]} != KB*M={kb * m}")

    x_blocks = x.reshape(b, kb, m).astype(jnp.float32)
    x_q = quantize(x_blocks, s_x.reshape(()))                       # int8 [B,KB,M]
    acc = jnp.einsum(
        "bkm,kmn->kbn",
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
    ).astype(jnp.float32)                                           # [KB,B,Np]
    acc = acc + read_noise
    codes = adc_quantize(acc, jnp.float32(adc_step))                # int32 [KB,B,Np]
    contrib = codes.astype(jnp.float32) * s_w[:, None, :]           # [KB,B,Np]
    y = jnp.sum(contrib, axis=0) * (jnp.float32(adc_step) * s_x.reshape(()))
    return y.astype(jnp.float32)


def digital_matmul_ref(x, w, out_dtype=jnp.float32):
    """The digital (CPU/SIMD) baseline the paper compares against: a plain
    full-precision matmul."""
    return jnp.asarray(x @ w, dtype=out_dtype)
