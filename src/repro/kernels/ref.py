"""Pure-jnp oracle for the AIMC crossbar MVM (the reference the Pallas kernel
must match bit-for-bit in tests).

Interface contract (shared with kernels/aimc_mvm.py and kernels/ops.py):

  x          f32/bf16 [B, KB*M]   activations, K already zero-padded to a
                                  whole number of row blocks
  w_q        int8     [KB, M, Np] programmed conductance codes, one row block
                                  per physical-tile row span (zero padded)
  s_w        f32      [KB, Np]    per (row-block, bit-line) weight scale, with
                                  drift gain / compensation already folded in
  s_x        f32      [1, 1]      DAC input scale (fixed or per-call max-abs)
  read_noise f32      [KB, B, Np] additive bit-line noise in accumulator LSBs
                                  (zeros when the noise model is disabled)
  adc_step   float    (static)    ADC step in accumulator LSBs (quant.adc_step_lsb)

Returns f32 [B, Np]:  sum over row blocks of
    ADC8(x_q_block @ w_q_block + noise) * adc_step * s_x * s_w_block
which is exactly the paper's data flow: CM_QUEUE (DAC quantize) ->
CM_PROCESS (analog MAC + ADC) -> CM_DEQUEUE + digital accumulate/cast.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import adc_quantize, quantize
from repro.kernels import cprng

EPILOGUE_FNS = {
    "none": lambda y: y,
    "relu": lambda y: jnp.maximum(y, 0.0),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


def aimc_matmul_ref(x, w_q, s_w, s_x, read_noise, *, adc_step: float) -> jnp.ndarray:
    if x.ndim != 2 or w_q.ndim != 3:
        raise ValueError(f"bad ranks: x{x.shape} w_q{w_q.shape}")
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    if x.shape[1] != kb * m:
        raise ValueError(f"x K={x.shape[1]} != KB*M={kb * m}")

    x_blocks = x.reshape(b, kb, m).astype(jnp.float32)
    x_q = quantize(x_blocks, s_x.reshape(()))                       # int8 [B,KB,M]
    acc = jnp.einsum(
        "bkm,kmn->kbn",
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
    ).astype(jnp.float32)                                           # [KB,B,Np]
    acc = acc + read_noise
    codes = adc_quantize(acc, jnp.float32(adc_step))                # int32 [KB,B,Np]
    contrib = codes.astype(jnp.float32) * s_w[:, None, :]           # [KB,B,Np]
    y = jnp.sum(contrib, axis=0) * (jnp.float32(adc_step) * s_x.reshape(()))
    return y.astype(jnp.float32)


def aimc_matmul_ref_v2(x, w_q, s_w, s_x, seed=None, bias=None, *,
                       adc_step: float, sigma: float = 0.0,
                       activation: str = "none") -> jnp.ndarray:
    """Kernel-v2 oracle: counter-addressed in-kernel noise + fused epilogue.

    Noise is materialized here (the oracle's whole point is bulk-array
    clarity) through the SAME `cprng` counter math the Pallas kernel runs
    per tile, so kernel and oracle are bit-identical for a given seed. The
    epilogue (bias + activation) is the identical f32 arithmetic the kernel
    applies on its last row-block step.
    """
    kb, m, np_ = w_q.shape
    b = x.shape[0]
    if sigma > 0.0:
        if seed is None:
            raise ValueError("sigma > 0 requires a seed")
        noise = sigma * cprng.read_noise_array(seed, kb, b, np_)
    else:
        noise = jnp.zeros((kb, b, np_), jnp.float32)
    y = aimc_matmul_ref(x, w_q, s_w, s_x, noise, adc_step=adc_step)
    if bias is not None:
        y = y + bias.reshape(1, np_).astype(jnp.float32)
    return EPILOGUE_FNS[activation](y)


def aimc_matmul_stacked_ref(x, w_q, s_w, s_x, seed=None, bias=None, *,
                            adc_step: float, sigma: float = 0.0,
                            activations="none") -> jnp.ndarray:
    """Gate-fused stack oracle: per-gate v2 calls under `stack_seed` — the
    bit-equality target for `aimc_matmul_pallas_stacked`."""
    g_, kb, m, np_ = w_q.shape
    if isinstance(activations, str):
        activations = (activations,) * g_
    outs = []
    for g in range(g_):
        seed_g = cprng.stack_seed(seed, g) if seed is not None else None
        outs.append(aimc_matmul_ref_v2(
            x, w_q[g], s_w[g], s_x, seed_g,
            bias[g] if bias is not None else None,
            adc_step=adc_step, sigma=sigma, activation=activations[g]))
    return jnp.stack(outs)


def digital_matmul_ref(x, w, out_dtype=jnp.float32):
    """The digital (CPU/SIMD) baseline the paper compares against: a plain
    full-precision matmul."""
    return jnp.asarray(x @ w, dtype=out_dtype)
