"""Jit'd dispatch wrappers around the AIMC kernels.

``aimc_matmul`` is the single entry point used by ``core.aimc``; it selects
between the pure-jnp oracle (default on CPU — numerically identical to the
Pallas kernel) and the Pallas kernel (interpret mode here, native on TPU),
and normalizes padding so callers never worry about block alignment.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.aimc_mvm import aimc_matmul_pallas

IMPLS = ("ref", "pallas_interpret", "pallas_tpu")


def aimc_matmul(x, w_q, s_w, s_x, read_noise, *, adc_step: float,
                impl: str = "ref", block_b: int = 128, block_n: int = 512):
    """Fused AIMC crossbar matmul. See kernels/ref.py for the tensor contract."""
    if impl == "ref":
        return _ref.aimc_matmul_ref(x, w_q, s_w, s_x, read_noise, adc_step=adc_step)
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")

    b, k = x.shape
    kb, m, np_ = w_q.shape
    bb = min(block_b, _round_up(b, 8))
    bn = min(block_n, np_)
    while np_ % bn:
        bn //= 2
    b_pad = _round_up(b, bb)
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0)))
        read_noise = jnp.pad(read_noise, ((0, 0), (0, b_pad - b), (0, 0)))
    y = aimc_matmul_pallas(
        x, w_q, s_w, s_x, read_noise,
        adc_step=adc_step, block_b=bb, block_n=bn,
        interpret=(impl == "pallas_interpret"),
    )
    return y[:b]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m
