"""Jit'd dispatch wrappers around the AIMC kernels.

``aimc_matmul_v2`` is the execution-path entry point used by ``core.aimc``:
in-kernel PRNG read noise (scalar seed instead of a streamed `[KB, B, Np]`
tensor), fused bias/activation epilogue, and `aimc_matmul_stacked` for
gate-fused multi-MVM stacks. Each selects between the pure-jnp oracle
(default on CPU — numerically identical to the Pallas kernel) and the Pallas
kernel (interpret mode here, native on TPU), and normalizes padding so
callers never worry about block alignment.

``aimc_matmul`` keeps the v1 contract (an explicit noise operand) for the
staged/loose comparisons and differential tests; `read_noise=None` now skips
the noise operand entirely instead of streaming zeros.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.aimc_mvm import (EPILOGUES, aimc_matmul_pallas,
                                    aimc_matmul_pallas_stacked,
                                    aimc_matmul_pallas_v2)
from repro.kernels.ref import EPILOGUE_FNS  # re-export: unfused fallbacks

IMPLS = ("ref", "pallas_interpret", "pallas_tpu")


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_blocks(b: int, np_: int, block_b: int, block_n: int) -> tuple[int, int]:
    """Block sizes honoring TPU lane alignment: bN is always a multiple of
    128 that divides Np (weight columns are 128-padded at programming time;
    a non-aligned Np is a contract violation, not something to shrink the
    block below the lane width for)."""
    if np_ % 128:
        raise ValueError(
            f"Np={np_} is not 128-lane aligned; pad weights at programming "
            f"time (program_linear pads Np for exactly this reason)")
    bn = min(_round_up(block_n, 128), np_)
    while np_ % bn:
        bn -= 128
    bb = min(block_b, _round_up(b, 8))
    return bb, bn


def _check_impl(impl: str) -> None:
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")


def _check_noise_source(noise_source: str, sigma: float, impl: str) -> None:
    """The hardware PRNG (`pltpu.prng_*`) only lowers on compiled TPU; the
    counter generator is the oracle-bit-identical path everywhere else."""
    if sigma > 0.0 and noise_source == "hw" and impl != "pallas_tpu":
        raise ValueError(
            'noise_source="hw" needs impl="pallas_tpu" (the interpreter and '
            'the oracle have no hardware PRNG); use "counter"')


def aimc_matmul(x, w_q, s_w, s_x, read_noise=None, *, adc_step: float,
                impl: str = "ref", block_b: int = 128, block_n: int = 512):
    """v1-contract fused AIMC crossbar matmul (see kernels/ref.py).

    ``read_noise=None`` means noise-off and is executed through the v2
    kernel with NO noise operand (nothing streamed); an explicit tensor
    keeps the v1 path for staged comparisons and differential tests.
    """
    if read_noise is None:
        return aimc_matmul_v2(x, w_q, s_w, s_x, adc_step=adc_step, impl=impl,
                              block_b=block_b, block_n=block_n)
    if impl == "ref":
        return _ref.aimc_matmul_ref(x, w_q, s_w, s_x, read_noise, adc_step=adc_step)
    _check_impl(impl)

    b, k = x.shape
    kb, m, np_ = w_q.shape
    bb, bn = _pick_blocks(b, np_, block_b, block_n)
    b_pad = _round_up(b, bb)
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0)))
        read_noise = jnp.pad(read_noise, ((0, 0), (0, b_pad - b), (0, 0)))
    y = aimc_matmul_pallas(
        x, w_q, s_w, s_x, read_noise,
        adc_step=adc_step, block_b=bb, block_n=bn,
        interpret=(impl == "pallas_interpret"),
    )
    return y[:b]


def aimc_matmul_v2(x, w_q, s_w, s_x, seed=None, bias=None, *,
                   adc_step: float, sigma: float = 0.0,
                   activation: str = "none", impl: str = "ref",
                   block_b: int = 128, block_n: int = 512,
                   noise_source: str = "counter"):
    """Kernel-v2 fused AIMC matmul: in-kernel noise + fused epilogue.

    `seed`/`sigma` replace the v1 noise tensor (see kernels/cprng.py for the
    counter contract); `bias` is `[Np]`-broadcastable, `activation` one of
    `EPILOGUES`. Output: f32 `[B, Np]`, epilogue already applied.
    """
    if activation not in EPILOGUES:
        raise ValueError(f"unknown epilogue {activation!r}")
    _check_noise_source(noise_source, sigma, impl)
    if impl == "ref":
        return _ref.aimc_matmul_ref_v2(x, w_q, s_w, s_x, seed, bias,
                                       adc_step=adc_step, sigma=sigma,
                                       activation=activation)
    _check_impl(impl)

    b, k = x.shape
    kb, m, np_ = w_q.shape
    bb, bn = _pick_blocks(b, np_, block_b, block_n)
    b_pad = _round_up(b, bb)
    xp = jnp.pad(x, ((0, b_pad - b), (0, 0))) if b_pad != b else x
    y = aimc_matmul_pallas_v2(
        xp, w_q, s_w, s_x, seed, bias,
        adc_step=adc_step, sigma=sigma, activation=activation,
        block_b=bb, block_n=bn, noise_source=noise_source,
        interpret=(impl == "pallas_interpret"), b_logical=b,
    )
    return y[:b]


def aimc_matmul_stacked(x, w_q, s_w, s_x, seed=None, bias=None, *,
                        adc_step: float, sigma: float = 0.0,
                        activations="none", impl: str = "ref",
                        block_b: int = 128, block_n: int = 512,
                        noise_source: str = "counter"):
    """Gate-fused multi-MVM: `[G, KB, M, Np]` stack, shared `[B, K]` input.

    One weight-stationary kernel launch computes all G outputs
    (`[G, B, Np]`), sharing the input block and its DAC scale; gate g draws
    noise under `cprng.stack_seed(seed, g)` so results are bit-equal to G
    per-gate `aimc_matmul_v2` calls with the derived seeds.
    """
    _check_noise_source(noise_source, sigma, impl)
    if impl == "ref":
        return _ref.aimc_matmul_stacked_ref(x, w_q, s_w, s_x, seed, bias,
                                            adc_step=adc_step, sigma=sigma,
                                            activations=activations)
    _check_impl(impl)

    b, k = x.shape
    g_, kb, m, np_ = w_q.shape
    bb, bn = _pick_blocks(b, np_, block_b, block_n)
    b_pad = _round_up(b, bb)
    xp = jnp.pad(x, ((0, b_pad - b), (0, 0))) if b_pad != b else x
    if isinstance(activations, str):
        activations = (activations,) * g_
    y = aimc_matmul_pallas_stacked(
        xp, w_q, s_w, s_x, seed, bias,
        adc_step=adc_step, sigma=sigma, activations=tuple(activations),
        block_b=bb, block_n=bn, noise_source=noise_source,
        interpret=(impl == "pallas_interpret"), b_logical=b,
    )
    return y[:, :b]
