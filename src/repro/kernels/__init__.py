"""Pallas TPU kernels for the AIMC-simulation hot spots + pure-jnp oracles.

  aimc_mvm        — fused DAC -> int8 crossbar MAC -> noise -> ADC -> accumulate
                    (kernel v2: in-kernel PRNG noise, fused epilogue,
                    gate-fused multi-MVM stacks; v1 legacy entry kept)
  cprng           — counter-based Gaussian PRNG shared by kernel and oracle
                    (bit-identical noise from a scalar seed, no HBM tensor)
  flash_attention — chunked online-softmax attention (O(seq) memory)
  ops             — jit'd dispatch wrappers (impl = ref | pallas_interpret | pallas_tpu)
  ref             — pure-jnp oracles (bit-identical math, the AIMClib "checker")
"""
