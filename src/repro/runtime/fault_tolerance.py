"""Fault tolerance and straggler mitigation for the step loop.

At thousand-node scale the failure model is: (a) hard node loss -> the run
dies and is restarted by the cluster scheduler; (b) transient device/runtime
errors -> retry in-process; (c) stragglers -> detect, log, and (on repeated
offence) trigger an elastic re-mesh restart.

This module implements the in-process half and the restart protocol:

  * `resilient_step`  — wraps a compiled step; retries transient failures,
    re-raising only after `max_retries` (at which point the supervisor
    restarts from the latest atomic checkpoint — which `checkpoint.restore`
    can load onto a DIFFERENT mesh, i.e. elastic shrink/grow).
  * `StragglerMonitor` — per-step wall-time EWMA + deviation; flags steps
    slower than `threshold`x the running mean, exposing a callback hook (on a
    real fleet: report the slow host to the scheduler for cordoning).
  * `Heartbeat` — step-progress file other processes / the scheduler can
    watch; doubles as the liveness probe in the launch scripts.

Public surface: `is_transient(exc)`, `resilient_step(fn, max_retries,
on_retry)`, `backoff_schedule`, `StragglerMonitor`, `Heartbeat`,
`elastic_mesh_shapes`.
Invariant: classification is on the error MESSAGE, not the type —
deterministic failures (RESOURCE_EXHAUSTED, INVALID_ARGUMENT, plain
RuntimeErrors) raise immediately; only recognized infrastructure flakes
retry (pinned by tests/test_engine.py).
"""

from __future__ import annotations

import json
import os
import statistics
import time
from typing import Callable

from repro.core import noise as noise_lib

# The candidate exception TYPES a transient device/runtime failure surfaces
# as. Type alone is NOT enough to retry: XLA raises RuntimeError/XlaRuntimeError
# for genuine bugs (INVALID_ARGUMENT) and for out-of-memory (RESOURCE_EXHAUSTED)
# just as it does for a flaky interconnect — retrying an OOM re-runs the
# allocation that already failed, and retrying a bug hides it. Classification
# is therefore on the error MESSAGE: terminal substrings always raise,
# transient substrings (plus plain I/O errors) retry.
TRANSIENT_ERRORS = (RuntimeError, OSError)

# Never retry: deterministic failures — the same call will fail the same way
# (or worse, an OOM retry loop wedges the host until the supervisor kills it).
TERMINAL_SUBSTRINGS = (
    "RESOURCE_EXHAUSTED", "out of memory", "OUT_OF_MEMORY",
    "INVALID_ARGUMENT", "FAILED_PRECONDITION", "UNIMPLEMENTED",
    "PERMISSION_DENIED", "NOT_FOUND",
)

# Worth retrying: infrastructure flakes that a backoff genuinely clears.
TRANSIENT_SUBSTRINGS = (
    "UNAVAILABLE", "DEADLINE_EXCEEDED", "ABORTED", "CANCELLED", "INTERNAL",
    "DATA_LOSS", "connection", "socket", "timed out", "timeout", "transient",
    "temporarily",
)


def is_transient(exc: BaseException) -> bool:
    """Should this step failure be retried in-process?

    Terminal substrings win outright (an OSError carrying RESOURCE_EXHAUSTED
    is still terminal). Otherwise OSErrors — I/O against a live fleet — are
    presumed transient, while RuntimeErrors must positively look like an
    infrastructure flake: an unrecognized RuntimeError is a bug and raises
    immediately rather than being retried as "transient".
    """
    if not isinstance(exc, TRANSIENT_ERRORS):
        return False
    low = str(exc).lower()
    if any(s.lower() in low for s in TERMINAL_SUBSTRINGS):
        return False
    if isinstance(exc, OSError):
        return True
    return any(s.lower() in low for s in TRANSIENT_SUBSTRINGS)


def backoff_schedule(max_retries: int, base: float = 0.05, cap: float = 2.0,
                     jitter: float = 0.5, seed: int = 0) -> tuple[float, ...]:
    """The exact sleep (seconds) before each retry: capped exponential
    backoff with DETERMINISTIC jitter.

    Attempt a sleeps ``min(cap, base * 2^a) * (1 + jitter * u_a)`` with
    ``u_a`` in [-1, 1) hashed from ``(seed, a)`` — same seed, same schedule,
    on every process and platform (pinned by tests/test_resilience.py).
    Jitter decorrelates a fleet of workers retrying the same flaky endpoint
    without sacrificing reproducibility; ``jitter=0`` is the pure
    exponential."""
    out = []
    for a in range(max_retries):
        delay = min(cap, base * (2.0 ** a))
        if jitter:
            u = 2.0 * noise_lib.unit_hash(seed, a) - 1.0
            delay *= 1.0 + jitter * u
        out.append(delay)
    return tuple(out)


def resilient_step(step_fn: Callable, max_retries: int = 2,
                   on_retry: Callable[[int, Exception], None] | None = None,
                   *, base_delay: float = 0.05, max_delay: float = 2.0,
                   jitter: float = 0.5, seed: int = 0,
                   sleep: Callable[[float], None] = time.sleep):
    """Wrap a compiled step function with bounded retry of TRANSIENT
    failures (`is_transient`); terminal errors propagate immediately.

    Sleeps between attempts follow `backoff_schedule(max_retries,
    base_delay, max_delay, jitter, seed)` — capped exponential with
    deterministic jitter, replacing the old linear 0.5s*(attempt+1) ramp
    (which synchronized retry storms and burned half a second on the first
    flake). ``sleep`` is injectable so tests pin the schedule without
    waiting it out."""
    delays = backoff_schedule(max_retries, base_delay, max_delay, jitter, seed)

    def wrapped(*args, **kwargs):
        for attempt in range(max_retries + 1):
            try:
                return step_fn(*args, **kwargs)
            except TRANSIENT_ERRORS as e:
                if not is_transient(e) or attempt == max_retries:
                    raise
                if on_retry:
                    on_retry(attempt, e)
                sleep(delays[attempt])
        raise AssertionError("unreachable")

    return wrapped


class StragglerMonitor:
    """EWMA step-time tracker with a slow-step callback.

    The EWMA baseline is seeded from the MEDIAN of the first ``warmup``
    samples, not the first sample alone: a slow first step would both
    escape detection (nothing to compare against) and poison the baseline
    so steps 2..warmup could never be flagged. Samples buffer until the
    warmup window fills; flagging starts on the first post-seed sample.

    Windows the caller KNOWS are legitimately slow — a hot-reprogram /
    recalibration chunk in the serve loop — are recorded with
    ``exempt=True``: they are never flagged (recovery must not trip the
    straggler callback) and never enter the EWMA or the warmup buffer (a
    recal chunk would inflate the baseline and mask real stragglers
    afterwards). Exempted samples are kept in ``self.exempted``."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 3, on_straggler=None):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = max(warmup, 1)
        self.on_straggler = on_straggler
        self.ewma = None
        self.count = 0
        self._warmup_buf: list[float] = []
        self.flagged: list[tuple[int, float, float]] = []
        self.exempted: list[tuple[int, float]] = []

    def record(self, step: int, dt: float, exempt: bool = False) -> bool:
        """Record one step time; returns True if flagged as straggler."""
        self.count += 1
        if exempt:
            self.exempted.append((step, dt))
            return False
        if self.ewma is None:
            self._warmup_buf.append(dt)
            if len(self._warmup_buf) < self.warmup:
                return False
            self.ewma = statistics.median(self._warmup_buf)
            self._warmup_buf.clear()
            return False
        is_slow = dt > self.threshold * self.ewma
        if is_slow:
            self.flagged.append((step, dt, self.ewma))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma)
        else:
            # stragglers do not poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_slow


class Heartbeat:
    """Progress file for external liveness/restart supervision."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, **info):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(), **info}, f)
        os.replace(tmp, self.path)

    def read(self) -> dict | None:
        try:
            with open(self.path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None


def elastic_mesh_shapes(n_devices: int, model_parallel: int):
    """Valid (data, model) meshes for whatever device count survives —
    the re-mesh table the supervisor consults when restarting smaller."""
    shapes = []
    mp = model_parallel
    while mp >= 1:
        if n_devices % mp == 0:
            shapes.append((n_devices // mp, mp))
        mp //= 2
    return shapes
