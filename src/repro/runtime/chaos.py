"""Deterministic chaos harness for the serving engine.

Fault injection that is REPRODUCIBLE: events fire at fixed chunk indices
(the engine's dispatch counter, not wall time), and every corruption is a
pure function of the event — same spec, same trace, same failure, every
run. That determinism is what lets the recovery tests demand bit-equality:
a chaos run that detects, drains, remaps and hot-reprograms before the
next chunk dispatches must produce token-for-token the same output as an
unfaulted run.

Two fault kinds, both applied at a chunk boundary by
`ServeEngine._resilience_tick`:

  * ``kill``     — a core (context) dies outright: every matrix on it reads
    as a dead crossbar (output gain 0), and the core is marked dead so the
    health monitor MUST drain it onto peers (`AimcProgram.remap_context`)
    and reprogram — recovery on the same core is not an option.
  * ``corrupt``  — the core's tiles lose a fraction of their conductance
    (gain 1-magnitude): detectable by the probe when the magnitude clears
    the health threshold, repaired in place (no remap — the tiles are
    reprogrammable).

CLI form (``launch.serve --chaos``): comma-separated events
``kill:CORE@CHUNK`` / ``corrupt:CORE@CHUNK[:MAGNITUDE]``, e.g.
``--chaos kill:1@4`` or ``--chaos corrupt:0@2:0.5,kill:1@6``.
"""

from __future__ import annotations

import dataclasses

from repro.core.aimc import AimcLinearState
from repro.core.program import AimcProgram

KILL = "kill"
CORRUPT = "corrupt"


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault, scheduled on the engine's chunk-dispatch index."""

    at_chunk: int
    kind: str                 # KILL | CORRUPT
    core: int
    magnitude: float = 1.0    # conductance fraction lost (1.0 = dead)

    def __post_init__(self):
        if self.kind not in (KILL, CORRUPT):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if not 0.0 < self.magnitude <= 1.0:
            raise ValueError(f"magnitude must be in (0, 1], "
                             f"got {self.magnitude}")

    def describe(self) -> str:
        if self.kind == KILL:
            return f"kill core {self.core} @ chunk {self.at_chunk}"
        return (f"corrupt core {self.core} @ chunk {self.at_chunk} "
                f"(magnitude {self.magnitude:g})")


class FaultInjector:
    """Fires scheduled `FaultEvent`s as the engine's chunk counter passes
    them. One-shot per event; `fired` keeps the audit trail the serve
    report exposes."""

    def __init__(self, events):
        self.events = tuple(sorted(events, key=lambda e: e.at_chunk))
        self.fired: list[FaultEvent] = []
        self._idx = 0

    def due(self, chunk_idx: int) -> list[FaultEvent]:
        out = []
        while (self._idx < len(self.events)
               and self.events[self._idx].at_chunk <= chunk_idx):
            out.append(self.events[self._idx])
            self._idx += 1
        self.fired.extend(out)
        return out

    @property
    def exhausted(self) -> bool:
        return self._idx >= len(self.events)

    def __len__(self) -> int:
        return len(self.events)


def corrupt_entries(program: AimcProgram, core: int,
                    magnitude: float) -> dict[str, AimcLinearState]:
    """Degraded views of every matrix on ``core``: conductance scaled by
    ``1 - magnitude`` (0 gain = dead crossbar). Deterministic — the
    corruption is the event, not a noise draw — and structure-preserving,
    so it installs via `install_updates` without recompiling."""
    gain = 1.0 - magnitude
    return {n: st.with_gain(gain)
            for n, st, c in zip(program.names, program.states,
                                program.contexts) if c == core}


def parse_chaos(spec: str) -> FaultInjector:
    """``kill:CORE@CHUNK`` / ``corrupt:CORE@CHUNK[:MAG]``, comma-joined."""
    events = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            kind, rest = part.split(":", 1)
            if kind == CORRUPT and rest.count(":") == 1:
                rest, mag = rest.rsplit(":", 1)
                magnitude = float(mag)
            else:
                magnitude = 1.0
            core, chunk = rest.split("@")
            events.append(FaultEvent(at_chunk=int(chunk), kind=kind,
                                     core=int(core), magnitude=magnitude))
        except (ValueError, TypeError) as e:
            raise ValueError(
                f"bad --chaos event {part!r} (want kill:CORE@CHUNK or "
                f"corrupt:CORE@CHUNK[:MAG]): {e}") from None
    if not events:
        raise ValueError(f"--chaos spec {spec!r} contains no events")
    return FaultInjector(events)
