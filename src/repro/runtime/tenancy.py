"""Per-tenant policy for the multi-tenant model server (`runtime.server`).

ALPINE's premise is a FLEXIBLE accelerator pool — AIMC tiles tightly
integrated with general-purpose cores serve whatever mix of models the
host schedules onto them, not one hard-coded dataflow. Once several models
are co-programmed on one crossbar budget (`core.program.TilePool`), the
interesting system is the TENANT layer: who may use which model, in what
order, with what share of the decode slots, against what latency target.
This module holds that policy, fully host-side:

  * `TenantPolicy`   — one tenant's contract: the model id its requests
    route to, a fair-share ``weight`` for decode slots, a per-tenant
    admission order (fifo / sjf), optional SLO targets (p99 TTFT and p99
    per-output-token latency).
  * `pick_tenant`    — the quota scheduler's single decision: among tenants
    with a ready request for a model with a free slot, admit the one using
    the smallest fraction of its entitlement (weighted deficit, stable
    tie-break). Work-conserving: a lone candidate may borrow beyond its
    share, but whenever a below-share tenant is waiting it goes first — so
    under saturation every tenant's slot share converges to
    ``weight_i / sum(weights)`` and nobody starves.
  * `fair_shares`    — the per-model slot entitlement those picks converge
    to (the denominator of the fairness checks).
  * `TenantStats` / `tenant_stats` — per-tenant SLO accounting from the
    engine's `RequestRecord`s: p50/p99 TTFT, completion latency,
    per-output-token latency (TPOT), tok/s, SLO verdicts.
  * `jains_index`    — the quota-fairness metric the benchmark reports
    (1.0 = perfectly fair, 1/n = one tenant took everything).
  * `tenant_ledgers` / `reconcile_tenants` — per-tenant CM_* books riding
    the per-request ledgers; summed across a model's tenants they must
    close EXACTLY against ``program.mvm_counts()`` (the multi-tenant twin
    of `batcher.reconcile`).
  * `mixed_poisson_trace` — interleaved multi-tenant synthetic load: one
    Poisson arrival process, each arrival assigned a tenant
    weight-proportionally, prompts drawn from that tenant's model vocab.

Invariants: all picks and traces are deterministic (stable w.r.t. tenant
name / rid) so multi-tenant runs replay; ledger reconciliation is exact,
never approximate.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Mapping, Sequence

from repro.runtime.batcher import Request, RequestRecord, percentile

ADMISSION_POLICIES = ("fifo", "sjf")


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """One tenant's serving contract (hashable, declarative).

    ``weight`` is the fair-share weight for decode slots on ``model``
    (entitlement = weight / sum of co-tenant weights); ``admission`` orders
    the tenant's OWN queue; the SLO targets are report-time verdicts, not
    enforcement (the quota is the enforcement lever). ``max_pages`` caps
    the tenant's NEWLY-allocated KV pages on a paged engine (shared prefix
    pages are unbilled — `runtime.engine.pages_needed`); None = unlimited,
    and it is simply ignored on a dense engine."""
    name: str
    model: str
    weight: float = 1.0
    admission: str = "fifo"
    slo_ttft_s: float | None = None       # p99 time-to-first-token target
    slo_tpot_s: float | None = None       # p99 per-output-token target
    max_pages: int | None = None          # paged-engine KV page quota

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if not self.model:
            raise ValueError(f"tenant {self.name!r}: model must be non-empty")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.max_pages is not None and self.max_pages <= 0:
            raise ValueError(f"tenant {self.name!r}: max_pages must be > 0 "
                             f"(None = unlimited)")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"tenant {self.name!r}: unknown admission "
                             f"policy {self.admission!r} "
                             f"(known: {ADMISSION_POLICIES})")


@dataclasses.dataclass(frozen=True)
class TenantRequest:
    """A request tagged with the tenant that submitted it."""
    tenant: str
    request: Request


# ---------------------------------------------------------------------------
# quota scheduling
# ---------------------------------------------------------------------------

def fair_shares(policies: Sequence[TenantPolicy], model: str,
                n_slots: int) -> dict[str, float]:
    """tenant -> entitled decode slots of ``model`` (weighted share)."""
    tenants = [p for p in policies if p.model == model]
    wsum = sum(p.weight for p in tenants)
    return {p.name: n_slots * p.weight / wsum for p in tenants}


def pick_tenant(candidates: Sequence[str], in_flight: Mapping[str, int],
                policies: Mapping[str, TenantPolicy]) -> str:
    """The quota scheduler's admission pick: the candidate tenant holding
    the smallest ``in_flight / weight`` ratio goes first (weighted deficit;
    name-ordered tie-break for determinism). Candidates are tenants with a
    ready request for a model that has a free slot — the caller's job."""
    if not candidates:
        raise ValueError("pick_tenant needs at least one candidate")
    return min(candidates,
               key=lambda t: (in_flight.get(t, 0) / policies[t].weight, t))


def jains_index(xs: Sequence[float]) -> float:
    """Jain's fairness index over per-tenant allocations: 1.0 when all
    equal, 1/n when one tenant took everything. Empty/zero input -> 0.0."""
    xs = list(xs)
    if not xs or all(x == 0 for x in xs):
        return 0.0
    return sum(xs) ** 2 / (len(xs) * sum(x * x for x in xs))


# ---------------------------------------------------------------------------
# per-tenant SLO accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TenantStats:
    """One tenant's view of a serve run (built by `tenant_stats`)."""
    name: str
    model: str
    n_requests: int
    generated_tokens: int
    vectors: int                           # useful token vectors (CM_* unit)
    tok_s: float                           # generated tokens / makespan
    p50_ttft_s: float
    p99_ttft_s: float
    p50_latency_s: float
    p99_latency_s: float
    p50_tpot_s: float                      # per-output-token decode latency
    p99_tpot_s: float
    slo_ttft_ok: bool | None = None        # None: no target declared
    slo_tpot_ok: bool | None = None

    def row(self) -> str:
        def ms(x):
            return f"{x * 1e3:.0f}" if x == x else "-"      # NaN -> "-"

        slo = ""
        if self.slo_ttft_ok is not None or self.slo_tpot_ok is not None:
            verdict = {True: "ok", False: "VIOLATED", None: "-"}
            slo = (f"  slo[ttft={verdict[self.slo_ttft_ok]} "
                   f"tpot={verdict[self.slo_tpot_ok]}]")
        return (f"{self.name}@{self.model}: {self.n_requests} reqs, "
                f"{self.generated_tokens} toks ({self.tok_s:.1f} tok/s); "
                f"ttft p50/p99 {ms(self.p50_ttft_s)}/{ms(self.p99_ttft_s)}ms"
                f"  tpot p50/p99 {ms(self.p50_tpot_s)}/"
                f"{ms(self.p99_tpot_s)}ms{slo}")


def tenant_stats(policy: TenantPolicy,
                 records: Mapping[int, RequestRecord],
                 makespan_s: float) -> TenantStats:
    """Build one tenant's stats from ITS records (caller pre-filters by
    tenant — `runtime.server.ServerReport.tenant_records`)."""
    recs = list(records.values())
    ttfts = [r.ttft for r in recs]
    lats = [r.latency for r in recs]
    # TPOT only exists for requests that decoded at least one token beyond
    # the prefill's first; prefill-only requests have no decode latency
    tpots = [(r.latency - r.ttft) / r.decode_vectors
             for r in recs if r.decode_vectors > 0]
    toks = sum(len(r.tokens) for r in recs)
    p99_ttft = percentile(ttfts, 99)
    p99_tpot = percentile(tpots, 99)
    return TenantStats(
        name=policy.name, model=policy.model,
        n_requests=len(recs),
        generated_tokens=toks,
        vectors=sum(r.vectors for r in recs),
        tok_s=toks / max(makespan_s, 1e-9),
        p50_ttft_s=percentile(ttfts, 50), p99_ttft_s=p99_ttft,
        p50_latency_s=percentile(lats, 50), p99_latency_s=percentile(lats, 99),
        p50_tpot_s=percentile(tpots, 50), p99_tpot_s=p99_tpot,
        slo_ttft_ok=(None if policy.slo_ttft_s is None or not recs
                     else bool(p99_ttft <= policy.slo_ttft_s)),
        slo_tpot_ok=(None if policy.slo_tpot_s is None or not tpots
                     else bool(p99_tpot <= policy.slo_tpot_s)),
    )


# ---------------------------------------------------------------------------
# per-tenant CM_* ledgers (against core.program.AimcProgram)
# ---------------------------------------------------------------------------

def tenant_ledgers(program, records: Mapping[int, RequestRecord],
                   tenant_of: Mapping[int, str]) -> dict:
    """tenant -> CM_* counts for that tenant's useful vectors through ONE
    model's program (records are that model's; ``tenant_of`` maps rid ->
    tenant). Flows through per-request ledgers, not a single scale, so the
    sum genuinely re-derives the total."""
    per_vec = program.mvm_counts()
    out: dict[str, object] = {}
    for rid, rec in records.items():
        t = tenant_of[rid]
        cm = per_vec.scaled(rec.vectors)
        out[t] = cm if t not in out else out[t] + cm
    return out


def reconcile_tenants(program, records: Mapping[int, RequestRecord],
                      tenant_of: Mapping[int, str],
                      observed_vectors: int | None = None):
    """(sum of per-tenant ledgers, the program's static total) for one
    model. The multi-tenant twin of `batcher.reconcile`: the left side
    flows through per-request -> per-tenant bookkeeping, the right scales
    ``program.mvm_counts()`` by the device loop's independent vector count.
    Exact equality or it's a bookkeeping bug."""
    if observed_vectors is None:
        observed_vectors = sum(rec.vectors for rec in records.values())
    total = program.mvm_counts().scaled(0)
    for cm in tenant_ledgers(program, records, tenant_of).values():
        total = total + cm
    return total, program.mvm_counts().scaled(observed_vectors)


# ---------------------------------------------------------------------------
# mixed-traffic synthetic load
# ---------------------------------------------------------------------------

def mixed_poisson_trace(policies: Sequence[TenantPolicy], n: int, rate: float,
                        *, vocab_of: Mapping[str, int], seed: int = 0,
                        prompt_len: tuple[int, int] = (4, 12),
                        max_new: tuple[int, int] = (2, 12),
                        ) -> list[TenantRequest]:
    """One interleaved Poisson arrival stream across every tenant.

    Exponential inter-arrivals at ``rate`` req/s; each arrival is assigned
    a tenant weight-proportionally, with prompt tokens drawn from THAT
    tenant's model vocab (``vocab_of``: model id -> vocab size). Rids are
    globally unique and arrival-ordered, so multi-tenant runs replay."""
    if rate <= 0:
        raise ValueError("rate must be > 0")
    if not policies:
        raise ValueError("need at least one tenant policy")
    missing = {p.model for p in policies} - set(vocab_of)
    if missing:
        raise ValueError(f"vocab_of missing models: {sorted(missing)}")
    rng = random.Random(seed)
    weights = [p.weight for p in policies]
    t = 0.0
    out = []
    for i in range(n):
        t += -math.log(1.0 - rng.random()) / rate
        pol = rng.choices(policies, weights=weights)[0]
        vocab = vocab_of[pol.model]
        p_len = rng.randint(*prompt_len)
        out.append(TenantRequest(
            tenant=pol.name,
            request=Request(
                rid=i,
                prompt=tuple(rng.randint(1, vocab - 1)
                             for _ in range(p_len)),
                max_new=rng.randint(*max_new),
                arrival=t)))
    return out
