"""Request admission, slot allocation and per-request accounting for the
continuous-batching engine (`runtime.engine`).

The serving regime is the paper's weights-stationary deployment (§IV-B,
Fig. 4): the crossbars are programmed once, then token vectors stream
through queue/process/dequeue forever. At that point the interesting system
is the REQUEST layer — ragged prompts arriving at random times, each wanting
its own number of new tokens — and this module holds its host-side state:

  * `Request`        — what a client submits (id, prompt, max_new, arrival).
  * `Batcher`        — the admission queue: requests ordered by an admission
    policy (fifo / sjf), popped when their arrival time has passed and a
    decode slot is free.
  * `SlotAllocator`  — the fixed-shape decode batch's free-list. Slots are
    the engine's unit of residency: a request owns one slot from prefill
    insertion to retirement (EOS / length), then the slot is refilled.
  * `RequestRecord`  — per-request ledger: token-vector counts (the CM_*
    accounting unit), TTFT and completion latency. `request_ledgers` /
    `reconcile` turn vector counts into CM_* instruction totals that sum
    EXACTLY to `program.mvm_counts().scaled(total_vectors)` — the engine's
    books against the `AimcProgram`'s static accounting.
  * trace builders   — `poisson_trace` (staggered synthetic load) and
    `synchronized_trace` (the legacy static-batch arrival pattern).
  * per-core views   — `request_core_ledgers` / `aggregate_core_ledgers`
    split each request's books across a `core.schedule.CoreSchedule`'s
    virtual cores; `reconcile_cores` closes the shard-aggregated sum
    against the schedule totals (sharded serving, DESIGN.md §11).

Invariants: `reconcile` and `reconcile_cores` compare two INDEPENDENT
countings (per-request records vs the device loop's observed vectors) and
must close EXACTLY — approximate agreement is a bookkeeping bug. All
admission orders are deterministic (stable w.r.t. rid) so traces replay.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request. ``arrival`` is in engine-clock seconds;
    ``max_new`` counts generated tokens INCLUDING the prefill's first one
    (``max_new=1`` retires at prefill, never occupying a decode slot)."""
    rid: int
    prompt: tuple[int, ...]
    max_new: int = 8
    arrival: float = 0.0

    def __post_init__(self):
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")
        if not self.prompt:
            raise ValueError(f"request {self.rid}: empty prompt")


@dataclasses.dataclass
class RequestRecord:
    """The engine's per-request books (filled in as the request moves
    through admitted -> prefilled -> decoding -> retired)."""
    request: Request
    t_admit: float = 0.0           # engine clock when popped from the queue
    t_first: float = 0.0           # first token emitted (prefill done)
    t_done: float = 0.0            # retirement
    tokens: list[int] = dataclasses.field(default_factory=list)
    prefill_vectors: int = 0       # useful prompt token vectors (== len)
    decode_vectors: int = 0        # decode steps this request rode in
    pad_vectors: int = 0           # prompt-padding lanes it wasted
    finish_reason: str = ""        # "length" | "eos" | "cap"

    @property
    def vectors(self) -> int:
        """Useful token vectors this request pushed through the program."""
        return self.prefill_vectors + self.decode_vectors

    @property
    def ttft(self) -> float:
        return self.t_first - self.request.arrival

    @property
    def latency(self) -> float:
        return self.t_done - self.request.arrival


class SlotAllocator:
    """Free-list over the fixed decode batch: slot i <-> batch row i."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> slot 0 first
        self.occupant: dict[int, int] = {}              # slot -> rid

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_busy(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self, rid: int) -> int:
        slot = self._free.pop()
        self.occupant[slot] = rid
        return slot

    def release(self, slot: int) -> int:
        rid = self.occupant.pop(slot)
        self._free.append(slot)
        return rid


class Batcher:
    """Admission queue: holds not-yet-admitted requests, releases them when
    their arrival time has passed AND the caller has a free slot.

    ``policy``: "fifo" admits in arrival order; "sjf" (shortest job first,
    by ``max_new``) is the classic latency-percentile lever — both are
    stable w.r.t. rid so traces replay deterministically.
    """

    def __init__(self, requests: Sequence[Request], policy: str = "fifo"):
        if policy not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.policy = policy
        # plain list: every pop re-scans the READY subset anyway (readiness
        # depends on `now`, which a static heap order cannot encode). sjf
        # orders the ready set by decode budget (arrival only breaks ties) —
        # budget-first is what makes it shortest-job-first under staggered
        # arrivals; arrival-first would degenerate to fifo.
        self._pending = list(requests)

    def _prio(self, r: Request):
        return ((r.max_new, r.arrival, r.rid) if self.policy == "sjf"
                else (r.arrival, r.rid))

    def __len__(self) -> int:
        return len(self._pending)

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest still-queued request."""
        if not self._pending:
            return None
        return min(r.arrival for r in self._pending)

    def has_ready(self, now: float) -> bool:
        """Whether any queued request's arrival has passed (non-popping —
        the multi-tenant server asks every tenant queue before picking)."""
        return any(r.arrival <= now for r in self._pending)

    def peek_ready(self, now: float) -> Request | None:
        """The request `pop_ready` WOULD return, without removing it.

        Paged admission asks the page allocator whether the next request
        fits BEFORE committing to pop it (runtime.engine.can_admit) — a
        popped-but-unadmittable request would either be dropped or jump
        the deterministic admission order."""
        ready = [r for r in self._pending if r.arrival <= now]
        return min(ready, key=self._prio) if ready else None

    def pop_ready(self, now: float) -> Request | None:
        """Pop the highest-priority request whose arrival has passed."""
        best = self.peek_ready(now)
        if best is not None:
            self._pending.remove(best)
        return best


# ---------------------------------------------------------------------------
# CM_* ledger reconciliation (against core.program.AimcProgram)
# ---------------------------------------------------------------------------

def request_ledgers(program, records: dict[int, RequestRecord]) -> dict:
    """rid -> CM_* counts for that request's useful token vectors."""
    per_vec = program.mvm_counts()
    return {rid: per_vec.scaled(rec.vectors) for rid, rec in records.items()}

def reconcile(program, records: dict[int, RequestRecord],
              observed_vectors: int | None = None):
    """(sum of per-request ledgers, the program's static total).

    ``observed_vectors`` should be the engine's INDEPENDENT count from the
    device loop (`ServeReport.observed_vectors`: prompt lengths at each
    prefill call + busy lanes at each decode call). The left side comes
    from per-request `RequestRecord` bookkeeping; with an observed total
    the two countings can genuinely disagree — a double- or under-counted
    vector on either path breaks the equality. Without it the check
    degrades to the linearity tautology (both sides scale the same record
    counts)."""
    if observed_vectors is None:
        observed_vectors = sum(rec.vectors for rec in records.values())
    ledger_sum = program.mvm_counts().scaled(0)
    for cm in request_ledgers(program, records).values():
        ledger_sum = ledger_sum + cm
    static = program.mvm_counts().scaled(observed_vectors)
    return ledger_sum, static


# ---------------------------------------------------------------------------
# per-core ledger aggregation (against core.schedule.CoreSchedule)
# ---------------------------------------------------------------------------

def request_core_ledgers(schedule, records: dict[int, RequestRecord]) -> dict:
    """rid -> {core -> CM_* counts} under a multi-core schedule.

    Each request's useful vectors ride through EVERY core the schedule
    places shards on, so its ledger splits per core by the schedule's
    per-vector `CoreLedger`s (column-split cores each queue the full
    vector; dequeue partitions exactly — core.schedule semantics)."""
    per_core = {led.core: led.cm for led in schedule.ledgers()}
    return {rid: {c: cm.scaled(rec.vectors) for c, cm in per_core.items()}
            for rid, rec in records.items()}


def aggregate_core_ledgers(schedule,
                           records: dict[int, RequestRecord]) -> dict:
    """core -> CM_* counts summed over all requests (the shard-aggregated
    view of `request_ledgers`)."""
    agg: dict[int, object] = {}
    for cores in request_core_ledgers(schedule, records).values():
        for c, cm in cores.items():
            agg[c] = cm if c not in agg else agg[c] + cm
    return agg


def reconcile_cores(schedule, records: dict[int, RequestRecord],
                    observed_vectors: int | None = None):
    """(sum over cores of the aggregated per-core ledgers, the schedule's
    static per-core totals scaled by ``observed_vectors``).

    The multi-core twin of `reconcile`: the left side flows through
    per-request, per-core bookkeeping; the right is
    ``schedule.ledger_totals().scaled(observed)``. For layer-per-core
    schedules (no column splits — `CoreSchedule.from_program`) the right
    side ALSO equals ``program.mvm_counts().scaled(observed)``, so the
    sharded engine's books close against the single-core program exactly."""
    if observed_vectors is None:
        observed_vectors = sum(rec.vectors for rec in records.values())
    agg = aggregate_core_ledgers(schedule, records)
    total = None
    for cm in agg.values():
        total = cm if total is None else total + cm
    if total is None:
        total = schedule.ledger_totals().scaled(0)
    return total, schedule.ledger_totals().scaled(observed_vectors)


# ---------------------------------------------------------------------------
# synthetic arrival traces
# ---------------------------------------------------------------------------

def poisson_trace(n: int, rate: float, seed: int = 0,
                  prompt_len: tuple[int, int] = (4, 16),
                  max_new: tuple[int, int] = (2, 12),
                  vocab: int = 128) -> list[Request]:
    """Staggered synthetic load: exponential inter-arrivals at ``rate``
    requests/second, ragged prompt lengths and per-request ``max_new``."""
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += -math.log(1.0 - rng.random()) / rate
        p_len = rng.randint(*prompt_len)
        out.append(Request(
            rid=i,
            prompt=tuple(rng.randint(1, vocab - 1) for _ in range(p_len)),
            max_new=rng.randint(*max_new),
            arrival=t))
    return out


def synchronized_trace(n: int, prompt_len: int = 8, max_new: int = 8,
                       seed: int = 0, vocab: int = 128) -> list[Request]:
    """The legacy static-batch arrival pattern: everyone at t=0, one prompt
    length, one decode budget — the shape the bit-equality test serves both
    ways."""
    rng = random.Random(seed)
    return [Request(
        rid=i,
        prompt=tuple(rng.randint(1, vocab - 1) for _ in range(prompt_len)),
        max_new=max_new, arrival=0.0) for i in range(n)]


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (q in [0, 100]) — no numpy needed."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    pos = (len(s) - 1) * q / 100.0
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))
