"""Fixed-size page pool for the serving engine's paged KV/state cache.

The dense slot cache pays ``n_slots x max_seq`` up front whether or not any
request uses it, and identical system prompts re-prefill from scratch on
every admission. This module owns the digital-side fix (DESIGN.md §15):

  * `PageAllocator` — a pool of ``n_pages`` physical pages (page 0 is a
    reserved SCRATCH page that is never allocated: traced writes for
    inactive/frozen lanes route there, mirroring how the dense engine's
    `mask_batch_select` discards frozen-lane writes). Every other page is
    at any instant EXACTLY one of: on the free list, or held with a
    positive refcount under one producing owner — the same
    every-tile-accounted discipline `core.program.TilePool` applies to
    crossbar tiles, here applied to cache pages (`verify`).

  * `PrefixCache` — content-addressed index over FULL prompt pages.
    Page ``j`` of a prompt is keyed by a CHAINED hash (the hash of pages
    ``0..j``'s tokens), so one key uniquely identifies an entire prefix:
    transformer KV reuse asks for the longest consecutive run of present
    keys (it needs every physical page), recurrent snapshot reuse asks for
    the deepest present key alone (one snapshot page holds the whole
    state). The cache holds one reference per entry; an entry whose page
    has no other sharer (refcount 1) is evictable, LRU-first.

Billing contract (enforced by the engine + tests/test_paged_engine.py):
the producer of a page pays its prefill vectors once; a prefix hit pays
only its continuation span. Hits are never double-billed and never free.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

SCRATCH = 0


def page_keys(prompt, page_size: int) -> list[bytes]:
    """Chained content hashes of a prompt's FULL pages.

    ``keys[j]`` = sha256 over (keys[j-1] || tokens of page j), so a single
    key commits to the entire token prefix ``[0, (j+1)*page_size)`` — two
    prompts share key ``j`` iff they agree on every token up to that
    boundary. Only full pages are hashable: a partial trailing page is
    never shared (its rows are still being written)."""
    keys = []
    h = b""
    for j in range(len(prompt) // page_size):
        page = np.asarray(prompt[j * page_size:(j + 1) * page_size],
                          np.int32)
        h = hashlib.sha256(h + page.tobytes()).digest()
        keys.append(h)
    return keys


class PageAllocator:
    """Exact-accounting allocator over ``n_pages`` physical pages.

    Page `SCRATCH` (0) is reserved and never handed out. ``alloc`` returns
    pages at refcount 1 under the given owner; ``retain``/``release`` move
    the refcount; a release to zero returns the page to the free list.
    `ledger()`/`verify()` prove the partition is exact at any time."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is scratch), "
                             f"got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list, low pages first on init (pop from the end)
        self._free = list(range(n_pages - 1, 0, -1))
        self._ref: dict[int, int] = {}     # pid -> refcount (>= 1)
        self._owner: dict[int, object] = {}  # pid -> producing owner tag

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_held(self) -> int:
        return len(self._ref)

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def owner(self, pid: int):
        return self._owner.get(pid)

    def alloc(self, n: int, owner) -> list[int] | None:
        """``n`` pages at refcount 1 under ``owner``, or None (shortage —
        the caller decides whether to evict and retry or defer admission).
        All-or-nothing: a partial grab is never left behind."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pids = [self._free.pop() for _ in range(n)]
        for pid in pids:
            self._ref[pid] = 1
            self._owner[pid] = owner
        return pids

    def retain(self, pid: int):
        if pid == SCRATCH or pid not in self._ref:
            raise ValueError(f"retain of unheld page {pid}")
        self._ref[pid] += 1

    def release(self, pid: int) -> bool:
        """Drop one reference; True when this freed the page."""
        if pid == SCRATCH or pid not in self._ref:
            raise ValueError(f"release of unheld page {pid} (double free?)")
        self._ref[pid] -= 1
        if self._ref[pid]:
            return False
        del self._ref[pid]
        del self._owner[pid]
        self._free.append(pid)
        return True

    def ledger(self) -> dict:
        """Point-in-time books: every page attributed exactly once."""
        by_owner: dict = {}
        for pid, owner in self._owner.items():
            by_owner.setdefault(owner, []).append(pid)
        return {"total": self.n_pages, "scratch": 1,
                "free": len(self._free), "held": len(self._ref),
                "refs": sum(self._ref.values()),
                "by_owner": {k: sorted(v) for k, v in by_owner.items()}}

    def verify(self) -> bool:
        """The exact-partition invariant: {scratch} ∪ free ∪ held is a
        disjoint cover of [0, n_pages), every held page has refcount >= 1
        and an owner, and no free/scratch page carries books."""
        free = set(self._free)
        held = set(self._ref)
        if SCRATCH in free or SCRATCH in held:
            return False
        if free & held:
            return False
        if len(free) != len(self._free):     # duplicate on the free list
            return False
        if free | held | {SCRATCH} != set(range(self.n_pages)):
            return False
        if any(r < 1 for r in self._ref.values()):
            return False
        return set(self._owner) == held


@dataclasses.dataclass
class _Entry:
    pid: int
    tick: int   # LRU clock at last touch


class PrefixCache:
    """Content hash -> resident page, refcounted through a `PageAllocator`.

    The cache itself holds ONE reference per entry (taken at `put`, via
    `retain` or by adopting the caller's reference), so a registered page
    survives its producer's retirement. An entry is evictable exactly when
    the cache is the last sharer (allocator refcount 1)."""

    def __init__(self, allocator: PageAllocator):
        self.alloc = allocator
        self._entries: dict[bytes, _Entry] = {}
        self._tick = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: bytes) -> bool:
        return key in self._entries

    def lookup(self, keys: list[bytes], peek: bool = False) -> list:
        """Per-index resident pids (None where absent). Touches LRU and
        books hit/miss stats unless ``peek`` (admission feasibility checks
        must not perturb eviction order). The caller derives its own match
        shape: transformer KV needs the longest consecutive run from 0,
        recurrent snapshots need only the deepest present index."""
        out = []
        for key in keys:
            ent = self._entries.get(key)
            if ent is None:
                out.append(None)
                if not peek:
                    self.misses += 1
                continue
            out.append(ent.pid)
            if not peek:
                self._tick += 1
                ent.tick = self._tick
                self.hits += 1
        return out

    def put(self, key: bytes, pid: int, adopt: bool = False) -> bool:
        """Register ``key`` -> ``pid``. With ``adopt`` the cache takes over
        the caller's existing reference (recurrent snapshot pages exist
        only for the cache); otherwise it retains its own (+1 — transformer
        KV pages stay co-held by the producing request until it retires).
        A key that is already resident is left as-is (returns False): the
        first producer wins, the duplicate page stays request-owned."""
        if key in self._entries:
            return False
        if not adopt:
            self.alloc.retain(pid)
        self._tick += 1
        self._entries[key] = _Entry(pid=pid, tick=self._tick)
        return True

    def evictable(self, protect=()) -> int:
        """How many entries could be evicted right now (cache is the only
        sharer), excluding pids in ``protect`` — an admission about to
        retain its hit pages must not count them as reclaimable."""
        protect = set(protect)
        return sum(1 for e in self._entries.values()
                   if self.alloc.refcount(e.pid) == 1
                   and e.pid not in protect)

    def evict(self, n_pages: int, protect=()) -> int:
        """Free up to ``n_pages`` pages by dropping sole-sharer entries,
        least-recently-used first. Returns the number actually freed."""
        protect = set(protect)
        victims = sorted(
            (e.tick, key) for key, e in self._entries.items()
            if self.alloc.refcount(e.pid) == 1 and e.pid not in protect)
        freed = 0
        for _, key in victims:
            if freed >= n_pages:
                break
            ent = self._entries.pop(key)
            self.alloc.release(ent.pid)
            self.evictions += 1
            freed += 1
        return freed

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions}
