"""ServeEngine: request-level continuous batching over a programmed AIMC
model (the `runtime/` serving subsystem).

ALPINE's deployment model is weights-stationary inference (§IV-B, Fig. 4):
CM_INITIALIZE happens once, outside the region of interest, and serving is a
forever-loop of queue/process/dequeue token vectors. This module is that
loop made real at the REQUEST level, modeled on the saxml server split
(servable model owns jitted device functions; a host-side driver owns slots
and admission):

  request lifecycle   queued -> admitted -> prefilled -> [slot i] decoding
                      -> retired (EOS / length / max_new) -> slot refilled

  slot state machine  a fixed batch of ``n_slots`` decode lanes. Each lane
                      is FREE or holds one request. Prefill runs per request
                      at one padded shape [1, prompt_pad] (ragged prompts
                      via ``valid_len``), the resulting KV/recurrent state
                      is inserted into the lane at the request's own length,
                      and the dense decode batch advances every lane at
                      once — retired/free lanes compute but are bit-frozen
                      (`mask_batch_select`), so they never corrupt state or
                      accounting.

  shape stability     exactly three device closures exist — prefill
                      [1, prompt_pad], insert (slot index is a traced
                      scalar), decode [n_slots, 1] — each compiled ONCE at
                      warmup. No shape depends on arrival order, prompt
                      length, or live-request count, so a ragged Poisson
                      trace runs the whole session on the warmup
                      executables (asserted by `compile_counts`).

The decode loop is wrapped in `fault_tolerance.resilient_step` (transient
device errors retry; terminal ones — e.g. RESOURCE_EXHAUSTED — raise) and
timed by a `fault_tolerance.StragglerMonitor`.

CM_* accounting: every USEFUL token vector (prompt tokens at prefill, one
vector per decode step a request rides in) is booked to its request's
`RequestRecord`; padding lanes (prompt pad, idle slots) are tracked
separately as waste. `batcher.reconcile` proves the per-request ledgers sum
exactly to ``program.mvm_counts().scaled(total_vectors)``.

`launch.steps.make_prefill_step` / `make_serve_step` build their device
functions from this module's closure builders (`static_prefill_closure`,
`static_decode_closure`), so the static shape cells and the engine serve
through one implementation of the model-facing math.

Public surface
  * `ServeEngine`         — single-device continuous batching: `warmup()`,
    `serve(requests) -> ServeReport`, `compile_counts()`, `ledgers()` /
    `core_ledgers()` (CM_* books).
  * `EngineSession` + the session primitives `begin()` / `admit()` /
    `step()` / `cancel_active()` / `finish()` — the serving loop decomposed
    so an external driver (the multi-tenant `runtime.server.ModelServer`)
    can interleave several engines under ONE clock. `serve()` is exactly
    these primitives driven by a single `Batcher`.
  * `ShardedServeEngine`  — the same loop over a JAX mesh (DESIGN.md §11):
    slots over `data`, crossbar bit lines over `model`; adds
    `device_ledgers()`. Bit-equal to `ServeEngine` on the same trace.
  * `ServeReport`         — everything one serve run produced.
  * `static_generate`, `static_prefill_closure`, `static_decode_closure`
    — the legacy static-batch oracle and the shared model-facing math.

Invariants (pinned by tests/test_engine.py, tests/test_sharded_engine.py)
  * shape stability: after `warmup()` every closure's executable cache
    holds exactly one entry, for any trace, on any mesh;
  * synchronized arrivals are bit-equal to `static_generate`; the sharded
    engine is bit-equal to the single-device engine on ANY trace;
  * slot reuse never leaks state (retired lanes are bit-frozen);
  * per-request ledgers reconcile exactly with `program.mvm_counts()`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import Execution, mask_batch_select
from repro.runtime.batcher import (Batcher, Request, RequestRecord,
                                   SlotAllocator, percentile)
from repro.runtime.fault_tolerance import StragglerMonitor, resilient_step

RECURRENT_MODULES = ("xlstm", "rglru")


# ---------------------------------------------------------------------------
# closure builders — the model-facing math, shared with launch.steps
# ---------------------------------------------------------------------------

def static_prefill_closure(model, cfg, exe: Execution, *, family: str = "lm",
                           module: str = "transformer", max_seq: int,
                           cache_dtype) -> Callable:
    """(params, batch dict) -> (next_tok [B,1] int32, cache).

    The static-batch prefill math: one call covers audio (enc-dec), vlm,
    transformer and recurrent families. `launch.steps.make_prefill_step`
    jits exactly this; the engine's static A/B baseline reuses it."""
    if family == "audio":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["frames"],
                                          batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif family == "vlm":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          patch_embeds=batch["patch_embeds"],
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif module == "transformer":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    else:
        # recurrent families: forward-only lowering (the dry-run cells carry
        # no cache; slot-cache prefill is `model.prefill`, used by the
        # engine's per-request closure below)
        def prefill(params, batch):
            logits, _ = model.forward(params, batch["tokens"], cfg, exe)
            return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), ()
    return prefill


def static_decode_closure(model, cfg, exe: Execution) -> Callable:
    """(params, cache, tokens [B,1]) -> (next_tok [B,1] int32, cache) —
    the lockstep decode step `launch.steps.make_serve_step` jits."""
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens, cfg, exe)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_cache
    return serve_step


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    """Everything one `ServeEngine.serve` run produced."""
    records: dict[int, RequestRecord]
    n_steps: int = 0               # decode batch steps executed
    n_prefills: int = 0
    idle_vectors: int = 0          # frozen decode lanes (slot-idle waste)
    prefill_pad_vectors: int = 0   # prompt-padding lanes (prefill waste)
    # useful vectors counted FROM THE DEVICE LOOP (prompt lengths at the
    # prefill call + busy lanes at each decode call) — independent of the
    # per-request RequestRecord bookkeeping, so the two can actually
    # disagree if the engine double- or under-counts (reconcile's job)
    observed_vectors: int = 0
    wall_prefill_s: float = 0.0
    wall_decode_s: float = 0.0
    makespan_s: float = 0.0        # engine clock: last retirement - start
    retries: int = 0
    stragglers: list = dataclasses.field(default_factory=list)

    @property
    def useful_vectors(self) -> int:
        return sum(r.vectors for r in self.records.values())

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.records.values())

    def tokens(self, rid: int) -> list[int]:
        return self.records[rid].tokens

    def latency_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        lats = [r.latency for r in self.records.values()]
        ttfts = [r.ttft for r in self.records.values()]
        out = {}
        for q in qs:
            out[f"p{q}_latency_s"] = percentile(lats, q)
            out[f"p{q}_ttft_s"] = percentile(ttfts, q)
        return out

    def summary(self) -> str:
        gen = self.generated_tokens
        wall = self.wall_prefill_s + self.wall_decode_s
        pct = self.latency_percentiles()
        return (f"{len(self.records)} requests, {gen} tokens in "
                f"{self.makespan_s:.2f}s engine-time ({gen / max(wall, 1e-9):.1f}"
                f" tok/s compute; {self.n_prefills} prefills, {self.n_steps} "
                f"decode steps, {self.idle_vectors} idle lanes); "
                f"p50/p99 latency {pct['p50_latency_s']:.2f}/"
                f"{pct['p99_latency_s']:.2f}s")


@dataclasses.dataclass
class EngineSession:
    """Host-side state of one in-flight serving run.

    Owned by a `ServeEngine`, created by `ServeEngine.begin()`; every field
    the old monolithic `serve()` loop kept as a local lives here so an
    external driver (`runtime.server.ModelServer`) can interleave sessions
    of SEVERAL engines under one clock. Device buffers (``cache``,
    ``tok_buf``) are reassigned by `admit`/`step` (insert donates), so a
    session must only ever be driven by its own engine's primitives."""
    report: ServeReport
    slots: SlotAllocator
    slot_rec: dict[int, RequestRecord]    # slot -> live record
    cache: object
    tok_buf: object
    active: list[bool]
    retries0: int                          # lifetime counters at begin()
    flagged0: int


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching serving engine over one installed model.

    Owns: the (program-installed) parameter tree, the slot-shaped decode
    cache, and the three jitted closures. Drives: admission (`Batcher`),
    slot allocation, retirement, refill, per-request accounting.

    ``params`` should already carry installed `AimcLinearState`s when
    serving the programmed AIMC path (``program.install(params)``); pass
    the `AimcProgram` as ``program`` for CM_* ledger reconciliation.
    """

    def __init__(self, model, cfg, exe: Execution, params, *,
                 n_slots: int = 4, prompt_pad: int = 16, max_seq: int = 64,
                 cache_dtype=jnp.float32, family: str = "lm",
                 module: str = "transformer", program=None, schedule=None,
                 eos_id: int | None = None, pad_id: int = 0,
                 max_retries: int = 2, straggler_threshold: float = 3.0,
                 admission: str = "fifo"):
        if family == "audio":
            raise ValueError("ServeEngine serves decoder-only LMs; the "
                             "enc-dec audio family decodes via launch.steps")
        if prompt_pad > max_seq:
            raise ValueError(f"prompt_pad {prompt_pad} > max_seq {max_seq}")
        if family == "vlm" and prompt_pad < cfg.n_patches:
            raise ValueError(
                f"vlm prompts start with {cfg.n_patches} patch positions; "
                f"prompt_pad {prompt_pad} cannot hold them")
        self.model, self.cfg, self.exe, self.params = model, cfg, exe, params
        self.n_slots, self.prompt_pad, self.max_seq = n_slots, prompt_pad, max_seq
        self.cache_dtype = cache_dtype
        self.family, self.module = family, module
        self.program, self.schedule = program, schedule
        self.eos_id, self.pad_id = eos_id, pad_id
        self.admission = admission
        self.recurrent = module in RECURRENT_MODULES
        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self._retries = 0
        self._step_no = 0          # engine-lifetime decode step counter

        # per-leaf batch axes of the decode cache (probed, not hardcoded:
        # transformer KV stacks batch at axis 1, recurrent state trees too,
        # but "len" and any future leaf may differ — shape-diffing two
        # abstract init_cache calls finds the axis without model knowledge)
        self._axes = self._probe_batch_axes()
        self._build_closures(max_retries)

    def _build_closures(self, max_retries: int):
        """Compile the three device closures. `ShardedServeEngine` overrides
        this to pin every input/output to a mesh placement; the math
        (`_prefill_fn`/`_insert_fn`/`_decode_fn`) is shared verbatim."""
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0, 2))
        # the decode cache is NOT donated: the step runs under
        # resilient_step, and a retry after a transient failure must be able
        # to re-present the same input buffers (donation would have
        # invalidated them on the failed attempt)
        self._jit_decode = jax.jit(self._decode_fn)
        self._safe_decode = resilient_step(
            self._jit_decode, max_retries=max_retries,
            on_retry=lambda attempt, e: self._count_retry())

    # -- closures ------------------------------------------------------------
    def _probe_batch_axes(self):
        def shapes(b):
            return jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, b, self.max_seq, self.cache_dtype))

        def axis_of(s2, s3):
            for i, (a, b) in enumerate(zip(s2.shape, s3.shape)):
                if a != b:
                    return i
            raise ValueError(f"no batch axis found in cache leaf {s2}")

        return jax.tree.map(axis_of, shapes(2), shapes(3))

    def _prefill_fn(self, params, tokens, valid_len):
        """[1, prompt_pad] ragged prefill -> (first_tok [1,1], cache1)."""
        kw = {}
        if self.family == "vlm":
            # patch positions are a prompt prefix; the engine serves the
            # text path with zero patch embeddings unless a request-level
            # frontend supplies them (frontend-stub rule)
            kw["patch_embeds"] = jnp.zeros(
                (tokens.shape[0], self.cfg.n_patches, self.cfg.d_model),
                jnp.float32)
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.exe, max_seq=self.max_seq,
            cache_dtype=self.cache_dtype, valid_len=valid_len, **kw)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        return tok, cache

    def _insert_fn(self, cache, cache1, tok_buf, tok1, slot):
        """Write a prefilled request's state into decode lane ``slot``."""
        def put(big, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot, axis=ax)

        cache = jax.tree.map(put, cache, cache1, self._axes)
        tok_buf = jax.lax.dynamic_update_slice(tok_buf, tok1, (slot, 0))
        return cache, tok_buf

    def _decode_fn(self, params, cache, tokens, active):
        """One dense decode step; inactive lanes are bit-frozen."""
        if self.module == "transformer":
            logits, new_cache = self.model.decode_step(
                params, cache, tokens, self.cfg, self.exe, ragged=True)
        else:
            logits, new_cache = self.model.decode_step(
                params, cache, tokens, self.cfg, self.exe)
        new_cache = jax.tree.map(
            lambda n, o, ax: mask_batch_select(n, o, active, ax),
            new_cache, cache, self._axes)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        tok = jnp.where(active[:, None], tok, tokens)
        return tok, new_cache

    # -- warmup / compile accounting ----------------------------------------
    def _empty_cache(self):
        return self.model.init_cache(self.cfg, self.n_slots, self.max_seq,
                                     self.cache_dtype)

    def _empty_tok_buf(self):
        """The [n_slots, 1] next-token buffer. A hook so the sharded engine
        can commit it to its mesh placement — an uncommitted buffer would
        key the insert closure's jit cache differently from the committed
        buffers later steps feed back, costing a recompile."""
        return jnp.zeros((self.n_slots, 1), jnp.int32)

    def warmup(self):
        """Compile all three closures once, outside the serving clock."""
        tokens = jnp.zeros((1, self.prompt_pad), jnp.int32)
        vl = jnp.ones((1,), jnp.int32)
        tok1, cache1 = self._jit_prefill(self.params, tokens, vl)
        cache = self._empty_cache()
        tok_buf = self._empty_tok_buf()
        cache, tok_buf = self._jit_insert(cache, cache1, tok_buf, tok1,
                                          jnp.int32(0))
        active = jnp.zeros((self.n_slots,), bool)
        tok, cache = self._jit_decode(self.params, cache, tok_buf, active)
        jax.block_until_ready(tok)
        return self.compile_counts()

    def compile_counts(self) -> dict[str, int]:
        """Executable-cache sizes of the engine closures. After `warmup`,
        serving any trace must leave every count at 1 — the shape-stability
        contract (pinned by tests/test_engine.py)."""
        return {"prefill": self._jit_prefill._cache_size(),
                "insert": self._jit_insert._cache_size(),
                "decode": self._jit_decode._cache_size()}

    def _count_retry(self):
        self._retries += 1

    # -- request plumbing ----------------------------------------------------
    def _pad_prompt(self, prompt):
        if len(prompt) > self.prompt_pad:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prompt_pad {self.prompt_pad}")
        if self.family == "vlm" and len(prompt) < self.cfg.n_patches:
            # positions [0, n_patches) are patch embeddings wholesale; a
            # shorter prompt would gather its "last valid" logit inside the
            # patch prefix and serve silently wrong
            raise ValueError(
                f"vlm prompt length {len(prompt)} < n_patches "
                f"{self.cfg.n_patches}: the prompt must cover the patch "
                f"positions")
        padded = list(prompt) + [self.pad_id] * (self.prompt_pad - len(prompt))
        return (jnp.asarray(padded, jnp.int32)[None],
                jnp.asarray([len(prompt)], jnp.int32))

    def _prefill_request(self, req: Request, rec: RequestRecord):
        """Run the [1, prompt_pad] prefill; book vectors and the first token."""
        tokens, vl = self._pad_prompt(req.prompt)
        t0 = time.perf_counter()
        tok1, cache1 = self._jit_prefill(self.params, tokens, vl)
        tok1.block_until_ready()
        dt = time.perf_counter() - t0
        rec.prefill_vectors = len(req.prompt)
        rec.pad_vectors = self.prompt_pad - len(req.prompt)
        first = int(tok1[0, 0])
        rec.tokens.append(first)
        return tok1, cache1, first, dt

    # -- session primitives --------------------------------------------------
    # The serving loop decomposed into driver-steerable pieces: `serve()`
    # drives one session off a single `Batcher`; the multi-tenant
    # `runtime.server.ModelServer` drives one session PER co-resident model
    # under a shared clock with tenant-quota admission. Both produce
    # identical tokens for identical (request, admission-order) sequences —
    # the primitives only factor the loop, they never reorder it.

    def begin(self) -> "EngineSession":
        """Open a serving session: fresh slots, device buffers and books.

        Snapshots lifetime retry/straggler counters so a reused engine
        reports only THIS session's retries/flags (the EWMA baseline itself
        carries over on purpose — it stays warm across traces)."""
        return EngineSession(
            report=ServeReport(records={}),
            slots=SlotAllocator(self.n_slots),
            slot_rec={},
            cache=self._empty_cache(),
            tok_buf=self._empty_tok_buf(),
            active=[False] * self.n_slots,
            retries0=self._retries,
            flagged0=len(self.monitor.flagged))

    @staticmethod
    def _retire(rec: RequestRecord, reason: str, at: float):
        rec.finish_reason = reason
        rec.t_done = at

    def admit(self, sess: "EngineSession", req: Request, now: float) -> float:
        """Admit one request at clock ``now``: prefill, book, and either
        retire at prefill (max_new=1 / instant EOS — the request never
        occupies a decode slot) or insert into a free slot. Returns the
        advanced clock. Caller guarantees ``sess.slots.n_free > 0``."""
        report = sess.report
        rec = RequestRecord(request=req, t_admit=now)
        report.records[req.rid] = rec
        tok1, cache1, first, dt = self._prefill_request(req, rec)
        now += dt
        report.wall_prefill_s += dt
        report.n_prefills += 1
        report.prefill_pad_vectors += rec.pad_vectors
        report.observed_vectors += len(req.prompt)
        rec.t_first = now
        eos_hit = self.eos_id is not None and first == self.eos_id
        if req.max_new == 1 or eos_hit:
            self._retire(rec, "eos" if eos_hit else "length", now)
            return now
        slot = sess.slots.alloc(req.rid)
        sess.slot_rec[slot] = rec
        t0 = time.perf_counter()
        sess.cache, sess.tok_buf = self._jit_insert(
            sess.cache, cache1, sess.tok_buf, tok1, jnp.int32(slot))
        sess.tok_buf.block_until_ready()
        ins = time.perf_counter() - t0
        now += ins
        report.wall_prefill_s += ins
        sess.active[slot] = True
        return now

    def step(self, sess: "EngineSession", now: float) -> float:
        """One dense decode step + retirement bookkeeping; returns the
        advanced clock. Caller guarantees ``sess.slots.n_busy > 0``."""
        report = sess.report
        amask = jnp.asarray(sess.active)
        t0 = time.perf_counter()
        sess.tok_buf, sess.cache = self._safe_decode(
            self.params, sess.cache, sess.tok_buf, amask)
        sess.tok_buf.block_until_ready()
        dt = time.perf_counter() - t0
        now += dt
        report.wall_decode_s += dt
        report.n_steps += 1
        report.idle_vectors += self.n_slots - sess.slots.n_busy
        report.observed_vectors += sess.slots.n_busy
        self._step_no += 1
        self.monitor.record(self._step_no, dt)
        host_tok = jax.device_get(sess.tok_buf)[:, 0].tolist()

        for slot in list(sess.slot_rec):
            rec = sess.slot_rec[slot]
            rec.decode_vectors += 1
            rec.tokens.append(host_tok[slot])
            done_len = len(rec.tokens) >= rec.request.max_new
            done_eos = (self.eos_id is not None
                        and host_tok[slot] == self.eos_id)
            # the KV write position is bounded by max_seq; O(1)-state
            # recurrent archs have no such cap
            done_cap = (not self.recurrent
                        and len(rec.request.prompt) + rec.decode_vectors
                        >= self.max_seq)
            if done_len or done_eos or done_cap:
                self._retire(rec, "eos" if done_eos
                             else ("length" if done_len else "cap"), now)
                sess.slot_rec.pop(slot)
                sess.slots.release(slot)
                sess.active[slot] = False
        return now

    def cancel_active(self, sess: "EngineSession", now: float):
        """Retire every in-flight request with reason "cap" (step budget)."""
        for slot in list(sess.slot_rec):
            self._retire(sess.slot_rec.pop(slot), "cap", now)
            sess.slots.release(slot)
            sess.active[slot] = False

    def finish(self, sess: "EngineSession", now: float) -> ServeReport:
        """Close the session and return its report."""
        sess.report.makespan_s = now
        sess.report.retries = self._retries - sess.retries0
        sess.report.stragglers = list(self.monitor.flagged[sess.flagged0:])
        return sess.report

    # -- the serving loop ----------------------------------------------------
    def serve(self, requests, max_steps: int = 100_000) -> ServeReport:
        """Serve a full trace to completion (simulated arrival clock).

        The engine clock starts at 0 and advances by the measured wall time
        of each device call; when every slot is empty it jumps to the next
        arrival. Request arrival times are in the same (second) units."""
        queue = Batcher(requests, policy=self.admission)
        sess = self.begin()
        now = 0.0

        while len(queue) or sess.slots.n_busy:
            # ---- admission + slot refill (continuous batching) ------------
            while sess.slots.n_free:
                req = queue.pop_ready(now)
                if req is None:
                    break
                now = self.admit(sess, req, now)

            if not sess.slots.n_busy:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                now = max(now, nxt)       # idle: jump to the next arrival
                continue

            # ---- one dense decode step ------------------------------------
            if sess.report.n_steps >= max_steps:
                self.cancel_active(sess, now)
                break
            now = self.step(sess, now)

        return self.finish(sess, now)

    # -- CM_* books ----------------------------------------------------------
    def ledgers(self, report: ServeReport) -> dict:
        """rid -> CM_* counts (requires a programmed engine)."""
        from repro.runtime.batcher import request_ledgers
        if self.program is None:
            raise ValueError("CM_* ledgers require an AimcProgram")
        return request_ledgers(self.program, report.records)

    def core_ledgers(self, report: ServeReport) -> dict:
        """core -> CM_* totals for this run's useful vectors (requires a
        `CoreSchedule`). The per-core split of `ledgers`: summed over cores
        the dequeue/initialize books close exactly against
        ``program.mvm_counts()`` (`batcher.reconcile_cores`)."""
        from repro.runtime.batcher import aggregate_core_ledgers
        if self.schedule is None:
            raise ValueError("per-core ledgers require a CoreSchedule")
        return aggregate_core_ledgers(self.schedule, report.records)


class ShardedServeEngine(ServeEngine):
    """`ServeEngine` with its device state laid out over a real JAX mesh.

    The multi-device join of the three prior subsystems (DESIGN.md §11):
    the installed `AimcProgram`'s crossbar states column-shard their bit
    lines over the mesh's ``model`` axis (`shardings.serve_engine_param_
    specs` — the layout `core.schedule` proves exact), every digital leaf
    replicates over ``data`` (weights-stationary serving), and the decode
    slots — KV caches, recurrent state, the token buffer, the active mask —
    shard over the data axes so each data-parallel device advances its own
    lanes. All three closures are compiled ONCE with `NamedSharding`-pinned
    inputs AND outputs, so the cache lives sharded on-device across the
    whole serving session; the host-side loop (admission, slots,
    accounting) is inherited unchanged.

    Correctness bar: no reduction dimension is ever sharded — column splits
    concatenate and batch rows are independent — so decode output is
    BIT-EQUAL to the single-device `ServeEngine` on the same trace
    (tests/test_sharded_engine.py, forced 2-device host-platform mesh).

    When a `CoreSchedule` is attached, `schedule.mesh_placement` maps its
    virtual cores onto the model-axis devices and `device_ledgers` reports
    CM_* totals per mesh device; per-request ledgers aggregate across
    shards exactly as the single-core path (`batcher.reconcile_cores`).

    ``n_slots`` should divide the data-axis size (and crossbar Np the
    model-axis size) for the sharding to take effect; non-dividing
    dimensions fall back to replicated rather than failing.
    """

    def __init__(self, model, cfg, exe: Execution, params, *, mesh,
                 model_axis: str = "model", **kw):
        self.mesh = mesh
        self.model_axis = model_axis
        super().__init__(model, cfg, exe, params, **kw)

    def _build_closures(self, max_retries: int):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import dp_axes
        from repro.launch.shardings import (fit_spec, serve_engine_param_specs,
                                            slot_cache_specs, to_named)
        mesh = self.mesh

        def named_replicated(shape_tree):
            return jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                shape_tree)

        params_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        pspecs = serve_engine_param_specs(params_shape, mesh, self.model_axis)
        self._param_sh = to_named(pspecs, mesh)
        # place the (installed) tree once, outside the serving clock
        self.params = jax.device_put(self.params, self._param_sh)

        cache_shape = jax.eval_shape(lambda: self.model.init_cache(
            self.cfg, self.n_slots, self.max_seq, self.cache_dtype))
        self._cache_sh = to_named(
            slot_cache_specs(cache_shape, self._axes, mesh), mesh)
        dp = dp_axes(mesh)
        tok_sh = NamedSharding(
            mesh, fit_spec(P(dp, None), (self.n_slots, 1), mesh))
        self._tok_sh = tok_sh
        act_sh = NamedSharding(mesh, fit_spec(P(dp), (self.n_slots,), mesh))
        self._act_sh = act_sh
        repl = NamedSharding(mesh, P())   # fully replicated, any rank

        tokens_s = jax.ShapeDtypeStruct((1, self.prompt_pad), jnp.int32)
        vl_s = jax.ShapeDtypeStruct((1,), jnp.int32)
        cache1_shape = jax.eval_shape(self._prefill_fn, params_shape,
                                      tokens_s, vl_s)[1]
        cache1_sh = named_replicated(cache1_shape)   # [1, ...]: nothing to split

        self._jit_prefill = jax.jit(
            self._prefill_fn,
            in_shardings=(self._param_sh, repl, repl),
            out_shardings=(repl, cache1_sh))
        self._jit_insert = jax.jit(
            self._insert_fn, donate_argnums=(0, 2),
            in_shardings=(self._cache_sh, cache1_sh, tok_sh, repl, repl),
            out_shardings=(self._cache_sh, tok_sh))
        self._jit_decode = jax.jit(
            self._decode_fn,
            in_shardings=(self._param_sh, self._cache_sh, tok_sh, act_sh),
            out_shardings=(tok_sh, self._cache_sh))
        self._safe_decode = resilient_step(
            self._jit_decode, max_retries=max_retries,
            on_retry=lambda attempt, e: self._count_retry())

    def _empty_cache(self):
        # created ON the mesh placement (models' sharding-annotated init)
        return self.model.init_cache(self.cfg, self.n_slots, self.max_seq,
                                     self.cache_dtype,
                                     shardings=self._cache_sh)

    def _empty_tok_buf(self):
        return jax.device_put(super()._empty_tok_buf(), self._tok_sh)

    def device_ledgers(self, report: ServeReport) -> dict:
        """model-axis device slot -> CM_* totals for this run, through the
        schedule's core->device placement (`CoreSchedule.mesh_placement`)."""
        if self.schedule is None:
            raise ValueError("device ledgers require a CoreSchedule")
        n_vec = report.useful_vectors
        return {dev: led.cm.scaled(n_vec)
                for dev, led in self.schedule.device_ledgers(
                    self.mesh, self.model_axis).items()}


# ---------------------------------------------------------------------------
# the legacy static-batch path (A/B baseline + bit-equality oracle)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _static_closures(model, cfg, exe, max_seq, cache_dtype):
    """Jitted static-path closures, cached per configuration — a fresh
    `jax.jit(lambda ...)` per call would recompile every invocation and
    bill the A/B baseline for jit time the engine's warmup doesn't pay."""
    prefill = jax.jit(lambda pr, tk: model.prefill(
        pr, tk, cfg, exe, max_seq=max_seq, cache_dtype=cache_dtype))
    decode = jax.jit(lambda pr, ca, tk: model.decode_step(pr, ca, tk, cfg,
                                                          exe))
    return prefill, decode


def static_generate(model, cfg, exe: Execution, params, prompts, gen: int,
                    max_seq: int | None = None, cache_dtype=jnp.float32):
    """The monolithic serve loop this engine replaced: one synchronized
    batch, one prompt length, ``gen`` lockstep decode steps. Kept as the
    oracle the continuous-batching tests compare against bit-for-bit, and
    as the bench's static-batching baseline.

    prompts: [B, P] int32. Returns ([B, gen] tokens, wall seconds
    (prefill_s, decode_s)). ``gen=1`` is prefill-only: no decode loop runs
    and the decode time is honestly 0.0.
    """
    b, p = prompts.shape
    max_seq = max_seq or (p + gen)
    prefill, decode = _static_closures(model, cfg, exe, max_seq, cache_dtype)
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]]
    jax.block_until_ready(out[-1])
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, out[-1])
        out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
    if gen > 1:
        jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0 if gen > 1 else 0.0
    return jnp.concatenate(out, axis=1), (t_prefill, t_decode)
