"""ServeEngine: request-level continuous batching over a programmed AIMC
model (the `runtime/` serving subsystem).

ALPINE's deployment model is weights-stationary inference (§IV-B, Fig. 4):
CM_INITIALIZE happens once, outside the region of interest, and serving is a
forever-loop of queue/process/dequeue token vectors. This module is that
loop made real at the REQUEST level, modeled on the saxml server split
(servable model owns jitted device functions; a host-side driver owns slots
and admission):

  request lifecycle   queued -> admitted -> prefilled -> [slot i] decoding
                      -> retired (EOS / length / max_new) -> slot refilled

  slot state machine  a fixed batch of ``n_slots`` decode lanes. Each lane
                      is FREE or holds one request. Prefill runs per request
                      at one padded shape [1, prompt_pad] (ragged prompts
                      via ``valid_len``), the resulting KV/recurrent state
                      is inserted into the lane at the request's own length,
                      and the dense decode batch advances every lane at
                      once — retired/free lanes compute but are bit-frozen
                      (`mask_batch_select`), so they never corrupt state or
                      accounting.

  chunked decode      the decode closure advances ``decode_chunk`` steps
                      inside ONE jitted `lax.scan` (DESIGN.md §13). The
                      retirement predicates (max_new / EOS / max_seq cap)
                      are traced, so the active mask, per-slot token and
                      position counters live ON DEVICE for the whole chunk;
                      the host syncs once per chunk, reading a [k, n_slots]
                      token block plus per-step active/reason rows it
                      mirrors into the per-request books. `serve()` double-
                      buffers: chunk i+1 is dispatched before chunk i's
                      token block is read, so host bookkeeping and
                      admission overlap device compute.

  shape stability     exactly three device closures exist — prefill
                      [1, prompt_pad], insert (slot index is a traced
                      scalar), decode ([n_slots, 1] x decode_chunk scanned
                      steps) — each compiled ONCE at warmup. No shape
                      depends on arrival order, prompt length, or
                      live-request count, so a ragged Poisson trace runs
                      the whole session on the warmup executables
                      (asserted by `compile_counts`).

The decode loop is wrapped in `fault_tolerance.resilient_step` (transient
device errors retry; terminal ones — e.g. RESOURCE_EXHAUSTED — raise) and
timed by a `fault_tolerance.StragglerMonitor`.

CM_* accounting: every USEFUL token vector (prompt tokens at prefill, one
vector per decode step a request rides in) is booked to its request's
`RequestRecord`; padding lanes (prompt pad, idle slots) are tracked
separately as waste. `batcher.reconcile` proves the per-request ledgers sum
exactly to ``program.mvm_counts().scaled(total_vectors)``.

`launch.steps.make_prefill_step` / `make_serve_step` build their device
functions from this module's closure builders (`static_prefill_closure`,
`static_decode_closure`), so the static shape cells and the engine serve
through one implementation of the model-facing math.

Public surface
  * `ServeEngine`         — single-device continuous batching: `warmup()`,
    `serve(requests) -> ServeReport`, `compile_counts()`, `ledgers()` /
    `core_ledgers()` (CM_* books).
  * `EngineSession` + the session primitives `begin()` / `admit()` /
    `step()` / `cancel_active()` / `finish()` — the serving loop decomposed
    so an external driver (the multi-tenant `runtime.server.ModelServer`)
    can interleave several engines under ONE clock. `serve()` is exactly
    these primitives driven by a single `Batcher`.
  * `ShardedServeEngine`  — the same loop over a JAX mesh (DESIGN.md §11):
    slots over `data`, crossbar bit lines over `model`; adds
    `device_ledgers()`. Bit-equal to `ServeEngine` on the same trace.
  * `ServeReport`         — everything one serve run produced.
  * `static_generate`, `static_prefill_closure`, `static_decode_closure`
    — the legacy static-batch oracle and the shared model-facing math.

Invariants (pinned by tests/test_engine.py, tests/test_sharded_engine.py)
  * shape stability: after `warmup()` every closure's executable cache
    holds exactly one entry, for any trace, on any mesh;
  * synchronized arrivals are bit-equal to `static_generate`; the sharded
    engine is bit-equal to the single-device engine on ANY trace; decode
    is bit-equal across `decode_chunk` sizes (tests/test_chunked_decode.py);
  * slot reuse never leaks state (retired lanes are bit-frozen);
  * per-request ledgers reconcile exactly with `program.mvm_counts()`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import Execution, mask_batch_select
from repro.runtime.batcher import (Batcher, Request, RequestRecord,
                                   SlotAllocator, percentile)
from repro.runtime.fault_tolerance import StragglerMonitor, resilient_step

RECURRENT_MODULES = ("xlstm", "rglru")


# ---------------------------------------------------------------------------
# closure builders — the model-facing math, shared with launch.steps
# ---------------------------------------------------------------------------

def static_prefill_closure(model, cfg, exe: Execution, *, family: str = "lm",
                           module: str = "transformer", max_seq: int,
                           cache_dtype) -> Callable:
    """(params, batch dict) -> (next_tok [B,1] int32, cache).

    The static-batch prefill math: one call covers audio (enc-dec), vlm,
    transformer and recurrent families. `launch.steps.make_prefill_step`
    jits exactly this; the engine's static A/B baseline reuses it."""
    if family == "audio":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["frames"],
                                          batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif family == "vlm":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          patch_embeds=batch["patch_embeds"],
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif module == "transformer":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    else:
        # recurrent families: forward-only lowering (the dry-run cells carry
        # no cache; slot-cache prefill is `model.prefill`, used by the
        # engine's per-request closure below)
        def prefill(params, batch):
            logits, _ = model.forward(params, batch["tokens"], cfg, exe)
            return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), ()
    return prefill


def static_decode_closure(model, cfg, exe: Execution) -> Callable:
    """(params, cache, tokens [B,1]) -> (next_tok [B,1] int32, cache) —
    the lockstep decode step `launch.steps.make_serve_step` jits."""
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens, cfg, exe)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_cache
    return serve_step


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    """Everything one `ServeEngine.serve` run produced."""
    records: dict[int, RequestRecord]
    n_steps: int = 0               # decode batch steps executed
    n_prefills: int = 0
    idle_vectors: int = 0          # frozen decode lanes (slot-idle waste)
    prefill_pad_vectors: int = 0   # prompt-padding lanes (prefill waste)
    # useful vectors counted FROM THE DEVICE LOOP (prompt lengths at the
    # prefill call + the scan's per-step active-lane counts read back with
    # each chunk) — independent of the per-request RequestRecord
    # bookkeeping, so the two can actually disagree if the engine double-
    # or under-counts (reconcile's job)
    observed_vectors: int = 0
    wall_prefill_s: float = 0.0
    wall_decode_s: float = 0.0
    makespan_s: float = 0.0        # engine clock: last retirement - start
    retries: int = 0
    stragglers: list = dataclasses.field(default_factory=list)
    # drift-aware serving books (runtime.health / runtime.chaos): probes
    # run, faults fired, hot recalibrations performed — and the extra
    # CM_INITIALIZE device writes they charged (NEVER silent; reconciled by
    # health.reconcile_recal against reprogram_counts recomputed from
    # shapes). wall_health_s is the probe+repair wall, billed apart from
    # decode so chunk timing stays honest under recovery.
    probes: int = 0
    n_recals: int = 0
    recal_initialize: int = 0
    recal_events: list = dataclasses.field(default_factory=list)
    fault_events: list = dataclasses.field(default_factory=list)
    wall_health_s: float = 0.0

    @property
    def useful_vectors(self) -> int:
        return sum(r.vectors for r in self.records.values())

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.records.values())

    def tokens(self, rid: int) -> list[int]:
        return self.records[rid].tokens

    def latency_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        lats = [r.latency for r in self.records.values()]
        ttfts = [r.ttft for r in self.records.values()]
        out = {}
        for q in qs:
            out[f"p{q}_latency_s"] = percentile(lats, q)
            out[f"p{q}_ttft_s"] = percentile(ttfts, q)
        return out

    def summary(self) -> str:
        gen = self.generated_tokens
        wall = self.wall_prefill_s + self.wall_decode_s
        pct = self.latency_percentiles()
        return (f"{len(self.records)} requests, {gen} tokens in "
                f"{self.makespan_s:.2f}s engine-time ({gen / max(wall, 1e-9):.1f}"
                f" tok/s compute; {self.n_prefills} prefills, {self.n_steps} "
                f"decode steps, {self.idle_vectors} idle lanes); "
                f"p50/p99 latency {pct['p50_latency_s']:.2f}/"
                f"{pct['p99_latency_s']:.2f}s")


@dataclasses.dataclass
class EngineSession:
    """Host-side state of one in-flight serving run.

    Owned by a `ServeEngine`, created by `ServeEngine.begin()`; every field
    the old monolithic `serve()` loop kept as a local lives here so an
    external driver (`runtime.server.ModelServer`) can interleave sessions
    of SEVERAL engines under one clock. Device buffers (``cache``,
    ``tok_buf``, ``state``) are reassigned by `admit`/`step` (insert
    donates), so a session must only ever be driven by its own engine's
    primitives. ``state`` is the DEVICE-resident per-lane retirement rows
    ({active, gen, pos, max_new}, each [n_slots]) — the host never
    rebuilds the active mask; it only mirrors retirement decisions read
    back with each chunk's ys."""
    report: ServeReport
    slots: SlotAllocator
    slot_rec: dict[int, RequestRecord]    # slot -> live record
    cache: object
    tok_buf: object
    state: object                          # device retirement rows (see above)
    retries0: int                          # lifetime counters at begin()
    flagged0: int
    # host-side projection of each busy lane's remaining length/cap budget
    # (slot -> steps). The chunk dispatcher picks the largest compiled
    # ladder length that some lane can still use — EOS may retire a lane
    # earlier than projected (bounded waste), never later.
    rem: dict[int, int] = dataclasses.field(default_factory=dict)
    # (record, first-token device handle) pairs whose prefill result the
    # host has NOT read yet: with no EOS configured nothing about admission
    # depends on the token's value, so the read defers to the next chunk
    # sync instead of stalling the host behind an in-flight chunk.
    lazy: list = dataclasses.field(default_factory=list)


# traced retirement codes emitted by the decode scan (0 = still running);
# priority eos > length > cap, matching the pre-chunk host loop
_REASONS = {1: "length", 2: "eos", 3: "cap"}


@dataclasses.dataclass
class _PendingChunk:
    """One in-flight decode chunk: the scan's device outputs plus the
    dispatch-time clock marks `_process_chunk` needs to bill wall time
    without double-counting admissions that overlap the chunk."""
    ys: tuple          # (toks [n,S], active [n,S], reason [n,S])
    t_wall: float      # perf_counter at dispatch
    prefill0: float    # report.wall_prefill_s at dispatch
    n: int             # dispatched chunk length (a ladder size)
    health0: float = 0.0   # report.wall_health_s at dispatch (overlap bill)
    recals0: int = 0       # report.n_recals at dispatch (straggler exemption)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching serving engine over one installed model.

    Owns: the (program-installed) parameter tree, the slot-shaped decode
    cache, and the three jitted closures. Drives: admission (`Batcher`),
    slot allocation, retirement, refill, per-request accounting.

    ``params`` should already carry installed `AimcLinearState`s when
    serving the programmed AIMC path (``program.install(params)``); pass
    the `AimcProgram` as ``program`` for CM_* ledger reconciliation.
    """

    def __init__(self, model, cfg, exe: Execution, params, *,
                 n_slots: int = 4, prompt_pad: int = 16, max_seq: int = 64,
                 cache_dtype=jnp.float32, family: str = "lm",
                 module: str = "transformer", program=None, schedule=None,
                 eos_id: int | None = None, pad_id: int = 0,
                 max_retries: int = 2, straggler_threshold: float = 3.0,
                 admission: str = "fifo", decode_chunk: int = 1,
                 health=None, chaos=None, heartbeat=None):
        if family == "audio":
            raise ValueError("ServeEngine serves decoder-only LMs; the "
                             "enc-dec audio family decodes via launch.steps")
        if prompt_pad > max_seq:
            raise ValueError(f"prompt_pad {prompt_pad} > max_seq {max_seq}")
        if family == "vlm" and prompt_pad < cfg.n_patches:
            raise ValueError(
                f"vlm prompts start with {cfg.n_patches} patch positions; "
                f"prompt_pad {prompt_pad} cannot hold them")
        self.model, self.cfg, self.exe, self.params = model, cfg, exe, params
        self.n_slots, self.prompt_pad, self.max_seq = n_slots, prompt_pad, max_seq
        self.cache_dtype = cache_dtype
        self.family, self.module = family, module
        self.program, self.schedule = program, schedule
        self.eos_id, self.pad_id = eos_id, pad_id
        self.admission = admission
        if decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
        self.decode_chunk = decode_chunk
        self._ladder = self._chunk_ladder(decode_chunk)
        self.recurrent = module in RECURRENT_MODULES
        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self._retries = 0
        self._step_no = 0          # engine-lifetime decode step counter
        self._chunks_dispatched = 0  # lifetime chunk counter (chaos clock)
        # drift-aware serving (DESIGN.md §14): a `runtime.health.
        # HealthMonitor` evolves the installed states with program age,
        # probes them at chunk boundaries, and hot-reprograms failing
        # cores; a `runtime.chaos.FaultInjector` fires deterministic
        # kill/corrupt events on the chunk-dispatch clock. Both act ONLY
        # between chunks (`_resilience_tick`), so in-flight requests are
        # never touched. `heartbeat` (fault_tolerance.Heartbeat) makes the
        # loop's liveness visible to an external supervisor.
        self.health, self.chaos, self.heartbeat = health, chaos, heartbeat
        if health is not None:
            if program is None:
                raise ValueError("health monitoring requires an AimcProgram")
            if tuple(health.program.names) != tuple(program.names):
                raise ValueError("health monitor was built for a different "
                                 "program (matrix names mismatch)")
        if chaos is not None and health is None:
            raise ValueError("chaos injection requires a HealthMonitor to "
                             "detect and repair the faults it fires")

        # per-leaf batch axes of the decode cache (probed, not hardcoded:
        # transformer KV stacks batch at axis 1, recurrent state trees too,
        # but "len" and any future leaf may differ — shape-diffing two
        # abstract init_cache calls finds the axis without model knowledge)
        self._axes = self._probe_batch_axes()
        self._build_closures(max_retries)

    @staticmethod
    def _chunk_ladder(k: int) -> tuple[int, ...]:
        """The compiled chunk lengths: every power of two up to ``k``, plus
        ``k`` itself. ALL ladder lengths compile at warmup; the dispatcher
        then picks per chunk (`_pick_chunk`), so serving never recompiles
        whatever mix of lengths a ragged trace needs."""
        ladder = {1, k}
        p = 2
        while p < k:
            ladder.add(p)
            p *= 2
        return tuple(sorted(ladder))

    def _build_closures(self, max_retries: int):
        """Compile the device closures. `ShardedServeEngine` overrides
        this to pin every input/output to a mesh placement; the math
        (`_prefill_fn`/`_insert_fn`/`_decode_fn`) is shared verbatim."""
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn,
                                   donate_argnums=(0, 2, 4))
        # the decode cache is NOT donated: the step runs under
        # resilient_step, and a retry after a transient failure must be able
        # to re-present the same input buffers (donation would have
        # invalidated them on the failed attempt)
        self._decode_jits = {
            n: jax.jit(functools.partial(self._decode_fn, length=n))
            for n in self._ladder}
        self._safe_decodes = {
            n: resilient_step(f, max_retries=max_retries,
                              on_retry=lambda attempt, e: self._count_retry())
            for n, f in self._decode_jits.items()}

    # -- closures ------------------------------------------------------------
    def _probe_batch_axes(self):
        def shapes(b):
            return jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, b, self.max_seq, self.cache_dtype))

        def axis_of(s2, s3):
            for i, (a, b) in enumerate(zip(s2.shape, s3.shape)):
                if a != b:
                    return i
            raise ValueError(f"no batch axis found in cache leaf {s2}")

        return jax.tree.map(axis_of, shapes(2), shapes(3))

    def _prefill_fn(self, params, tokens, valid_len):
        """[1, prompt_pad] ragged prefill -> (first_tok [1,1], cache1)."""
        kw = {}
        if self.family == "vlm":
            # patch positions are a prompt prefix; the engine serves the
            # text path with zero patch embeddings unless a request-level
            # frontend supplies them (frontend-stub rule)
            kw["patch_embeds"] = jnp.zeros(
                (tokens.shape[0], self.cfg.n_patches, self.cfg.d_model),
                jnp.float32)
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.exe, max_seq=self.max_seq,
            cache_dtype=self.cache_dtype, valid_len=valid_len, **kw)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        return tok, cache

    def _insert_fn(self, cache, cache1, tok_buf, tok1, state, slot, pos0,
                   max_new):
        """Write a prefilled request's state into decode lane ``slot`` —
        including the lane's on-device retirement row (active flag,
        generated-token count, KV position, decode budget), so the decode
        closure never needs a host-built mask."""
        def put(big, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot, axis=ax)

        cache = jax.tree.map(put, cache, cache1, self._axes)
        tok_buf = jax.lax.dynamic_update_slice(tok_buf, tok1, (slot, 0))
        # gen starts at 1: the prefill's first token counts against max_new
        state = {"active": state["active"].at[slot].set(True),
                 "gen": state["gen"].at[slot].set(1),
                 "pos": state["pos"].at[slot].set(pos0),
                 "max_new": state["max_new"].at[slot].set(max_new)}
        return cache, tok_buf, state

    def _decode_fn(self, params, cache, tok_buf, state, length):
        """``length`` dense decode steps in ONE jitted `lax.scan`; inactive
        lanes are bit-frozen. Retirement predicates (max_new / EOS /
        max_seq cap) are traced, so the active mask and per-lane counters
        never leave the device mid-chunk. ``length`` is host-chosen per
        dispatch from the COMPILED LADDER (`_chunk_ladder`): the host
        mirrors every lane's length/cap budget exactly, so it picks the
        largest ladder length no greater than the longest remaining budget
        — a chunk never runs past the last live lane (the fixed-k variant
        over-ran ragged traces by 2-3x decode steps at k=8), and the
        device needs no early-exit predicate (an `active.any()` loop
        condition would be a per-token cross-device collective).

        Returns (tok_buf, cache, state, ys) with per-step chunk outputs
        ys = (toks [length,S], active-at-entry [length,S], reason
        [length,S]). The per-step busy count is NOT reduced on device:
        `active.sum()` would be the only other cross-device collective in
        the data-sharded loop (one all-reduce per token) — the host pops
        it from the ``active`` rows it reads back anyway. ys rides outside
        ``state`` because a subsequent insert donates the state buffers
        while a chunk's readback may still be pending (double-buffered
        serve)."""
        def one_step(carry, _):
            cache, tokens, st = carry
            active = st["active"]
            if self.module == "transformer":
                logits, new_cache = self.model.decode_step(
                    params, cache, tokens, self.cfg, self.exe, ragged=True)
            else:
                logits, new_cache = self.model.decode_step(
                    params, cache, tokens, self.cfg, self.exe)
            new_cache = jax.tree.map(
                lambda n, o, ax: mask_batch_select(n, o, active, ax),
                new_cache, cache, self._axes)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            tok = jnp.where(active[:, None], tok, tokens)
            emitted = tok[:, 0]
            gen = st["gen"] + active.astype(jnp.int32)
            pos = st["pos"] + active.astype(jnp.int32)
            done_len = gen >= st["max_new"]
            done_eos = (jnp.zeros_like(active) if self.eos_id is None
                        else emitted == jnp.int32(self.eos_id))
            # the KV write position is bounded by max_seq; O(1)-state
            # recurrent archs have no such cap
            done_cap = (jnp.zeros_like(active) if self.recurrent
                        else pos >= jnp.int32(self.max_seq))
            reason = jnp.where(done_eos, 2, jnp.where(done_len, 1,
                               jnp.where(done_cap, 3, 0))).astype(jnp.int32)
            reason = jnp.where(active, reason, 0)
            new_st = {"active": active & (reason == 0), "gen": gen,
                      "pos": pos, "max_new": st["max_new"]}
            return (new_cache, tok, new_st), (emitted, active, reason)

        (cache, tok_buf, state), ys = jax.lax.scan(
            one_step, (cache, tok_buf, state), None, length=length)
        return tok_buf, cache, state, ys

    # -- warmup / compile accounting ----------------------------------------
    def _empty_cache(self):
        return self.model.init_cache(self.cfg, self.n_slots, self.max_seq,
                                     self.cache_dtype)

    def _empty_tok_buf(self):
        """The [n_slots, 1] next-token buffer. A hook so the sharded engine
        can commit it to its mesh placement — an uncommitted buffer would
        key the insert closure's jit cache differently from the committed
        buffers later steps feed back, costing a recompile."""
        return jnp.zeros((self.n_slots, 1), jnp.int32)

    def _empty_state(self):
        """The device-resident per-lane retirement rows, all [n_slots]:
        active mask, generated-token and KV-position counters, decode
        budget. Sharded engine override commits them to the mesh. Each leaf
        must be a DISTINCT buffer — insert donates the whole dict, and XLA
        rejects donating one buffer twice."""
        def z():
            return jnp.zeros((self.n_slots,), jnp.int32)
        return {"active": jnp.zeros((self.n_slots,), bool),
                "gen": z(), "pos": z(), "max_new": z()}

    def warmup(self):
        """Compile every closure (prefill, insert, and one decode
        executable per ladder length) once, outside the serving clock."""
        tokens = jnp.zeros((1, self.prompt_pad), jnp.int32)
        vl = jnp.ones((1,), jnp.int32)
        tok1, cache1 = self._jit_prefill(self.params, tokens, vl)
        cache = self._empty_cache()
        tok_buf = self._empty_tok_buf()
        state = self._empty_state()
        cache, tok_buf, state = self._jit_insert(
            cache, cache1, tok_buf, tok1, state, jnp.int32(0), jnp.int32(1),
            jnp.int32(1))
        for n in self._ladder:
            tok_buf, cache, state, ys = self._decode_jits[n](
                self.params, cache, tok_buf, state)
        jax.block_until_ready(ys)
        return self.compile_counts()

    def compile_counts(self) -> dict[str, int]:
        """Executable-cache sizes of the engine closures. After `warmup`,
        serving any trace must leave prefill/insert at 1 and decode at
        ``len(self._ladder)`` (one executable per compiled chunk length,
        all warmed up front) — the shape-stability contract (pinned by
        tests/test_engine.py and tests/test_chunked_decode.py)."""
        return {"prefill": self._jit_prefill._cache_size(),
                "insert": self._jit_insert._cache_size(),
                "decode": sum(f._cache_size()
                              for f in self._decode_jits.values())}

    def _count_retry(self):
        self._retries += 1

    # -- drift / health / chaos (DESIGN.md §14) -------------------------------
    def _set_params(self, params):
        """Swap the served parameter tree. Every update preserves shapes and
        treedef (drift gains scale s_w; reprogrammed states are
        structure-identical), so the compiled closures are reused as-is.
        The sharded engine overrides this to re-pin the mesh placement."""
        self.params = params

    def _resilience_tick(self, sess: "EngineSession", now: float) -> float:
        """Chunk-boundary resilience work: fire due chaos events, advance
        drift, probe the live states, and hot-reprogram failing cores.

        Runs on the host BETWEEN chunk dispatches — an in-flight chunk was
        dispatched against the previous parameter tree and is untouched, so
        recovery never drops or perturbs an in-flight request. All wall
        time spent here is billed to ``wall_health_s`` (and subtracted from
        the overlapping chunk's decode bill in `_process_chunk`)."""
        if self.health is None and self.chaos is None:
            return now
        from repro.core.program import installed_entries
        from repro.runtime import chaos as chaos_lib
        from repro.runtime.health import RecalEvent
        t0 = time.perf_counter()
        report = sess.report
        forced = False
        if self.chaos is not None:
            for ev in self.chaos.due(self._chunks_dispatched):
                prog = self.health.program
                mag = 1.0 if ev.kind == chaos_lib.KILL else ev.magnitude
                entries = chaos_lib.corrupt_entries(prog, ev.core, mag)
                if ev.kind == chaos_lib.KILL:
                    self.health.mark_dead(ev.core)
                if entries:
                    self._set_params(
                        prog.install_updates(self.params, entries))
                report.fault_events.append(ev)
                forced = True
        if self.health is not None and (forced or self.health.due(now)):
            drifted = self.health.drifted_entries(now)
            if drifted:
                self._set_params(
                    self.health.program.install_updates(self.params, drifted))
            live = installed_entries(self.params)
            sample = self.health.probe(live, now)
            report.probes += 1
            failing = self.health.failing_cores(sample)
            if failing:
                dead = set(failing) & self.health.dead
                t_r = time.perf_counter()
                entries, names, cm = self.health.recalibrate(failing, now)
                if names:
                    prog = self.health.program
                    self.program = prog
                    if (self.schedule is not None
                            and self.schedule.name == "from_program"):
                        from repro.core.schedule import CoreSchedule
                        self.schedule = CoreSchedule.from_program(
                            prog, pipelined=self.schedule.pipelined)
                    self._set_params(
                        prog.install_updates(self.params, entries))
                    ev = RecalEvent(
                        t=now,
                        reason=("dead_core" if dead
                                else "fault" if forced else "drift"),
                        cores=tuple(failing), names=names,
                        initialize=cm.initialize,
                        wall_s=time.perf_counter() - t_r)
                    self.health.events.append(ev)
                    report.recal_events.append(ev)
                    report.recal_initialize += cm.initialize
                    report.n_recals += 1
        wall = time.perf_counter() - t0
        report.wall_health_s += wall
        return now + wall

    # -- request plumbing ----------------------------------------------------
    def _pad_prompt(self, prompt):
        if len(prompt) > self.prompt_pad:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prompt_pad {self.prompt_pad}")
        if self.family == "vlm" and len(prompt) < self.cfg.n_patches:
            # positions [0, n_patches) are patch embeddings wholesale; a
            # shorter prompt would gather its "last valid" logit inside the
            # patch prefix and serve silently wrong
            raise ValueError(
                f"vlm prompt length {len(prompt)} < n_patches "
                f"{self.cfg.n_patches}: the prompt must cover the patch "
                f"positions")
        padded = list(prompt) + [self.pad_id] * (self.prompt_pad - len(prompt))
        return (jnp.asarray(padded, jnp.int32)[None],
                jnp.asarray([len(prompt)], jnp.int32))

    def _prefill_request(self, req: Request, rec: RequestRecord,
                         lazy: bool = False):
        """Run the [1, prompt_pad] prefill; book the vector counts. The
        caller decides whether the first token is delivered output (an
        instant EOS is control, not payload — `admit`). With ``lazy`` the
        host does NOT block on the result: ``first`` comes back None and
        the caller reads the token handle at the next chunk sync — the
        prefill itself queues behind any in-flight chunk on the device, so
        blocking here would stall admission on decode compute."""
        tokens, vl = self._pad_prompt(req.prompt)
        t0 = time.perf_counter()
        tok1, cache1 = self._jit_prefill(self.params, tokens, vl)
        first = None
        if not lazy:
            tok1.block_until_ready()
            first = int(tok1[0, 0])
        dt = time.perf_counter() - t0
        rec.prefill_vectors = len(req.prompt)
        rec.pad_vectors = self.prompt_pad - len(req.prompt)
        return tok1, cache1, first, dt

    # -- session primitives --------------------------------------------------
    # The serving loop decomposed into driver-steerable pieces: `serve()`
    # drives one session off a single `Batcher`; the multi-tenant
    # `runtime.server.ModelServer` drives one session PER co-resident model
    # under a shared clock with tenant-quota admission. Both produce
    # identical tokens for identical (request, admission-order) sequences —
    # the primitives only factor the loop, they never reorder it.

    def begin(self) -> "EngineSession":
        """Open a serving session: fresh slots, device buffers and books.

        Snapshots lifetime retry/straggler counters so a reused engine
        reports only THIS session's retries/flags (the EWMA baseline itself
        carries over on purpose — it stays warm across traces)."""
        return EngineSession(
            report=ServeReport(records={}),
            slots=SlotAllocator(self.n_slots),
            slot_rec={},
            cache=self._empty_cache(),
            tok_buf=self._empty_tok_buf(),
            state=self._empty_state(),
            retries0=self._retries,
            flagged0=len(self.monitor.flagged))

    @staticmethod
    def _retire(rec: RequestRecord, reason: str, at: float):
        rec.finish_reason = reason
        rec.t_done = at

    def admit(self, sess: "EngineSession", req: Request, now: float) -> float:
        """Admit one request at clock ``now``: prefill, book, and either
        retire at prefill (max_new=1 / instant EOS — the request never
        occupies a decode slot) or insert into a free slot. Returns the
        advanced clock. Caller guarantees ``sess.slots.n_free > 0``."""
        report = sess.report
        rec = RequestRecord(request=req, t_admit=now)
        report.records[req.rid] = rec
        # with no EOS configured, NOTHING about admission depends on the
        # first token's value — defer the host read to the next chunk sync
        # so admission overlaps the in-flight chunk instead of waiting
        # behind it on the device queue
        lazy = self.eos_id is None
        tok1, cache1, first, dt = self._prefill_request(req, rec, lazy)
        now += dt
        report.wall_prefill_s += dt
        report.n_prefills += 1
        report.prefill_pad_vectors += rec.pad_vectors
        report.observed_vectors += len(req.prompt)
        rec.t_first = now
        if lazy:
            sess.lazy.append((rec, tok1))
        else:
            eos_hit = first == self.eos_id
            if not eos_hit:
                # the EOS token is control, not payload: it never lands in
                # `rec.tokens` (so generated_tokens / tok_s count delivered
                # output only), but its vector stays in the CM_* books
                rec.tokens.append(first)
            if eos_hit:
                self._retire(rec, "eos", now)
                return now
        if req.max_new == 1:
            self._retire(rec, "length", now)
            return now
        slot = sess.slots.alloc(req.rid)
        sess.slot_rec[slot] = rec
        rem = req.max_new - 1
        if not self.recurrent:
            rem = min(rem, self.max_seq - len(req.prompt))
        sess.rem[slot] = rem
        t0 = time.perf_counter()
        sess.cache, sess.tok_buf, sess.state = self._jit_insert(
            sess.cache, cache1, sess.tok_buf, tok1, sess.state,
            jnp.int32(slot), jnp.int32(len(req.prompt)),
            jnp.int32(req.max_new))
        if not lazy:
            # the blocking (EOS-aware) path bills the full prefill+insert
            # wall here; the lazy path bills dispatch only — the device
            # time lands in the next chunk's window, where it actually
            # serializes (insert chains on the in-flight chunk's outputs)
            sess.tok_buf.block_until_ready()
        ins = time.perf_counter() - t0
        now += ins
        report.wall_prefill_s += ins
        return now

    def _pick_chunk(self, sess: "EngineSession",
                    responsive: bool = False) -> int:
        """Chunk length for the next dispatch, from the compiled ladder.

        Default: the largest ladder length not exceeding the longest
        PROJECTED remaining budget across busy lanes — maximum host-round
        amortization, and a chunk never runs past the last projected-live
        lane. ``responsive`` (requests are waiting for a slot): the
        SMALLEST ladder length covering the earliest projected retirement,
        so the freed slot reaches the admission loop promptly instead of
        idling to the end of a long chunk. 0 means every in-flight lane is
        projected retired (a dispatch would scan an all-frozen batch —
        skip it)."""
        rems = [r for r in (sess.rem.get(s, 0) for s in sess.slot_rec)
                if r > 0]
        if not rems:
            return 0
        if responsive:
            target = min(rems)
            for n in self._ladder:
                if n >= target:
                    return n
            return self._ladder[-1]
        target = max(rems)
        for n in reversed(self._ladder):
            if n <= target:
                return n
        return 1

    def _dispatch_chunk(self, sess: "EngineSession",
                        n: int | None = None) -> _PendingChunk:
        """Launch one ``n``-step scan (a compiled ladder length, default
        host-picked) WITHOUT waiting for its results; `sess`'s device
        buffers advance to the chunk's outputs so the next chunk (or an
        insert) chains on-device."""
        if n is None:
            n = self._pick_chunk(sess) or 1
        t0 = time.perf_counter()
        sess.tok_buf, sess.cache, sess.state, ys = self._safe_decodes[n](
            self.params, sess.cache, sess.tok_buf, sess.state)
        for slot in sess.slot_rec:
            sess.rem[slot] = max(0, sess.rem.get(slot, 0) - n)
        self._chunks_dispatched += 1
        return _PendingChunk(ys=ys, t_wall=t0,
                             prefill0=sess.report.wall_prefill_s, n=n,
                             health0=sess.report.wall_health_s,
                             recals0=sess.report.n_recals)

    def _process_chunk(self, sess: "EngineSession", pend: _PendingChunk,
                       now: float) -> float:
        """Sync one dispatched chunk and mirror its on-device retirement
        rows into the host books. Billing: the chunk costs (wall since
        dispatch) minus any prefill/insert wall already billed inside that
        window — the double-buffered loop admits WHILE a chunk flies."""
        report = sess.report
        toks, acts, reasons = jax.device_get(pend.ys)
        # any admission since the last sync has its prefill long done by
        # now (the chunk we just read back queued after it) — the deferred
        # first-token reads cost a host copy, not a wait
        self._resolve_firsts(sess)
        overlap = ((report.wall_prefill_s - pend.prefill0)
                   + (report.wall_health_s - pend.health0))
        dt = max(time.perf_counter() - pend.t_wall - overlap, 0.0)
        now += dt
        report.wall_decode_s += dt
        ran = int(toks.shape[0])
        busy = int(acts.sum())
        report.n_steps += ran
        # busy-lane counts come from the DEVICE (chunk ys), independent of
        # the per-request records — reconcile compares two real countings
        report.observed_vectors += busy
        report.idle_vectors += self.n_slots * ran - busy
        self._step_no += ran
        # a chunk whose window held a hot reprogram is legitimately slow:
        # exempt it from the straggler EWMA (flagging recovery would page
        # an operator for behavior the engine itself caused, and the
        # inflated sample would poison the baseline)
        self.monitor.record(self._step_no, dt / max(ran, 1),
                            exempt=report.n_recals > pend.recals0)
        if self.heartbeat is not None:
            self.heartbeat.beat(
                self._step_no, slots_busy=sess.slots.n_busy,
                slots_free=sess.slots.n_free, chunk_len=ran,
                last_chunk_s=time.time(),
                wall_decode_s=report.wall_decode_s,
                n_recals=report.n_recals)

        for s in range(ran):
            for slot in list(sess.slot_rec):
                if not acts[s, slot]:
                    continue    # freed/refilled after this chunk's dispatch
                rec = sess.slot_rec[slot]
                rec.decode_vectors += 1
                r = int(reasons[s, slot])
                if r != 2:      # EOS is control, not payload (see admit)
                    rec.tokens.append(int(toks[s, slot]))
                if r:
                    self._retire(rec, _REASONS[r], now)
                    sess.slot_rec.pop(slot)
                    sess.slots.release(slot)
                    sess.rem.pop(slot, None)
        return now

    @staticmethod
    def _resolve_firsts(sess: "EngineSession"):
        """Read back the deferred prefill first-tokens (lazy admission,
        `admit`). Runs before any decode-token append for those records —
        a record admitted after a chunk's dispatch shows acts=False for
        that whole chunk, so its first token always lands at index 0."""
        for rec, tok1 in sess.lazy:
            rec.tokens.insert(0, int(tok1[0, 0]))
        sess.lazy.clear()

    def step(self, sess: "EngineSession", now: float) -> float:
        """One SYNCHRONOUS decode chunk (``decode_chunk`` dense steps,
        dispatched and immediately processed) + retirement bookkeeping;
        returns the advanced clock. Caller guarantees ``sess.slots.n_busy
        > 0``. External drivers (the multi-tenant server) see retirement
        and quota accounting land on chunk boundaries; `serve()` instead
        double-buffers dispatch/process for comm/compute overlap."""
        now = self._resilience_tick(sess, now)
        return self._process_chunk(sess, self._dispatch_chunk(sess), now)

    def cancel_active(self, sess: "EngineSession", now: float):
        """Retire every in-flight request with reason "cap" (step budget).
        The device-side active rows are left stale on purpose — a canceled
        session is never stepped again."""
        self._resolve_firsts(sess)
        for slot in list(sess.slot_rec):
            self._retire(sess.slot_rec.pop(slot), "cap", now)
            sess.slots.release(slot)
            sess.rem.pop(slot, None)

    def finish(self, sess: "EngineSession", now: float) -> ServeReport:
        """Close the session and return its report."""
        self._resolve_firsts(sess)
        sess.report.makespan_s = now
        sess.report.retries = self._retries - sess.retries0
        sess.report.stragglers = list(self.monitor.flagged[sess.flagged0:])
        return sess.report

    # -- the serving loop ----------------------------------------------------
    def serve(self, requests, max_steps: int = 100_000) -> ServeReport:
        """Serve a full trace to completion (simulated arrival clock).

        The engine clock starts at 0 and advances by the measured wall time
        of each device call; when every slot is empty it jumps to the next
        arrival. Request arrival times are in the same (second) units.

        Decode is DOUBLE-BUFFERED: chunk i+1 is dispatched before chunk
        i's token block is read back, so host bookkeeping and admission
        overlap device compute. Per-request tokens are unaffected — decode
        lanes are row-independent, so what a request generates never
        depends on which chunk (or which lane-mates) it rode with."""
        queue = Batcher(requests, policy=self.admission)
        sess = self.begin()
        now = 0.0
        pending: _PendingChunk | None = None

        while len(queue) or sess.slots.n_busy or pending is not None:
            # ---- admission + slot refill (continuous batching) ------------
            while sess.slots.n_free:
                req = queue.pop_ready(now)
                if req is None:
                    break
                now = self.admit(sess, req, now)

            # ---- chunk-boundary resilience (drift / chaos / recal) ---------
            now = self._resilience_tick(sess, now)

            if not sess.slots.n_busy and pending is None:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                now = max(now, nxt)       # idle: jump to the next arrival
                continue

            # ---- one decode chunk, double-buffered ------------------------
            in_flight = pending.n if pending is not None else 0
            capped = sess.report.n_steps + in_flight >= max_steps
            n_next = (self._pick_chunk(sess, responsive=bool(len(queue)))
                      if sess.slots.n_busy else 0)
            cur = (self._dispatch_chunk(sess, n_next)
                   if n_next and not capped else None)
            if pending is not None:
                now = self._process_chunk(sess, pending, now)
            pending = cur
            if capped and pending is None:
                self.cancel_active(sess, now)
                break

        return self.finish(sess, now)

    # -- CM_* books ----------------------------------------------------------
    def ledgers(self, report: ServeReport) -> dict:
        """rid -> CM_* counts (requires a programmed engine)."""
        from repro.runtime.batcher import request_ledgers
        if self.program is None:
            raise ValueError("CM_* ledgers require an AimcProgram")
        return request_ledgers(self.program, report.records)

    def core_ledgers(self, report: ServeReport) -> dict:
        """core -> CM_* totals for this run's useful vectors (requires a
        `CoreSchedule`). The per-core split of `ledgers`: summed over cores
        the dequeue/initialize books close exactly against
        ``program.mvm_counts()`` (`batcher.reconcile_cores`)."""
        from repro.runtime.batcher import aggregate_core_ledgers
        if self.schedule is None:
            raise ValueError("per-core ledgers require a CoreSchedule")
        return aggregate_core_ledgers(self.schedule, report.records)


class ShardedServeEngine(ServeEngine):
    """`ServeEngine` with its device state laid out over a real JAX mesh.

    The multi-device join of the three prior subsystems (DESIGN.md §11):
    the installed `AimcProgram`'s crossbar states column-shard their bit
    lines over the mesh's ``model`` axis (`shardings.serve_engine_param_
    specs` — the layout `core.schedule` proves exact), every digital leaf
    replicates over ``data`` (weights-stationary serving), and the decode
    slots — KV caches, recurrent state, the token buffer, the retirement
    state rows — shard over the data axes so each data-parallel device
    advances its own lanes. All three closures are compiled ONCE with `NamedSharding`-pinned
    inputs AND outputs, so the cache lives sharded on-device across the
    whole serving session; the host-side loop (admission, slots,
    accounting) is inherited unchanged.

    Correctness bar: no reduction dimension is ever sharded — column splits
    concatenate and batch rows are independent — so decode output is
    BIT-EQUAL to the single-device `ServeEngine` on the same trace
    (tests/test_sharded_engine.py, forced 2-device host-platform mesh).

    When a `CoreSchedule` is attached, `schedule.mesh_placement` maps its
    virtual cores onto the model-axis devices and `device_ledgers` reports
    CM_* totals per mesh device; per-request ledgers aggregate across
    shards exactly as the single-core path (`batcher.reconcile_cores`).

    ``n_slots`` should divide the data-axis size (and crossbar Np the
    model-axis size) for the sharding to take effect; non-dividing
    dimensions fall back to replicated rather than failing.
    """

    def __init__(self, model, cfg, exe: Execution, params, *, mesh,
                 model_axis: str = "model", **kw):
        self.mesh = mesh
        self.model_axis = model_axis
        super().__init__(model, cfg, exe, params, **kw)

    def _build_closures(self, max_retries: int):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import dp_axes
        from repro.launch.shardings import (fit_spec, serve_engine_param_specs,
                                            slot_cache_specs, slot_state_specs,
                                            to_named)
        mesh = self.mesh

        def named_replicated(shape_tree):
            return jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                shape_tree)

        params_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        pspecs = serve_engine_param_specs(params_shape, mesh, self.model_axis)
        self._param_sh = to_named(pspecs, mesh)
        # place the (installed) tree once, outside the serving clock
        self.params = jax.device_put(self.params, self._param_sh)

        cache_shape = jax.eval_shape(lambda: self.model.init_cache(
            self.cfg, self.n_slots, self.max_seq, self.cache_dtype))
        self._cache_sh = to_named(
            slot_cache_specs(cache_shape, self._axes, mesh), mesh)
        dp = dp_axes(mesh)
        tok_sh = NamedSharding(
            mesh, fit_spec(P(dp, None), (self.n_slots, 1), mesh))
        self._tok_sh = tok_sh
        state_shape = jax.eval_shape(lambda: ServeEngine._empty_state(self))
        self._state_sh = to_named(slot_state_specs(state_shape, mesh), mesh)
        repl = NamedSharding(mesh, P())   # fully replicated, any rank
        # chunk outputs: per-step [n, n_slots] rows follow the lane split
        # (slots over data axes); the spec is shape-free, so one sharding
        # serves every compiled ladder length
        ys_row = NamedSharding(mesh, fit_spec(
            P(None, dp), (self.decode_chunk, self.n_slots), mesh))
        ys_sh = (ys_row, ys_row, ys_row)

        tokens_s = jax.ShapeDtypeStruct((1, self.prompt_pad), jnp.int32)
        vl_s = jax.ShapeDtypeStruct((1,), jnp.int32)
        cache1_shape = jax.eval_shape(self._prefill_fn, params_shape,
                                      tokens_s, vl_s)[1]
        cache1_sh = named_replicated(cache1_shape)   # [1, ...]: nothing to split

        self._jit_prefill = jax.jit(
            self._prefill_fn,
            in_shardings=(self._param_sh, repl, repl),
            out_shardings=(repl, cache1_sh))
        self._jit_insert = jax.jit(
            self._insert_fn, donate_argnums=(0, 2, 4),
            in_shardings=(self._cache_sh, cache1_sh, tok_sh, repl,
                          self._state_sh, repl, repl, repl),
            out_shardings=(self._cache_sh, tok_sh, self._state_sh))
        self._decode_jits = {
            n: jax.jit(
                functools.partial(self._decode_fn, length=n),
                in_shardings=(self._param_sh, self._cache_sh, tok_sh,
                              self._state_sh),
                out_shardings=(tok_sh, self._cache_sh, self._state_sh,
                               ys_sh))
            for n in self._ladder}
        self._safe_decodes = {
            n: resilient_step(f, max_retries=max_retries,
                              on_retry=lambda attempt, e: self._count_retry())
            for n, f in self._decode_jits.items()}

    def _set_params(self, params):
        # re-pin the updated tree to the mesh layout the closures were
        # compiled against (identical treedef/shapes -> no recompile)
        self.params = jax.device_put(params, self._param_sh)

    def _empty_cache(self):
        # created ON the mesh placement (models' sharding-annotated init)
        return self.model.init_cache(self.cfg, self.n_slots, self.max_seq,
                                     self.cache_dtype,
                                     shardings=self._cache_sh)

    def _empty_tok_buf(self):
        return jax.device_put(super()._empty_tok_buf(), self._tok_sh)

    def _empty_state(self):
        return jax.device_put(super()._empty_state(), self._state_sh)

    def device_ledgers(self, report: ServeReport) -> dict:
        """model-axis device slot -> CM_* totals for this run, through the
        schedule's core->device placement (`CoreSchedule.mesh_placement`)."""
        if self.schedule is None:
            raise ValueError("device ledgers require a CoreSchedule")
        n_vec = report.useful_vectors
        return {dev: led.cm.scaled(n_vec)
                for dev, led in self.schedule.device_ledgers(
                    self.mesh, self.model_axis).items()}


# ---------------------------------------------------------------------------
# the legacy static-batch path (A/B baseline + bit-equality oracle)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _static_closures(model, cfg, exe, max_seq, cache_dtype):
    """Jitted static-path closures, cached per configuration — a fresh
    `jax.jit(lambda ...)` per call would recompile every invocation and
    bill the A/B baseline for jit time the engine's warmup doesn't pay."""
    prefill = jax.jit(lambda pr, tk: model.prefill(
        pr, tk, cfg, exe, max_seq=max_seq, cache_dtype=cache_dtype))
    decode = jax.jit(lambda pr, ca, tk: model.decode_step(pr, ca, tk, cfg,
                                                          exe))
    return prefill, decode


def static_generate(model, cfg, exe: Execution, params, prompts, gen: int,
                    max_seq: int | None = None, cache_dtype=jnp.float32):
    """The monolithic serve loop this engine replaced: one synchronized
    batch, one prompt length, ``gen`` lockstep decode steps. Kept as the
    oracle the continuous-batching tests compare against bit-for-bit, and
    as the bench's static-batching baseline.

    prompts: [B, P] int32. Returns ([B, gen] tokens, wall seconds
    (prefill_s, decode_s)). ``gen=1`` is prefill-only: no decode loop runs
    and the decode time is honestly 0.0.
    """
    b, p = prompts.shape
    max_seq = max_seq or (p + gen)
    prefill, decode = _static_closures(model, cfg, exe, max_seq, cache_dtype)
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]]
    jax.block_until_ready(out[-1])
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, out[-1])
        out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
    if gen > 1:
        jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0 if gen > 1 else 0.0
    return jnp.concatenate(out, axis=1), (t_prefill, t_decode)
