"""ServeEngine: request-level continuous batching over a programmed AIMC
model (the `runtime/` serving subsystem).

ALPINE's deployment model is weights-stationary inference (§IV-B, Fig. 4):
CM_INITIALIZE happens once, outside the region of interest, and serving is a
forever-loop of queue/process/dequeue token vectors. This module is that
loop made real at the REQUEST level, modeled on the saxml server split
(servable model owns jitted device functions; a host-side driver owns slots
and admission):

  request lifecycle   queued -> admitted -> prefilled -> [slot i] decoding
                      -> retired (EOS / length / max_new) -> slot refilled

  slot state machine  a fixed batch of ``n_slots`` decode lanes. Each lane
                      is FREE or holds one request. Prefill runs per request
                      at one padded shape [1, prompt_pad] (ragged prompts
                      via ``valid_len``), the resulting KV/recurrent state
                      is inserted into the lane at the request's own length,
                      and the dense decode batch advances every lane at
                      once — retired/free lanes compute but are bit-frozen
                      (`mask_batch_select`), so they never corrupt state or
                      accounting.

  chunked decode      the decode closure advances ``decode_chunk`` steps
                      inside ONE jitted `lax.scan` (DESIGN.md §13). The
                      retirement predicates (max_new / EOS / max_seq cap)
                      are traced, so the active mask, per-slot token and
                      position counters live ON DEVICE for the whole chunk;
                      the host syncs once per chunk, reading a [k, n_slots]
                      token block plus per-step active/reason rows it
                      mirrors into the per-request books. `serve()` double-
                      buffers: chunk i+1 is dispatched before chunk i's
                      token block is read, so host bookkeeping and
                      admission overlap device compute.

  shape stability     exactly three device closures exist — prefill
                      [1, prompt_pad], insert (slot index is a traced
                      scalar), decode ([n_slots, 1] x decode_chunk scanned
                      steps) — each compiled ONCE at warmup. No shape
                      depends on arrival order, prompt length, or
                      live-request count, so a ragged Poisson trace runs
                      the whole session on the warmup executables
                      (asserted by `compile_counts`).

The decode loop is wrapped in `fault_tolerance.resilient_step` (transient
device errors retry; terminal ones — e.g. RESOURCE_EXHAUSTED — raise) and
timed by a `fault_tolerance.StragglerMonitor`.

CM_* accounting: every USEFUL token vector (prompt tokens at prefill, one
vector per decode step a request rides in) is booked to its request's
`RequestRecord`; padding lanes (prompt pad, idle slots) are tracked
separately as waste. `batcher.reconcile` proves the per-request ledgers sum
exactly to ``program.mvm_counts().scaled(total_vectors)``.

`launch.steps.make_prefill_step` / `make_serve_step` build their device
functions from this module's closure builders (`static_prefill_closure`,
`static_decode_closure`), so the static shape cells and the engine serve
through one implementation of the model-facing math.

Public surface
  * `ServeEngine`         — single-device continuous batching: `warmup()`,
    `serve(requests) -> ServeReport`, `compile_counts()`, `ledgers()` /
    `core_ledgers()` (CM_* books).
  * `EngineSession` + the session primitives `begin()` / `admit()` /
    `step()` / `cancel_active()` / `finish()` — the serving loop decomposed
    so an external driver (the multi-tenant `runtime.server.ModelServer`)
    can interleave several engines under ONE clock. `serve()` is exactly
    these primitives driven by a single `Batcher`.
  * `ShardedServeEngine`  — the same loop over a JAX mesh (DESIGN.md §11):
    slots over `data`, crossbar bit lines over `model`; adds
    `device_ledgers()`. Bit-equal to `ServeEngine` on the same trace.
  * `ServeReport`         — everything one serve run produced.
  * `static_generate`, `static_prefill_closure`, `static_decode_closure`
    — the legacy static-batch oracle and the shared model-facing math.

Invariants (pinned by tests/test_engine.py, tests/test_sharded_engine.py)
  * shape stability: after `warmup()` every closure's executable cache
    holds exactly one entry, for any trace, on any mesh;
  * synchronized arrivals are bit-equal to `static_generate`; the sharded
    engine is bit-equal to the single-device engine on ANY trace; decode
    is bit-equal across `decode_chunk` sizes (tests/test_chunked_decode.py);
  * slot reuse never leaks state (retired lanes are bit-frozen);
  * per-request ledgers reconcile exactly with `program.mvm_counts()`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import Execution, mask_batch_select
from repro.runtime.batcher import (Batcher, Request, RequestRecord,
                                   SlotAllocator, percentile)
from repro.runtime.fault_tolerance import StragglerMonitor, resilient_step

RECURRENT_MODULES = ("xlstm", "rglru")


# ---------------------------------------------------------------------------
# closure builders — the model-facing math, shared with launch.steps
# ---------------------------------------------------------------------------

def static_prefill_closure(model, cfg, exe: Execution, *, family: str = "lm",
                           module: str = "transformer", max_seq: int,
                           cache_dtype) -> Callable:
    """(params, batch dict) -> (next_tok [B,1] int32, cache).

    The static-batch prefill math: one call covers audio (enc-dec), vlm,
    transformer and recurrent families. `launch.steps.make_prefill_step`
    jits exactly this; the engine's static A/B baseline reuses it."""
    if family == "audio":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["frames"],
                                          batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif family == "vlm":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          patch_embeds=batch["patch_embeds"],
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    elif module == "transformer":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch["tokens"], cfg, exe,
                                          max_seq=max_seq,
                                          cache_dtype=cache_dtype)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    else:
        # recurrent families: forward-only lowering (the dry-run cells carry
        # no cache; slot-cache prefill is `model.prefill`, used by the
        # engine's per-request closure below)
        def prefill(params, batch):
            logits, _ = model.forward(params, batch["tokens"], cfg, exe)
            return jnp.argmax(logits[:, -1:], -1).astype(jnp.int32), ()
    return prefill


def static_decode_closure(model, cfg, exe: Execution) -> Callable:
    """(params, cache, tokens [B,1]) -> (next_tok [B,1] int32, cache) —
    the lockstep decode step `launch.steps.make_serve_step` jits."""
    def serve_step(params, cache, tokens):
        logits, new_cache = model.decode_step(params, cache, tokens, cfg, exe)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_cache
    return serve_step


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    """Everything one `ServeEngine.serve` run produced."""
    records: dict[int, RequestRecord]
    n_steps: int = 0               # decode batch steps executed
    n_prefills: int = 0
    idle_vectors: int = 0          # frozen decode lanes (slot-idle waste)
    prefill_pad_vectors: int = 0   # prompt-padding lanes (prefill waste)
    # useful vectors counted FROM THE DEVICE LOOP (prompt lengths at the
    # prefill call + the scan's per-step active-lane counts read back with
    # each chunk) — independent of the per-request RequestRecord
    # bookkeeping, so the two can actually disagree if the engine double-
    # or under-counts (reconcile's job)
    observed_vectors: int = 0
    wall_prefill_s: float = 0.0
    wall_decode_s: float = 0.0
    makespan_s: float = 0.0        # engine clock: last retirement - start
    retries: int = 0
    stragglers: list = dataclasses.field(default_factory=list)
    # drift-aware serving books (runtime.health / runtime.chaos): probes
    # run, faults fired, hot recalibrations performed — and the extra
    # CM_INITIALIZE device writes they charged (NEVER silent; reconciled by
    # health.reconcile_recal against reprogram_counts recomputed from
    # shapes). wall_health_s is the probe+repair wall, billed apart from
    # decode so chunk timing stays honest under recovery.
    probes: int = 0
    n_recals: int = 0
    recal_initialize: int = 0
    recal_events: list = dataclasses.field(default_factory=list)
    fault_events: list = dataclasses.field(default_factory=list)
    wall_health_s: float = 0.0
    # paged serving books (DESIGN.md §15; defaults keep dense runs
    # untouched): prefix reuse, chunked-prefill legs, and the page-pool
    # ledger snapshot taken at finish() — every page attributed to exactly
    # one owner or the free list (`page_ledger_exact` is the allocator's
    # exact-partition verify()).
    # capacity-overflow rotation books (core.placement / DESIGN.md §16):
    # state swaps performed, the CM_INITIALIZE writes they charged (per
    # `AimcProgram.reprogram_counts` on each swap's incoming group —
    # reconciled exactly by placement.reconcile_swaps), and the host wall
    # spent swapping (billed apart from decode, overlap-exempt like
    # wall_health_s).
    n_swaps: int = 0
    swap_initialize: int = 0
    swap_events: list = dataclasses.field(default_factory=list)
    wall_swap_s: float = 0.0
    prefix_hits: int = 0           # admissions that reused >= 1 page/snapshot
    prefix_hit_vectors: int = 0    # prompt vectors NOT re-prefilled (shared span)
    prefill_chunks: int = 0        # prefill legs executed
    page_evictions: int = 0
    page_ledger: dict = dataclasses.field(default_factory=dict)
    page_ledger_exact: bool = True
    prefix_stats: dict = dataclasses.field(default_factory=dict)

    @property
    def useful_vectors(self) -> int:
        return sum(r.vectors for r in self.records.values())

    @property
    def generated_tokens(self) -> int:
        return sum(len(r.tokens) for r in self.records.values())

    def tokens(self, rid: int) -> list[int]:
        return self.records[rid].tokens

    def latency_percentiles(self, qs=(50, 99)) -> dict[str, float]:
        lats = [r.latency for r in self.records.values()]
        ttfts = [r.ttft for r in self.records.values()]
        out = {}
        for q in qs:
            out[f"p{q}_latency_s"] = percentile(lats, q)
            out[f"p{q}_ttft_s"] = percentile(ttfts, q)
        return out

    def summary(self) -> str:
        gen = self.generated_tokens
        wall = self.wall_prefill_s + self.wall_decode_s
        pct = self.latency_percentiles()
        return (f"{len(self.records)} requests, {gen} tokens in "
                f"{self.makespan_s:.2f}s engine-time ({gen / max(wall, 1e-9):.1f}"
                f" tok/s compute; {self.n_prefills} prefills, {self.n_steps} "
                f"decode steps, {self.idle_vectors} idle lanes); "
                f"p50/p99 latency {pct['p50_latency_s']:.2f}/"
                f"{pct['p99_latency_s']:.2f}s")


@dataclasses.dataclass
class EngineSession:
    """Host-side state of one in-flight serving run.

    Owned by a `ServeEngine`, created by `ServeEngine.begin()`; every field
    the old monolithic `serve()` loop kept as a local lives here so an
    external driver (`runtime.server.ModelServer`) can interleave sessions
    of SEVERAL engines under one clock. Device buffers (``cache``,
    ``tok_buf``, ``state``) are reassigned by `admit`/`step` (insert
    donates), so a session must only ever be driven by its own engine's
    primitives. ``state`` is the DEVICE-resident per-lane retirement rows
    ({active, gen, pos, max_new}, each [n_slots]) — the host never
    rebuilds the active mask; it only mirrors retirement decisions read
    back with each chunk's ys."""
    report: ServeReport
    slots: SlotAllocator
    slot_rec: dict[int, RequestRecord]    # slot -> live record
    cache: object
    tok_buf: object
    state: object                          # device retirement rows (see above)
    retries0: int                          # lifetime counters at begin()
    flagged0: int
    # host-side projection of each busy lane's remaining length/cap budget
    # (slot -> steps). The chunk dispatcher picks the largest compiled
    # ladder length that some lane can still use — EOS may retire a lane
    # earlier than projected (bounded waste), never later.
    rem: dict[int, int] = dataclasses.field(default_factory=dict)
    # (record, first-token device handle) pairs whose prefill result the
    # host has NOT read yet: with no EOS configured nothing about admission
    # depends on the token's value, so the read defers to the next chunk
    # sync instead of stalling the host behind an in-flight chunk.
    lazy: list = dataclasses.field(default_factory=list)
    # paged serving (DESIGN.md §15): queued chunked-prefill jobs (FIFO, one
    # leg advanced per serve-loop iteration), the pages each busy slot holds
    # as (owned pids, shared-hit pids), and the prefix-cache counters at
    # begin() so the report shows THIS session's hits/evictions only.
    jobs: list = dataclasses.field(default_factory=list)
    slot_pages: dict = dataclasses.field(default_factory=dict)
    evictions0: int = 0
    hits0: int = 0
    misses0: int = 0


# traced retirement codes emitted by the decode scan (0 = still running);
# priority eos > length > cap, matching the pre-chunk host loop
_REASONS = {1: "length", 2: "eos", 3: "cap"}


@dataclasses.dataclass
class _PendingChunk:
    """One in-flight decode chunk: the scan's device outputs plus the
    dispatch-time clock marks `_process_chunk` needs to bill wall time
    without double-counting admissions that overlap the chunk."""
    ys: tuple          # (toks [n,S], active [n,S], reason [n,S])
    t_wall: float      # perf_counter at dispatch
    prefill0: float    # report.wall_prefill_s at dispatch
    n: int             # dispatched chunk length (a ladder size)
    health0: float = 0.0   # report.wall_health_s at dispatch (overlap bill)
    recals0: int = 0       # report.n_recals at dispatch (straggler exemption)
    swap0: float = 0.0     # report.wall_swap_s at dispatch (overlap bill)
    swaps0: int = 0        # report.n_swaps at dispatch (straggler exemption)


@dataclasses.dataclass
class _PrefillJob:
    """One admitted request whose (chunked) prefill has not finished.

    The remaining legs either drain synchronously at `admit` (``drain=True``
    — external drivers need admission to complete before they hand the
    clock elsewhere) or run one per serve-loop iteration interleaved with
    decode chunks (`_advance_prefill`). The slot is held for the job's whole
    life — its decode lane stays inactive on device — until `_finalize_job`
    registers the page table row / recurrent state and arms the lane."""
    req: Request
    rec: RequestRecord
    slot: int
    legs: list                # [(pos0, span, tokens [1, C])] in order
    leg_i: int = 0
    pt_row: object = None     # transformer: device [M] int32 page-table row
    keys: list = dataclasses.field(default_factory=list)
    f_eff: int = 0            # pages reused from the prefix cache
    carry: object = None      # recurrent: carried state between legs
    tok1: object = None       # [1,1] first-token handle from the last leg

    @property
    def done(self) -> bool:
        return self.leg_i >= len(self.legs)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching serving engine over one installed model.

    Owns: the (program-installed) parameter tree, the slot-shaped decode
    cache, and the three jitted closures. Drives: admission (`Batcher`),
    slot allocation, retirement, refill, per-request accounting.

    ``params`` should already carry installed `AimcLinearState`s when
    serving the programmed AIMC path (``program.install(params)``); pass
    the `AimcProgram` as ``program`` for CM_* ledger reconciliation.
    """

    def __init__(self, model, cfg, exe: Execution, params, *,
                 n_slots: int = 4, prompt_pad: int = 16, max_seq: int = 64,
                 cache_dtype=jnp.float32, family: str = "lm",
                 module: str = "transformer", program=None, schedule=None,
                 eos_id: int | None = None, pad_id: int = 0,
                 max_retries: int = 2, straggler_threshold: float = 3.0,
                 admission: str = "fifo", decode_chunk: int = 1,
                 health=None, chaos=None, heartbeat=None,
                 page_size: int = 0, n_pages: int = 0,
                 prefix_cache: bool = False, prefill_chunk: int = 0,
                 rotation=None, rotation_params=None):
        if family == "audio":
            raise ValueError("ServeEngine serves decoder-only LMs; the "
                             "enc-dec audio family decodes via launch.steps")
        if prompt_pad > max_seq:
            raise ValueError(f"prompt_pad {prompt_pad} > max_seq {max_seq}")
        if family == "vlm" and prompt_pad < cfg.n_patches:
            raise ValueError(
                f"vlm prompts start with {cfg.n_patches} patch positions; "
                f"prompt_pad {prompt_pad} cannot hold them")
        self.model, self.cfg, self.exe, self.params = model, cfg, exe, params
        self.n_slots, self.prompt_pad, self.max_seq = n_slots, prompt_pad, max_seq
        self.cache_dtype = cache_dtype
        self.family, self.module = family, module
        self.program, self.schedule = program, schedule
        self.eos_id, self.pad_id = eos_id, pad_id
        self.admission = admission
        if decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {decode_chunk}")
        self.decode_chunk = decode_chunk
        self._ladder = self._chunk_ladder(decode_chunk)
        self.recurrent = module in RECURRENT_MODULES

        # ---- paged KV / prefix cache / chunked prefill (DESIGN.md §15) ----
        if page_size < 0 or n_pages < 0 or prefill_chunk < 0:
            raise ValueError("page_size / n_pages / prefill_chunk >= 0")
        if (prefix_cache or prefill_chunk) and page_size == 0:
            raise ValueError("prefix_cache / prefill_chunk require "
                             "page_size > 0")
        if page_size > max_seq:
            raise ValueError(f"page_size {page_size} > max_seq {max_seq}")
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        # _paged_kv: the slot KV cache lives in a page pool behind a traced
        # page table (transformer families). _use_legs: prefill runs as
        # `prefill_chunk`-wide legs writing pages directly (needed by both
        # the prefix cache and chunked prefill). Recurrent archs have O(1)
        # state, so "paging" means snapshot pages (_snap) + chunked legs
        # (_legs_rec) instead of a paged decode cache.
        self._paged_kv = page_size > 0 and not self.recurrent
        self._legs_rec = (self.recurrent and page_size > 0
                          and (prefix_cache or prefill_chunk > 0))
        self._snap = self._legs_rec and prefix_cache
        self._use_legs = self._paged_kv and (prefix_cache or prefill_chunk > 0)
        self._chunked = prefill_chunk > 0
        if self._paged_kv and module != "transformer":
            raise ValueError(f"paged KV serves the transformer module; "
                             f"got {module!r}")
        self._pt_width = -(-max_seq // page_size) if self._paged_kv else 0
        if self._use_legs:
            if family == "vlm":
                raise ValueError(
                    "prefix_cache / prefill_chunk cannot serve vlm (patch "
                    "embeds cannot ride a chunked prefill leg)")
            if getattr(cfg, "is_moe", False):
                raise ValueError(
                    "prefix_cache / prefill_chunk cannot serve MoE models: "
                    "capacity-factor routing mixes positions, so a chunked "
                    "prefill is not bit-equal to the dense one")
            if cache_dtype != jnp.float32:
                raise ValueError(
                    "prefix_cache / prefill_chunk require cache_dtype "
                    "float32: a page read back by a sharer must be bit-"
                    "identical to what the producing leg computed")
        if self._snap and self._chunked and prefill_chunk % page_size:
            raise ValueError(
                "recurrent prefix_cache requires prefill_chunk to be a "
                "multiple of page_size (snapshots are taken at leg ends, "
                "which must land on page boundaries)")
        self.pages = None
        self.prefix = None
        self._pool = None        # engine-lifetime (kp, vp) pool handles
        self._pool_snap = None   # engine-lifetime recurrent snapshot pool
        if self._paged_kv or self._snap:
            from repro.runtime.pages import PageAllocator, PrefixCache
            if n_pages == 0:
                n_pages = (n_slots * self._pt_width + 1
                           + (self._pt_width if prefix_cache else 0)
                           if self._paged_kv
                           else 1 + n_slots * max(1, prompt_pad // page_size))
            if self._paged_kv and n_pages < self._pt_width + 1:
                raise ValueError(
                    f"n_pages {n_pages} cannot hold one max-length request "
                    f"({self._pt_width} pages + scratch): admission would "
                    f"deadlock on an empty engine")
            self.pages = PageAllocator(n_pages, page_size)
            self.prefix = PrefixCache(self.pages) if prefix_cache else None
        # leg width: transformer legs default to one full-prompt leg;
        # recurrent legs to one page (snapshot boundaries = leg ends)
        self._leg_c = ((prefill_chunk or prompt_pad) if not self.recurrent
                       else (prefill_chunk or page_size))

        self.monitor = StragglerMonitor(threshold=straggler_threshold)
        self._retries = 0
        self._step_no = 0          # engine-lifetime decode step counter
        self._chunks_dispatched = 0  # lifetime chunk counter (chaos clock)
        # drift-aware serving (DESIGN.md §14): a `runtime.health.
        # HealthMonitor` evolves the installed states with program age,
        # probes them at chunk boundaries, and hot-reprograms failing
        # cores; a `runtime.chaos.FaultInjector` fires deterministic
        # kill/corrupt events on the chunk-dispatch clock. Both act ONLY
        # between chunks (`_resilience_tick`), so in-flight requests are
        # never touched. `heartbeat` (fault_tolerance.Heartbeat) makes the
        # loop's liveness visible to an external supervisor.
        self.health, self.chaos, self.heartbeat = health, chaos, heartbeat
        if health is not None:
            if program is None:
                raise ValueError("health monitoring requires an AimcProgram")
            if tuple(health.program.names) != tuple(program.names):
                raise ValueError("health monitor was built for a different "
                                 "program (matrix names mismatch)")
        if chaos is not None and health is None:
            raise ValueError("chaos injection requires a HealthMonitor to "
                             "detect and repair the faults it fires")

        # ---- capacity-overflow rotation (core.placement, DESIGN.md §16) ----
        # A `RotationPlan` time-multiplexes analog layer groups through a
        # tile budget the model exceeds: the engine holds ONE uncapped
        # program plus one installed parameter tree PER rotation state
        # (`AimcProgram.install_subset` — layers outside a state serve
        # digitally from the raw weights), and `_placement_tick` advances
        # the state at chunk boundaries, billing each swap's incoming
        # group as CM_INITIALIZE. Different states install different
        # leaves (different treedefs), so each state compiles its own
        # prefill/decode executables — ALL warmed in `warmup`.
        self.rotation = rotation
        self._rotation_params = (tuple(rotation_params)
                                 if rotation_params is not None else None)
        self._rot_state = 0
        self._swaps_done = 0
        if rotation is not None:
            if program is None:
                raise ValueError("rotation serving requires the backing "
                                 "AimcProgram (swap billing is shape-based)")
            if (self._rotation_params is None
                    or len(self._rotation_params) != rotation.n_states):
                got = (len(self._rotation_params)
                       if self._rotation_params is not None else None)
                raise ValueError(
                    f"rotation needs one installed parameter tree per "
                    f"state ({rotation.n_states}), got {got}")
            if health is not None or chaos is not None:
                raise ValueError(
                    "rotation cannot combine with health/chaos: a hot "
                    "recal would repair only the current state's tree")
            if prefix_cache or prefill_chunk:
                raise ValueError(
                    "rotation cannot combine with prefix_cache / "
                    "prefill_chunk: a cached span replayed under a "
                    "different rotation state would not be bit-stable")
            self.params = self._rotation_params[0]

        # per-leaf batch axes of the decode cache (probed, not hardcoded:
        # transformer KV stacks batch at axis 1, recurrent state trees too,
        # but "len" and any future leaf may differ — shape-diffing two
        # abstract init_cache calls finds the axis without model knowledge)
        self._axes = self._probe_batch_axes()
        self._build_closures(max_retries)

    @staticmethod
    def _chunk_ladder(k: int) -> tuple[int, ...]:
        """The compiled chunk lengths: every power of two up to ``k``, plus
        ``k`` itself. ALL ladder lengths compile at warmup; the dispatcher
        then picks per chunk (`_pick_chunk`), so serving never recompiles
        whatever mix of lengths a ragged trace needs."""
        ladder = {1, k}
        p = 2
        while p < k:
            ladder.add(p)
            p *= 2
        return tuple(sorted(ladder))

    def _build_closures(self, max_retries: int):
        """Compile the device closures. `ShardedServeEngine` overrides
        this to pin every input/output to a mesh placement; the math
        (`_prefill_fn`/`_insert_fn`/`_decode_fn`) is shared verbatim."""
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn,
                                   donate_argnums=(0, 2, 4))
        decode_fn = self._decode_fn
        if self._paged_kv:
            decode_fn = self._decode_paged_fn
            self._jit_insert_paged = jax.jit(self._insert_paged_fn,
                                             donate_argnums=(0, 2, 4))
        if self._use_legs:
            self._jit_leg = jax.jit(self._leg_fn, donate_argnums=(2, 3))
            self._jit_register = jax.jit(self._register_fn,
                                         donate_argnums=(0, 1, 2, 4))
        if self._legs_rec:
            self._jit_leg_rec = jax.jit(self._leg_rec_fn,
                                        donate_argnums=(1,))
        if self._snap:
            self._jit_snap_put = jax.jit(self._snap_put_fn,
                                         donate_argnums=(0,))
            self._jit_snap_get = jax.jit(self._snap_get_fn)
        # the decode cache is NOT donated: the step runs under
        # resilient_step, and a retry after a transient failure must be able
        # to re-present the same input buffers (donation would have
        # invalidated them on the failed attempt)
        self._decode_jits = {
            n: jax.jit(functools.partial(decode_fn, length=n))
            for n in self._ladder}
        self._safe_decodes = {
            n: resilient_step(f, max_retries=max_retries,
                              on_retry=lambda attempt, e: self._count_retry())
            for n, f in self._decode_jits.items()}

    # -- closures ------------------------------------------------------------
    def _probe_batch_axes(self):
        def shapes(b):
            return jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, b, self.max_seq, self.cache_dtype))

        def axis_of(s2, s3):
            for i, (a, b) in enumerate(zip(s2.shape, s3.shape)):
                if a != b:
                    return i
            raise ValueError(f"no batch axis found in cache leaf {s2}")

        return jax.tree.map(axis_of, shapes(2), shapes(3))

    def _prefill_fn(self, params, tokens, valid_len):
        """[1, prompt_pad] ragged prefill -> (first_tok [1,1], cache1)."""
        kw = {}
        if self.family == "vlm":
            # patch positions are a prompt prefix; the engine serves the
            # text path with zero patch embeddings unless a request-level
            # frontend supplies them (frontend-stub rule)
            kw["patch_embeds"] = jnp.zeros(
                (tokens.shape[0], self.cfg.n_patches, self.cfg.d_model),
                jnp.float32)
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.exe, max_seq=self.max_seq,
            cache_dtype=self.cache_dtype, valid_len=valid_len, **kw)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        return tok, cache

    def _insert_fn(self, cache, cache1, tok_buf, tok1, state, slot, pos0,
                   max_new):
        """Write a prefilled request's state into decode lane ``slot`` —
        including the lane's on-device retirement row (active flag,
        generated-token count, KV position, decode budget), so the decode
        closure never needs a host-built mask."""
        def put(big, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot, axis=ax)

        cache = jax.tree.map(put, cache, cache1, self._axes)
        tok_buf = jax.lax.dynamic_update_slice(tok_buf, tok1, (slot, 0))
        # gen starts at 1: the prefill's first token counts against max_new
        state = {"active": state["active"].at[slot].set(True),
                 "gen": state["gen"].at[slot].set(1),
                 "pos": state["pos"].at[slot].set(pos0),
                 "max_new": state["max_new"].at[slot].set(max_new)}
        return cache, tok_buf, state

    def _decode_fn(self, params, cache, tok_buf, state, length):
        """``length`` dense decode steps in ONE jitted `lax.scan`; inactive
        lanes are bit-frozen. Retirement predicates (max_new / EOS /
        max_seq cap) are traced, so the active mask and per-lane counters
        never leave the device mid-chunk. ``length`` is host-chosen per
        dispatch from the COMPILED LADDER (`_chunk_ladder`): the host
        mirrors every lane's length/cap budget exactly, so it picks the
        largest ladder length no greater than the longest remaining budget
        — a chunk never runs past the last live lane (the fixed-k variant
        over-ran ragged traces by 2-3x decode steps at k=8), and the
        device needs no early-exit predicate (an `active.any()` loop
        condition would be a per-token cross-device collective).

        Returns (tok_buf, cache, state, ys) with per-step chunk outputs
        ys = (toks [length,S], active-at-entry [length,S], reason
        [length,S]). The per-step busy count is NOT reduced on device:
        `active.sum()` would be the only other cross-device collective in
        the data-sharded loop (one all-reduce per token) — the host pops
        it from the ``active`` rows it reads back anyway. ys rides outside
        ``state`` because a subsequent insert donates the state buffers
        while a chunk's readback may still be pending (double-buffered
        serve)."""
        def one_step(carry, _):
            cache, tokens, st = carry
            active = st["active"]
            if self.module == "transformer":
                logits, new_cache = self.model.decode_step(
                    params, cache, tokens, self.cfg, self.exe, ragged=True)
            else:
                logits, new_cache = self.model.decode_step(
                    params, cache, tokens, self.cfg, self.exe)
            new_cache = jax.tree.map(
                lambda n, o, ax: mask_batch_select(n, o, active, ax),
                new_cache, cache, self._axes)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            tok = jnp.where(active[:, None], tok, tokens)
            emitted = tok[:, 0]
            gen = st["gen"] + active.astype(jnp.int32)
            pos = st["pos"] + active.astype(jnp.int32)
            done_len = gen >= st["max_new"]
            done_eos = (jnp.zeros_like(active) if self.eos_id is None
                        else emitted == jnp.int32(self.eos_id))
            # the KV write position is bounded by max_seq; O(1)-state
            # recurrent archs have no such cap
            done_cap = (jnp.zeros_like(active) if self.recurrent
                        else pos >= jnp.int32(self.max_seq))
            reason = jnp.where(done_eos, 2, jnp.where(done_len, 1,
                               jnp.where(done_cap, 3, 0))).astype(jnp.int32)
            reason = jnp.where(active, reason, 0)
            new_st = {"active": active & (reason == 0), "gen": gen,
                      "pos": pos, "max_new": st["max_new"]}
            return (new_cache, tok, new_st), (emitted, active, reason)

        (cache, tok_buf, state), ys = jax.lax.scan(
            one_step, (cache, tok_buf, state), None, length=length)
        return tok_buf, cache, state, ys

    # -- paged closures (DESIGN.md §15) --------------------------------------
    @staticmethod
    def _paged_axes():
        """Per-leaf data axes of the paged cache dict: the pools split at
        their page axis, the table and lengths at the slot axis."""
        return {"kp": 1, "vp": 1, "pt": 0, "len": 0}

    def _insert_paged_fn(self, cache, cache1, tok_buf, tok1, state, slot,
                         pos0, max_new, pt_row, write_mask):
        """Mode-A paged insert: scatter a DENSE [1, max_seq] prefill cache
        into this request's pages and point the slot's page-table row at
        them. ``write_mask`` keeps only the first n_alloc table entries
        (the pages actually allocated — rows past the request's reach hold
        prompt padding never read); masked-off writes route to SCRATCH."""
        p, m = self.page_size, self._pt_width
        n_rows = m * p

        def to_pages(leaf, pool):
            x = leaf[:, 0].astype(pool.dtype)      # [L, max_seq, H, hd]
            if n_rows != self.max_seq:
                pad = [(0, 0)] * x.ndim
                pad[1] = (0, n_rows - self.max_seq)
                x = jnp.pad(x, pad)
            return x.reshape(x.shape[0], m, p, *x.shape[2:])

        pids = jnp.where(write_mask, pt_row, 0)
        kp = cache["kp"].at[:, pids].set(to_pages(cache1["k"], cache["kp"]))
        vp = cache["vp"].at[:, pids].set(to_pages(cache1["v"], cache["vp"]))
        pt = jax.lax.dynamic_update_slice(cache["pt"], pt_row[None, :],
                                          (slot, 0))
        lens = cache["len"].at[slot].set(pos0)
        tok_buf = jax.lax.dynamic_update_slice(tok_buf, tok1, (slot, 0))
        state = {"active": state["active"].at[slot].set(True),
                 "gen": state["gen"].at[slot].set(1),
                 "pos": state["pos"].at[slot].set(pos0),
                 "max_new": state["max_new"].at[slot].set(max_new)}
        return {"kp": kp, "vp": vp, "pt": pt, "len": lens}, tok_buf, state

    def _register_fn(self, pt, lens, tok_buf, tok1, state, slot, pos0,
                     max_new, pt_row):
        """Arm a slot whose pages were filled in place by prefill LEGS
        (`_leg_fn`): only the small per-slot leaves change — the pools are
        not even passed through, so nothing copies them."""
        pt = jax.lax.dynamic_update_slice(pt, pt_row[None, :], (slot, 0))
        lens = lens.at[slot].set(pos0)
        tok_buf = jax.lax.dynamic_update_slice(tok_buf, tok1, (slot, 0))
        state = {"active": state["active"].at[slot].set(True),
                 "gen": state["gen"].at[slot].set(1),
                 "pos": state["pos"].at[slot].set(pos0),
                 "max_new": state["max_new"].at[slot].set(max_new)}
        return pt, lens, tok_buf, state

    def _leg_fn(self, params, tokens, kp, vp, pt_row, pos0, span):
        """One transformer prefill leg writing straight into pages."""
        return self.model.prefill_chunk(
            params, tokens, self.cfg, self.exe, kp, vp, pt_row, pos0, span,
            page_size=self.page_size, context_len=self.prompt_pad)

    def _leg_rec_fn(self, params, cache, tokens, span):
        """One recurrent prefill leg advancing a carried [1, ...] state."""
        logits, cache = self.model.prefill_chunk(
            params, cache, tokens, self.cfg, self.exe, span)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        return tok, cache

    def _snap_put_fn(self, pool, cache1, pid):
        """Store a [1, ...] recurrent state into snapshot page ``pid``."""
        def put(big, one, ax):
            return jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), pid, axis=ax)
        return jax.tree.map(put, pool, cache1, self._axes)

    def _snap_get_fn(self, pool, pid):
        """Read snapshot page ``pid`` back as a [1, ...] state tree."""
        return jax.tree.map(
            lambda big, ax: jax.lax.dynamic_slice_in_dim(big, pid, 1,
                                                         axis=ax),
            pool, self._axes)

    def _decode_paged_fn(self, params, cache, tok_buf, state, length):
        """The paged twin of `_decode_fn`: same scanned retirement machine,
        but the KV cache is a page pool behind a traced page table.

        Per step: gather the table into a dense [S, max_seq] VIEW (pure
        indexing — `transformer.paged_view`), run the IDENTICAL ragged
        `decode_step`, then scatter each lane's one written row back to its
        page. The view rows a lane actually attends to were produced by the
        same ops as the dense cache rows (prefill or a previous readback-
        identical scatter), and `decode_attention` masks pre-softmax, so
        decode is BIT-EQUAL to the dense engine. Inactive lanes scatter to
        the reserved SCRATCH page — the paged twin of `mask_batch_select`'s
        bit-freeze (their table rows may be stale after retirement; scratch
        absorbs the write and the gathered view is masked by ``len``)."""
        pt = cache["pt"]
        p = self.page_size
        rows = jnp.arange(self.n_slots)

        def one_step(carry, _):
            kp, vp, lens, tokens, st = carry
            active = st["active"]
            k_view, v_view = self.model.paged_view(kp, vp, pt, self.max_seq)
            dense = {"k": k_view, "v": v_view, "len": lens}
            logits, new_cache = self.model.decode_step(
                params, dense, tokens, self.cfg, self.exe, ragged=True)
            # the one row decode_step wrote, per lane (its pre-step length)
            row = jnp.clip(lens, 0, self.max_seq - 1)
            k_row = new_cache["k"][:, rows, row]      # [L, S, H, hd]
            v_row = new_cache["v"][:, rows, row]
            pid = jnp.take_along_axis(pt, (row // p)[:, None], axis=1)[:, 0]
            spid = jnp.where(active, pid, 0)          # inactive -> SCRATCH
            soff = jnp.where(active, row % p, 0)
            kp = kp.at[:, spid, soff].set(k_row)
            vp = vp.at[:, spid, soff].set(v_row)
            lens = jnp.where(active, new_cache["len"], lens)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            tok = jnp.where(active[:, None], tok, tokens)
            emitted = tok[:, 0]
            gen = st["gen"] + active.astype(jnp.int32)
            pos = st["pos"] + active.astype(jnp.int32)
            done_len = gen >= st["max_new"]
            done_eos = (jnp.zeros_like(active) if self.eos_id is None
                        else emitted == jnp.int32(self.eos_id))
            done_cap = pos >= jnp.int32(self.max_seq)
            reason = jnp.where(done_eos, 2, jnp.where(done_len, 1,
                               jnp.where(done_cap, 3, 0))).astype(jnp.int32)
            reason = jnp.where(active, reason, 0)
            new_st = {"active": active & (reason == 0), "gen": gen,
                      "pos": pos, "max_new": st["max_new"]}
            return (kp, vp, lens, tok, new_st), (emitted, active, reason)

        (kp, vp, lens, tok_buf, state), ys = jax.lax.scan(
            one_step, (cache["kp"], cache["vp"], cache["len"], tok_buf,
                       state), None, length=length)
        return tok_buf, {"kp": kp, "vp": vp, "pt": pt, "len": lens}, \
            state, ys

    # -- warmup / compile accounting ----------------------------------------
    @staticmethod
    def _commit_ambient(tree):
        """Commit creation-fresh buffers to the replicated placement of the
        ambient mesh, if one is active. Under `use_mesh`, jit OUTPUTS come
        back NamedSharding-committed while `jnp.zeros` stays uncommitted —
        and the executable cache keys on placement, so a closure fed a
        fresh buffer at session start and a jit output afterwards would
        compile twice. No ambient mesh: identity (placement is uniform)."""
        import jax.sharding as shd
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return tree
        sh = shd.NamedSharding(mesh, shd.PartitionSpec())
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, sh), tree)

    def _fresh_pools(self):
        """Zero-filled (kp, vp) page pools. Overridden by the sharded
        engine to create them on the mesh placement."""
        pools = self.model.init_paged_cache(
            self.cfg, self.pages.n_pages, self.page_size, self.cache_dtype)
        return self._commit_ambient((pools["kp"], pools["vp"]))

    def _paged_cache_dict(self, kp, vp):
        """Assemble the paged slot cache around pool handles. The SINGLE
        place pt/len are created — warmup's throwaway cache and the session
        cache must key the insert closure's jit cache identically, so the
        sharded override commits them to the mesh here."""
        return {"kp": kp, "vp": vp,
                "pt": self._commit_ambient(
                    jnp.zeros((self.n_slots, self._pt_width), jnp.int32)),
                "len": self._commit_ambient(
                    jnp.zeros((self.n_slots,), jnp.int32))}

    def _empty_cache(self):
        if self._paged_kv:
            # the pools OUTLIVE sessions (prefix pages stay resident across
            # `begin()`s); the handles move into the session here and come
            # back at `finish()` — everything else is per-session zeros
            if self._pool is None:
                self._pool = self._fresh_pools()
            kp, vp = self._pool
            self._pool = None
            return self._paged_cache_dict(kp, vp)
        return self._commit_ambient(self.model.init_cache(
            self.cfg, self.n_slots, self.max_seq, self.cache_dtype))

    def _snap_pool(self):
        """The engine-lifetime recurrent snapshot pool, lazily created (its
        leaves are the slot cache's with n_pages in the batch axis)."""
        if self._pool_snap is None:
            self._pool_snap = self._commit_ambient(self.model.init_cache(
                self.cfg, self.pages.n_pages, self.max_seq,
                self.cache_dtype))
        return self._pool_snap

    def _empty_tok_buf(self):
        """The [n_slots, 1] next-token buffer. A hook so the sharded engine
        can commit it to its mesh placement — an uncommitted buffer would
        key the insert closure's jit cache differently from the committed
        buffers later steps feed back, costing a recompile."""
        return self._commit_ambient(jnp.zeros((self.n_slots, 1), jnp.int32))

    def _empty_state(self):
        """The device-resident per-lane retirement rows, all [n_slots]:
        active mask, generated-token and KV-position counters, decode
        budget. Sharded engine override commits them to the mesh. Each leaf
        must be a DISTINCT buffer — insert donates the whole dict, and XLA
        rejects donating one buffer twice."""
        def z():
            return jnp.zeros((self.n_slots,), jnp.int32)
        return self._commit_ambient(
            {"active": jnp.zeros((self.n_slots,), bool),
             "gen": z(), "pos": z(), "max_new": z()})

    def warmup(self):
        """Compile every closure (prefill, insert, and one decode
        executable per ladder length) once, outside the serving clock.
        Under rotation, prefill/decode compile once PER rotation state
        (states install different leaves, hence different treedefs), so
        mid-trace swaps never hit the serving clock with a compile."""
        tokens = jnp.zeros((1, self.prompt_pad), jnp.int32)
        vl = jnp.ones((1,), jnp.int32)
        param_sets = self._rotation_params or (self.params,)
        for ps in param_sets:
            tok1, cache1 = self._jit_prefill(ps, tokens, vl)
        tok_buf = self._empty_tok_buf()
        state = self._empty_state()
        if self._paged_kv:
            # THROWAWAY pools: insert/leg closures DONATE their pool
            # arguments, so warming them on the engine-lifetime pool would
            # invalidate it before the first session
            kp, vp = self._fresh_pools()
            cache = self._paged_cache_dict(kp, vp)
            pt_row = jnp.zeros((self._pt_width,), jnp.int32)
            cache, tok_buf, state = self._jit_insert_paged(
                cache, cache1, tok_buf, tok1, state, jnp.int32(0),
                jnp.int32(1), jnp.int32(1), pt_row,
                jnp.zeros((self._pt_width,), bool))
            if self._use_legs:
                leg_toks = jnp.zeros((1, self._leg_c), jnp.int32)
                tokw, kp2, vp2 = self._jit_leg(
                    self.params, leg_toks, cache["kp"], cache["vp"],
                    pt_row, jnp.int32(0), jnp.int32(1))
                cache["kp"], cache["vp"] = kp2, vp2
                pt2, len2, tok_buf, state = self._jit_register(
                    cache["pt"], cache["len"], tok_buf, tokw, state,
                    jnp.int32(0), jnp.int32(1), jnp.int32(1), pt_row)
                cache["pt"], cache["len"] = pt2, len2
        else:
            cache = self._empty_cache()
            if self._legs_rec:
                c1 = self._commit_ambient(self.model.init_cache(
                    self.cfg, 1, self.max_seq, self.cache_dtype))
                leg_toks = jnp.zeros((1, self._leg_c), jnp.int32)
                tokw, c1 = self._jit_leg_rec(self.params, c1, leg_toks,
                                             jnp.int32(1))
                if self._snap:
                    # throwaway snapshot pool, same reason as above; must
                    # carry the same ambient-mesh placement as _snap_pool()
                    # or snap_put compiles twice (warmup vs serve)
                    pool = self._commit_ambient(self.model.init_cache(
                        self.cfg, self.pages.n_pages, self.max_seq,
                        self.cache_dtype))
                    pool = self._jit_snap_put(pool, c1, jnp.int32(1))
                    jax.block_until_ready(
                        self._jit_snap_get(pool, jnp.int32(1)))
                # warm insert on the LEG RUNNER's outputs: serve-time
                # finalize always inserts a leg_rec-produced carry/token,
                # whose ambient-mesh placement differs from _jit_prefill's
                # (committed vs not) and would force a second executable
                cache, tok_buf, state = self._jit_insert(
                    cache, c1, tok_buf, tokw, state, jnp.int32(0),
                    jnp.int32(1), jnp.int32(1))
            else:
                cache, tok_buf, state = self._jit_insert(
                    cache, cache1, tok_buf, tok1, state, jnp.int32(0),
                    jnp.int32(1), jnp.int32(1))
        for ps in param_sets:
            for n in self._ladder:
                tok_buf, cache, state, ys = self._decode_jits[n](
                    ps, cache, tok_buf, state)
        jax.block_until_ready(ys)
        return self.compile_counts()

    def compile_counts(self) -> dict[str, int]:
        """Executable-cache sizes of the engine closures. After `warmup`,
        serving any trace must leave prefill/insert at 1 and decode at
        ``len(self._ladder)`` (one executable per compiled chunk length,
        all warmed up front) — the shape-stability contract (pinned by
        tests/test_engine.py and tests/test_chunked_decode.py)."""
        insert = (self._jit_insert_paged if self._paged_kv
                  else self._jit_insert)
        counts = {"prefill": self._jit_prefill._cache_size(),
                  "insert": insert._cache_size(),
                  "decode": sum(f._cache_size()
                                for f in self._decode_jits.values())}
        if self._use_legs:
            counts["prefill_chunk"] = self._jit_leg._cache_size()
            counts["register"] = self._jit_register._cache_size()
        if self._legs_rec:
            counts["prefill_chunk"] = self._jit_leg_rec._cache_size()
        if self._snap:
            counts["snapshot"] = self._jit_snap_put._cache_size()
            counts["restore"] = self._jit_snap_get._cache_size()
        return counts

    def _count_retry(self):
        self._retries += 1

    # -- drift / health / chaos (DESIGN.md §14) -------------------------------
    def _set_params(self, params):
        """Swap the served parameter tree. Every update preserves shapes and
        treedef (drift gains scale s_w; reprogrammed states are
        structure-identical), so the compiled closures are reused as-is.
        The sharded engine overrides this to re-pin the mesh placement."""
        self.params = params

    def _resilience_tick(self, sess: "EngineSession", now: float) -> float:
        """Chunk-boundary resilience work: fire due chaos events, advance
        drift, probe the live states, and hot-reprogram failing cores.

        Runs on the host BETWEEN chunk dispatches — an in-flight chunk was
        dispatched against the previous parameter tree and is untouched, so
        recovery never drops or perturbs an in-flight request. All wall
        time spent here is billed to ``wall_health_s`` (and subtracted from
        the overlapping chunk's decode bill in `_process_chunk`)."""
        if self.health is None and self.chaos is None:
            return now
        from repro.core.program import installed_entries
        from repro.runtime import chaos as chaos_lib
        from repro.runtime.health import RecalEvent
        t0 = time.perf_counter()
        report = sess.report
        forced = False
        if self.chaos is not None:
            for ev in self.chaos.due(self._chunks_dispatched):
                prog = self.health.program
                mag = 1.0 if ev.kind == chaos_lib.KILL else ev.magnitude
                entries = chaos_lib.corrupt_entries(prog, ev.core, mag)
                if ev.kind == chaos_lib.KILL:
                    self.health.mark_dead(ev.core)
                if entries:
                    self._set_params(
                        prog.install_updates(self.params, entries))
                report.fault_events.append(ev)
                forced = True
        if self.health is not None and (forced or self.health.due(now)):
            drifted = self.health.drifted_entries(now)
            if drifted:
                self._set_params(
                    self.health.program.install_updates(self.params, drifted))
            live = installed_entries(self.params)
            sample = self.health.probe(live, now)
            report.probes += 1
            failing = self.health.failing_cores(sample)
            if failing:
                dead = set(failing) & self.health.dead
                t_r = time.perf_counter()
                entries, names, cm = self.health.recalibrate(failing, now)
                if names:
                    prog = self.health.program
                    self.program = prog
                    if (self.schedule is not None
                            and self.schedule.name == "from_program"):
                        from repro.core.schedule import CoreSchedule
                        self.schedule = CoreSchedule.from_program(
                            prog, pipelined=self.schedule.pipelined)
                    self._set_params(
                        prog.install_updates(self.params, entries))
                    ev = RecalEvent(
                        t=now,
                        reason=("dead_core" if dead
                                else "fault" if forced else "drift"),
                        cores=tuple(failing), names=names,
                        initialize=cm.initialize,
                        wall_s=time.perf_counter() - t_r)
                    self.health.events.append(ev)
                    report.recal_events.append(ev)
                    report.recal_initialize += cm.initialize
                    report.n_recals += 1
        wall = time.perf_counter() - t0
        report.wall_health_s += wall
        return now + wall

    # -- capacity-overflow rotation (core.placement, DESIGN.md §16) ----------
    def _placement_tick(self, sess: "EngineSession", now: float) -> float:
        """Chunk-boundary rotation swap: when the swap cadence is due,
        advance ONE rotation state, install its parameter tree, and bill
        the incoming group's reprogram as CM_INITIALIZE plus the host wall
        spent swapping.

        Swaps land BETWEEN chunk dispatches only — the in-flight chunk ran
        entirely under the previous state's tree, so no token is ever
        produced by a half-swapped program. Decode lanes are row-
        independent and every state is bit-validated against the digital
        oracle separately (`launch.serve --placement-verify`), so the
        rotation schedule never changes what a request generates."""
        rot = self.rotation
        if rot is None or rot.n_states < 2:
            return now
        due = self._chunks_dispatched // rot.swap_every
        if due <= self._swaps_done:
            return now
        from repro.core.placement import SwapEvent
        t0 = time.perf_counter()
        report = sess.report
        self._swaps_done = due
        self._rot_state = (self._rot_state + 1) % rot.n_states
        self._set_params(self._rotation_params[self._rot_state])
        incoming = rot.incoming(self._rot_state)
        cm = self.program.reprogram_counts(incoming)
        wall = time.perf_counter() - t0
        ev = SwapEvent(t=now, chunk=self._chunks_dispatched,
                       state=self._rot_state, incoming=incoming,
                       initialize=cm.initialize, wall_s=wall)
        report.swap_events.append(ev)
        report.swap_initialize += cm.initialize
        report.n_swaps += 1
        report.wall_swap_s += wall
        return now + wall

    # -- request plumbing ----------------------------------------------------
    def _pad_prompt(self, prompt):
        if len(prompt) > self.prompt_pad:
            raise ValueError(f"prompt length {len(prompt)} exceeds "
                             f"prompt_pad {self.prompt_pad}")
        if self.family == "vlm" and len(prompt) < self.cfg.n_patches:
            # positions [0, n_patches) are patch embeddings wholesale; a
            # shorter prompt would gather its "last valid" logit inside the
            # patch prefix and serve silently wrong
            raise ValueError(
                f"vlm prompt length {len(prompt)} < n_patches "
                f"{self.cfg.n_patches}: the prompt must cover the patch "
                f"positions")
        padded = list(prompt) + [self.pad_id] * (self.prompt_pad - len(prompt))
        return (jnp.asarray(padded, jnp.int32)[None],
                jnp.asarray([len(prompt)], jnp.int32))

    def _prefill_request(self, req: Request, rec: RequestRecord,
                         lazy: bool = False):
        """Run the [1, prompt_pad] prefill; book the vector counts. The
        caller decides whether the first token is delivered output (an
        instant EOS is control, not payload — `admit`). With ``lazy`` the
        host does NOT block on the result: ``first`` comes back None and
        the caller reads the token handle at the next chunk sync — the
        prefill itself queues behind any in-flight chunk on the device, so
        blocking here would stall admission on decode compute."""
        tokens, vl = self._pad_prompt(req.prompt)
        t0 = time.perf_counter()
        tok1, cache1 = self._jit_prefill(self.params, tokens, vl)
        first = None
        if not lazy:
            tok1.block_until_ready()
            first = int(tok1[0, 0])
        dt = time.perf_counter() - t0
        rec.prefill_vectors = len(req.prompt)
        rec.pad_vectors = self.prompt_pad - len(req.prompt)
        return tok1, cache1, first, dt

    # -- session primitives --------------------------------------------------
    # The serving loop decomposed into driver-steerable pieces: `serve()`
    # drives one session off a single `Batcher`; the multi-tenant
    # `runtime.server.ModelServer` drives one session PER co-resident model
    # under a shared clock with tenant-quota admission. Both produce
    # identical tokens for identical (request, admission-order) sequences —
    # the primitives only factor the loop, they never reorder it.

    def begin(self) -> "EngineSession":
        """Open a serving session: fresh slots, device buffers and books.

        Snapshots lifetime retry/straggler counters so a reused engine
        reports only THIS session's retries/flags (the EWMA baseline itself
        carries over on purpose — it stays warm across traces)."""
        px = self.prefix
        return EngineSession(
            report=ServeReport(records={}),
            slots=SlotAllocator(self.n_slots),
            slot_rec={},
            cache=self._empty_cache(),
            tok_buf=self._empty_tok_buf(),
            state=self._empty_state(),
            retries0=self._retries,
            flagged0=len(self.monitor.flagged),
            evictions0=px.evictions if px is not None else 0,
            hits0=px.hits if px is not None else 0,
            misses0=px.misses if px is not None else 0)

    @staticmethod
    def _retire(rec: RequestRecord, reason: str, at: float):
        rec.finish_reason = reason
        rec.t_done = at

    def admit(self, sess: "EngineSession", req: Request, now: float,
              drain: bool = True) -> float:
        """Admit one request at clock ``now``: prefill, book, and either
        retire at prefill (max_new=1 / instant EOS — the request never
        occupies a decode slot) or insert into a free slot. Returns the
        advanced clock. Caller guarantees ``sess.slots.n_free > 0`` (and,
        when paged, `can_admit`).

        Legged admission (prefix cache / chunked prefill) builds a
        `_PrefillJob`; with ``drain`` (default — external drivers like the
        multi-tenant server need admission to complete before the clock
        moves elsewhere) every leg runs before this returns, otherwise the
        job queues on ``sess.jobs`` and `serve()` advances one leg per loop
        iteration, interleaved with decode chunks."""
        report = sess.report
        rec = RequestRecord(request=req, t_admit=now)
        report.records[req.rid] = rec
        if req.max_new > 1 and (self._use_legs or self._legs_rec):
            return self._admit_legged(sess, req, rec, now, drain)
        # ---- dense prefill (also paged mode A: dense prefill, paged
        # insert) --------------------------------------------------------
        # with no EOS configured, NOTHING about admission depends on the
        # first token's value — defer the host read to the next chunk sync
        # so admission overlaps the in-flight chunk instead of waiting
        # behind it on the device queue
        lazy = self.eos_id is None
        tok1, cache1, first, dt = self._prefill_request(req, rec, lazy)
        now += dt
        report.wall_prefill_s += dt
        report.n_prefills += 1
        report.prefill_pad_vectors += rec.pad_vectors
        report.observed_vectors += len(req.prompt)
        rec.t_first = now
        if lazy:
            sess.lazy.append((rec, tok1))
        else:
            eos_hit = first == self.eos_id
            if not eos_hit:
                # the EOS token is control, not payload: it never lands in
                # `rec.tokens` (so generated_tokens / tok_s count delivered
                # output only), but its vector stays in the CM_* books
                rec.tokens.append(first)
            if eos_hit:
                self._retire(rec, "eos", now)
                return now
        if req.max_new == 1:
            self._retire(rec, "length", now)
            return now
        slot = sess.slots.alloc(req.rid)
        sess.slot_rec[slot] = rec
        rem = req.max_new - 1
        if not self.recurrent:
            rem = min(rem, self.max_seq - len(req.prompt))
        sess.rem[slot] = rem
        t0 = time.perf_counter()
        if self._paged_kv:
            pt_row, mask, owned = self._alloc_pt_row(req)
            sess.slot_pages[slot] = (owned, [])
            sess.cache, sess.tok_buf, sess.state = self._jit_insert_paged(
                sess.cache, cache1, sess.tok_buf, tok1, sess.state,
                jnp.int32(slot), jnp.int32(len(req.prompt)),
                jnp.int32(req.max_new), pt_row, mask)
        else:
            sess.cache, sess.tok_buf, sess.state = self._jit_insert(
                sess.cache, cache1, sess.tok_buf, tok1, sess.state,
                jnp.int32(slot), jnp.int32(len(req.prompt)),
                jnp.int32(req.max_new))
        if not lazy:
            # the blocking (EOS-aware) path bills the full prefill+insert
            # wall here; the lazy path bills dispatch only — the device
            # time lands in the next chunk's window, where it actually
            # serializes (insert chains on the in-flight chunk's outputs)
            sess.tok_buf.block_until_ready()
        ins = time.perf_counter() - t0
        now += ins
        report.wall_prefill_s += ins
        return now

    # -- paged admission (DESIGN.md §15) -------------------------------------
    def _pages_span(self, req: Request) -> tuple[int, int]:
        """(last row index + 1 this request can ever write, pages that
        cover it). Decode budget caps growth: rem is clipped at admission,
        so rows past ``end`` are never written OR read."""
        plen = len(req.prompt)
        end = plen + min(req.max_new - 1, self.max_seq - plen)
        return end, -(-end // self.page_size)

    def _alloc_pages(self, n: int, owner, protect=()):
        """``n`` pages, evicting sole-sharer prefix entries (LRU) to make
        room. Raises on a genuine shortage — `can_admit` gates callers."""
        pids = self.pages.alloc(n, owner=owner)
        if pids is None and self.prefix is not None:
            self.prefix.evict(n - self.pages.n_free, protect=protect)
            pids = self.pages.alloc(n, owner=owner)
        if pids is None:
            raise RuntimeError(
                f"page pool exhausted: {owner} needs {n} pages, "
                f"{self.pages.n_free} free (gate admission on can_admit)")
        return pids

    def _alloc_pt_row(self, req: Request):
        """Mode-A page grab: all pages owned, no sharing."""
        from repro.runtime.pages import SCRATCH
        _, n_alloc = self._pages_span(req)
        owned = self._alloc_pages(n_alloc, req.rid)
        row = owned + [SCRATCH] * (self._pt_width - n_alloc)
        mask = [j < n_alloc for j in range(self._pt_width)]
        return (jnp.asarray(row, jnp.int32), jnp.asarray(mask, bool), owned)

    def _peek_prefix(self, prompt) -> tuple[int, list]:
        """(f_eff, hit pids) a transformer admission WOULD reuse: the
        longest consecutive run of resident full pages, capped so the
        continuation keeps >= 1 token. Non-perturbing (LRU/stats untouched)
        — `can_admit` probes feasibility without committing."""
        if self.prefix is None or not self._use_legs:
            return 0, []
        from repro.runtime.pages import page_keys
        got = self.prefix.lookup(page_keys(prompt, self.page_size),
                                 peek=True)
        f = 0
        while f < len(got) and got[f] is not None:
            f += 1
        f = min(f, (len(prompt) - 1) // self.page_size)
        return f, got[:f]

    def pages_needed(self, req: Request) -> int:
        """Pages an admission would NEWLY allocate — the tenant-quota unit
        (shared prefix pages are not billed to their sharers)."""
        if not self._paged_kv or req.max_new <= 1:
            return 0
        _, n_alloc = self._pages_span(req)
        f_eff, _ = self._peek_prefix(req.prompt)
        return n_alloc - f_eff

    def can_admit(self, sess: "EngineSession", req: Request) -> bool:
        """Whether the page pool can cover ``req`` right now (free pages
        plus cache-only entries admission may evict, minus the hit pages
        about to gain a sharer — those must not count as reclaimable).
        Unpaged / recurrent engines always admit: snapshot pages are
        best-effort, never required."""
        if not self._paged_kv or req.max_new <= 1:
            return True
        _, n_alloc = self._pages_span(req)
        f_eff, hit_pids = self._peek_prefix(req.prompt)
        have = self.pages.n_free
        if self.prefix is not None:
            have += self.prefix.evictable(protect=hit_pids)
        return have >= n_alloc - f_eff

    def tenant_pages(self, sess: "EngineSession",
                     tenant_of: dict | None = None) -> dict:
        """tenant -> pages currently held as owner across busy slots (the
        quota view `runtime.server` charges against). ``tenant_of`` maps
        rid -> tenant (the server's view); without it requests fall under
        one anonymous tenant."""
        held: dict = {}
        for slot, (owned, _shared) in sess.slot_pages.items():
            rec = sess.slot_rec.get(slot)
            if rec is None:
                continue
            t = (tenant_of.get(rec.request.rid) if tenant_of is not None
                 else None)
            held[t] = held.get(t, 0) + len(owned)
        return held

    def _admit_legged(self, sess: "EngineSession", req: Request,
                      rec: RequestRecord, now: float, drain: bool) -> float:
        """Admission via prefill legs: look up the shared prefix, allocate
        the continuation's pages (transformer) or restore the deepest
        snapshot (recurrent), split the rest of the prompt into legs, and
        run them (now, or interleaved — see `admit`)."""
        from repro.runtime.pages import SCRATCH, page_keys
        report = sess.report
        p, c = self.page_size, self._leg_c
        prompt = list(req.prompt)
        plen = len(prompt)
        if plen > self.prompt_pad:
            raise ValueError(f"prompt length {plen} exceeds "
                             f"prompt_pad {self.prompt_pad}")
        keys = page_keys(prompt, p) if self.prefix is not None else []
        start, f_eff, pids_hit, carry, pt_row, owned = 0, 0, [], None, None, []
        if self._use_legs:
            if self.prefix is not None:
                got = self.prefix.lookup(keys)
                while f_eff < len(got) and got[f_eff] is not None:
                    f_eff += 1
                # cap so the continuation keeps >= 1 real token (the legs
                # must produce the first-token logits)
                f_eff = min(f_eff, (plen - 1) // p)
                pids_hit = [got[j] for j in range(f_eff)]
                for pid in pids_hit:
                    self.pages.retain(pid)     # sharer refs FIRST: eviction
                                               # below must not free them
            start = f_eff * p
            _, n_alloc = self._pages_span(req)
            try:
                owned = self._alloc_pages(n_alloc - f_eff, req.rid,
                                          protect=pids_hit)
            except RuntimeError:
                for pid in pids_hit:
                    self.pages.release(pid)
                raise
            row = pids_hit + owned + [SCRATCH] * (self._pt_width - n_alloc)
            pt_row = jnp.asarray(row, jnp.int32)
        else:
            # recurrent: ONE snapshot page restores the whole state at a
            # page boundary — take the deepest resident one
            hit_j = -1
            if self.prefix is not None:
                got = self.prefix.lookup(keys)
                for j in range(min(len(got), (plen - 1) // p)):
                    if got[j] is not None:
                        hit_j = j
            if hit_j >= 0:
                carry = self._jit_snap_get(self._snap_pool(),
                                           jnp.int32(got[hit_j]))
                start = (hit_j + 1) * p
                f_eff = hit_j + 1
            else:
                carry = self._commit_ambient(self.model.init_cache(
                    self.cfg, 1, self.max_seq, self.cache_dtype))
        legs, pos = [], start
        while pos < plen:
            span = min(c, plen - pos)
            toks = prompt[pos:pos + span] + [self.pad_id] * (c - span)
            legs.append((pos, span, jnp.asarray(toks, jnp.int32)[None]))
            pos += span
        report.n_prefills += 1
        if start:
            report.prefix_hits += 1
            report.prefix_hit_vectors += start
        slot = sess.slots.alloc(req.rid)
        sess.slot_rec[slot] = rec
        if self._use_legs:
            sess.slot_pages[slot] = (owned, pids_hit)
        job = _PrefillJob(req=req, rec=rec, slot=slot, legs=legs,
                          pt_row=pt_row, keys=keys, f_eff=f_eff, carry=carry)
        if drain or not self._chunked:
            while not job.done:
                now = self._advance_leg(sess, job, now)
        else:
            sess.jobs.append(job)
        return now

    def _advance_leg(self, sess: "EngineSession", job: _PrefillJob,
                     now: float) -> float:
        """Run ONE prefill leg; finalize the job after its last. Vector
        books advance per leg (not at admission) so an aborted job's record
        matches exactly what the device observed."""
        report = sess.report
        pos0, span, toks = job.legs[job.leg_i]
        t0 = time.perf_counter()
        if self._use_legs:
            tok1, kp, vp = self._jit_leg(
                self.params, toks, sess.cache["kp"], sess.cache["vp"],
                job.pt_row, jnp.int32(pos0), jnp.int32(span))
            sess.cache["kp"], sess.cache["vp"] = kp, vp
        else:
            tok1, job.carry = self._jit_leg_rec(
                self.params, job.carry, toks, jnp.int32(span))
        tok1.block_until_ready()
        dt = time.perf_counter() - t0
        now += dt
        report.wall_prefill_s += dt
        report.observed_vectors += span
        report.prefill_chunks += 1
        rec = job.rec
        rec.prefill_vectors += span
        rec.pad_vectors += self._leg_c - span
        report.prefill_pad_vectors += self._leg_c - span
        job.tok1 = tok1
        job.leg_i += 1
        end = pos0 + span
        if self._snap and end % self.page_size == 0:
            self._register_snapshot(job, end)
        if job.done:
            now = self._finalize_job(sess, job, now)
        return now

    def _register_snapshot(self, job: _PrefillJob, end: int):
        """Store the carried recurrent state at a page-aligned leg end.
        Best effort: an exhausted pool skips the snapshot, never the
        request — restores stay instantaneous (no retain on hit needed;
        fresh entries are LRU-protected by their put tick)."""
        key = job.keys[end // self.page_size - 1]
        if key in self.prefix:
            return
        pids = self.pages.alloc(1, owner=("snap", job.req.rid))
        if pids is None and self.prefix.evict(1):
            pids = self.pages.alloc(1, owner=("snap", job.req.rid))
        if pids is None:
            return
        self._pool_snap = self._jit_snap_put(self._snap_pool(), job.carry,
                                             jnp.int32(pids[0]))
        self.prefix.put(key, pids[0], adopt=True)

    def _finalize_job(self, sess: "EngineSession", job: _PrefillJob,
                      now: float) -> float:
        """Last leg done: register produced prefix pages, deliver/inspect
        the first token, and arm the decode lane."""
        report = sess.report
        req, rec, tok1 = job.req, job.rec, job.tok1
        plen = len(req.prompt)
        if self._use_legs and self.prefix is not None:
            # register this prompt's produced FULL pages: the cache takes
            # one ref ON TOP of the producer's (released at retire), so the
            # pages outlive the request. First producer wins; a racing
            # duplicate's page stays request-owned and frees at retire.
            pids_all = list(sess.slot_pages[job.slot][1]) + \
                list(sess.slot_pages[job.slot][0])
            for j in range(job.f_eff, plen // self.page_size):
                self.prefix.put(job.keys[j], pids_all[j])
        rec.t_first = now
        if self.eos_id is None:
            sess.lazy.append((rec, tok1))
        else:
            first = int(tok1[0, 0])
            if first == self.eos_id:
                self._retire(rec, "eos", now)
                self._free_slot(sess, job.slot)
                return now
            rec.tokens.append(first)
        t0 = time.perf_counter()
        if self._use_legs:
            (sess.cache["pt"], sess.cache["len"], sess.tok_buf,
             sess.state) = self._jit_register(
                sess.cache["pt"], sess.cache["len"], sess.tok_buf, tok1,
                sess.state, jnp.int32(job.slot), jnp.int32(plen),
                jnp.int32(req.max_new), job.pt_row)
        else:
            sess.cache, sess.tok_buf, sess.state = self._jit_insert(
                sess.cache, job.carry, sess.tok_buf, tok1, sess.state,
                jnp.int32(job.slot), jnp.int32(plen),
                jnp.int32(req.max_new))
        dt = time.perf_counter() - t0
        now += dt
        report.wall_prefill_s += dt
        rem = req.max_new - 1
        if not self.recurrent:
            rem = min(rem, self.max_seq - plen)
        sess.rem[job.slot] = rem
        return now

    def _advance_prefill(self, sess: "EngineSession", now: float) -> float:
        """Advance ONE leg of the oldest queued prefill job — the loop-
        cadence unit that interleaves long prefills with decode chunks."""
        job = sess.jobs[0]
        now = self._advance_leg(sess, job, now)
        if job.done:
            sess.jobs.pop(0)
        return now

    def _release_slot_pages(self, sess: "EngineSession", slot: int):
        """Drop the retiring slot's page refs (owned allocations AND the
        sharer refs its prefix hits took). Cache-registered pages survive
        through the cache's own reference."""
        held = sess.slot_pages.pop(slot, None)
        if held is None:
            return
        owned, shared = held
        for pid in list(owned) + list(shared):
            self.pages.release(pid)

    def _free_slot(self, sess: "EngineSession", slot: int):
        sess.slot_rec.pop(slot, None)
        sess.slots.release(slot)
        sess.rem.pop(slot, None)
        self._release_slot_pages(sess, slot)

    def _pick_chunk(self, sess: "EngineSession",
                    responsive: bool = False) -> int:
        """Chunk length for the next dispatch, from the compiled ladder.

        Default: the largest ladder length not exceeding the longest
        PROJECTED remaining budget across busy lanes — maximum host-round
        amortization, and a chunk never runs past the last projected-live
        lane. ``responsive`` (requests are waiting for a slot): the
        SMALLEST ladder length covering the earliest projected retirement,
        so the freed slot reaches the admission loop promptly instead of
        idling to the end of a long chunk. 0 means every in-flight lane is
        projected retired (a dispatch would scan an all-frozen batch —
        skip it)."""
        rems = [r for r in (sess.rem.get(s, 0) for s in sess.slot_rec)
                if r > 0]
        if not rems:
            return 0
        if responsive:
            target = min(rems)
            for n in self._ladder:
                if n >= target:
                    return n
            return self._ladder[-1]
        target = max(rems)
        for n in reversed(self._ladder):
            if n <= target:
                return n
        return 1

    def _dispatch_chunk(self, sess: "EngineSession",
                        n: int | None = None) -> _PendingChunk:
        """Launch one ``n``-step scan (a compiled ladder length, default
        host-picked) WITHOUT waiting for its results; `sess`'s device
        buffers advance to the chunk's outputs so the next chunk (or an
        insert) chains on-device."""
        if n is None:
            n = self._pick_chunk(sess) or 1
        t0 = time.perf_counter()
        sess.tok_buf, sess.cache, sess.state, ys = self._safe_decodes[n](
            self.params, sess.cache, sess.tok_buf, sess.state)
        for slot in sess.slot_rec:
            sess.rem[slot] = max(0, sess.rem.get(slot, 0) - n)
        self._chunks_dispatched += 1
        return _PendingChunk(ys=ys, t_wall=t0,
                             prefill0=sess.report.wall_prefill_s, n=n,
                             health0=sess.report.wall_health_s,
                             recals0=sess.report.n_recals,
                             swap0=sess.report.wall_swap_s,
                             swaps0=sess.report.n_swaps)

    def _process_chunk(self, sess: "EngineSession", pend: _PendingChunk,
                       now: float) -> float:
        """Sync one dispatched chunk and mirror its on-device retirement
        rows into the host books. Billing: the chunk costs (wall since
        dispatch) minus any prefill/insert wall already billed inside that
        window — the double-buffered loop admits WHILE a chunk flies."""
        report = sess.report
        toks, acts, reasons = jax.device_get(pend.ys)
        # any admission since the last sync has its prefill long done by
        # now (the chunk we just read back queued after it) — the deferred
        # first-token reads cost a host copy, not a wait
        self._resolve_firsts(sess)
        overlap = ((report.wall_prefill_s - pend.prefill0)
                   + (report.wall_health_s - pend.health0)
                   + (report.wall_swap_s - pend.swap0))
        dt = max(time.perf_counter() - pend.t_wall - overlap, 0.0)
        now += dt
        report.wall_decode_s += dt
        ran = int(toks.shape[0])
        busy = int(acts.sum())
        report.n_steps += ran
        # busy-lane counts come from the DEVICE (chunk ys), independent of
        # the per-request records — reconcile compares two real countings
        report.observed_vectors += busy
        report.idle_vectors += self.n_slots * ran - busy
        self._step_no += ran
        # a chunk whose window held a hot reprogram is legitimately slow:
        # exempt it from the straggler EWMA (flagging recovery would page
        # an operator for behavior the engine itself caused, and the
        # inflated sample would poison the baseline)
        self.monitor.record(self._step_no, dt / max(ran, 1),
                            exempt=(report.n_recals > pend.recals0
                                    or report.n_swaps > pend.swaps0))
        if self.heartbeat is not None:
            self.heartbeat.beat(
                self._step_no, slots_busy=sess.slots.n_busy,
                slots_free=sess.slots.n_free, chunk_len=ran,
                last_chunk_s=time.time(),
                wall_decode_s=report.wall_decode_s,
                n_recals=report.n_recals)

        for s in range(ran):
            for slot in list(sess.slot_rec):
                if not acts[s, slot]:
                    continue    # freed/refilled after this chunk's dispatch
                rec = sess.slot_rec[slot]
                rec.decode_vectors += 1
                r = int(reasons[s, slot])
                if r != 2:      # EOS is control, not payload (see admit)
                    rec.tokens.append(int(toks[s, slot]))
                if r:
                    self._retire(rec, _REASONS[r], now)
                    sess.slot_rec.pop(slot)
                    sess.slots.release(slot)
                    sess.rem.pop(slot, None)
                    self._release_slot_pages(sess, slot)
        return now

    @staticmethod
    def _resolve_firsts(sess: "EngineSession"):
        """Read back the deferred prefill first-tokens (lazy admission,
        `admit`). Runs before any decode-token append for those records —
        a record admitted after a chunk's dispatch shows acts=False for
        that whole chunk, so its first token always lands at index 0."""
        for rec, tok1 in sess.lazy:
            rec.tokens.insert(0, int(tok1[0, 0]))
        sess.lazy.clear()

    def step(self, sess: "EngineSession", now: float) -> float:
        """One SYNCHRONOUS decode chunk (``decode_chunk`` dense steps,
        dispatched and immediately processed) + retirement bookkeeping;
        returns the advanced clock. Caller guarantees ``sess.slots.n_busy
        > 0``. External drivers (the multi-tenant server) see retirement
        and quota accounting land on chunk boundaries; `serve()` instead
        double-buffers dispatch/process for comm/compute overlap."""
        now = self._resilience_tick(sess, now)
        now = self._placement_tick(sess, now)
        return self._process_chunk(sess, self._dispatch_chunk(sess), now)

    def cancel_active(self, sess: "EngineSession", now: float):
        """Retire every in-flight request with reason "cap" (step budget).
        The device-side active rows are left stale on purpose — a canceled
        session is never stepped again."""
        self._resolve_firsts(sess)
        for job in sess.jobs:    # half-prefilled requests lose their slot
            self._retire(job.rec, "cap", now)
            self._free_slot(sess, job.slot)
        sess.jobs.clear()
        for slot in list(sess.slot_rec):
            self._retire(sess.slot_rec.pop(slot), "cap", now)
            sess.slots.release(slot)
            sess.rem.pop(slot, None)
            self._release_slot_pages(sess, slot)

    def finish(self, sess: "EngineSession", now: float) -> ServeReport:
        """Close the session and return its report. Paged engines hand the
        pool buffers back to the engine (prefix pages stay resident for the
        next session) and snapshot the allocator's exact-partition ledger."""
        self._resolve_firsts(sess)
        report = sess.report
        for job in list(sess.jobs):   # a closed session holds nothing
            self._retire(job.rec, "cap", now)
            self._free_slot(sess, job.slot)
        sess.jobs.clear()
        if self._paged_kv:
            self._pool = (sess.cache["kp"], sess.cache["vp"])
        if self.pages is not None:
            report.page_ledger = self.pages.ledger()
            report.page_ledger_exact = self.pages.verify()
        if self.prefix is not None:
            report.page_evictions = self.prefix.evictions - sess.evictions0
            report.prefix_stats = self.prefix.stats()
        report.makespan_s = now
        report.retries = self._retries - sess.retries0
        report.stragglers = list(self.monitor.flagged[sess.flagged0:])
        return report

    # -- the serving loop ----------------------------------------------------
    def serve(self, requests, max_steps: int = 100_000) -> ServeReport:
        """Serve a full trace to completion (simulated arrival clock).

        The engine clock starts at 0 and advances by the measured wall time
        of each device call; when every slot is empty it jumps to the next
        arrival. Request arrival times are in the same (second) units.

        Decode is DOUBLE-BUFFERED: chunk i+1 is dispatched before chunk
        i's token block is read back, so host bookkeeping and admission
        overlap device compute. Per-request tokens are unaffected — decode
        lanes are row-independent, so what a request generates never
        depends on which chunk (or which lane-mates) it rode with."""
        queue = Batcher(requests, policy=self.admission)
        sess = self.begin()
        now = 0.0
        pending: _PendingChunk | None = None

        while len(queue) or sess.slots.n_busy or pending is not None:
            # ---- admission + slot refill (continuous batching) ------------
            while sess.slots.n_free:
                if self._paged_kv:
                    # ask the allocator BEFORE popping: a request that does
                    # not fit waits at the head (order-preserving HOL block;
                    # never deadlocks — an all-free engine can always cover
                    # one max-length request, ctor-checked)
                    req = queue.peek_ready(now)
                    if req is None or not self.can_admit(sess, req):
                        break
                    queue.pop_ready(now)
                else:
                    req = queue.pop_ready(now)
                    if req is None:
                        break
                now = self.admit(sess, req, now, drain=not self._chunked)

            # ---- chunked-prefill legs ride the loop cadence ---------------
            if sess.jobs:
                now = self._advance_prefill(sess, now)

            # ---- chunk-boundary resilience (drift / chaos / recal) ---------
            now = self._resilience_tick(sess, now)

            # ---- chunk-boundary rotation swap (capacity overflow) ----------
            now = self._placement_tick(sess, now)

            if not sess.slots.n_busy and pending is None:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                now = max(now, nxt)       # idle: jump to the next arrival
                continue

            # ---- one decode chunk, double-buffered ------------------------
            in_flight = pending.n if pending is not None else 0
            capped = sess.report.n_steps + in_flight >= max_steps
            n_next = (self._pick_chunk(sess, responsive=bool(len(queue)))
                      if sess.slots.n_busy else 0)
            cur = (self._dispatch_chunk(sess, n_next)
                   if n_next and not capped else None)
            if pending is not None:
                now = self._process_chunk(sess, pending, now)
            pending = cur
            if capped and pending is None:
                self.cancel_active(sess, now)
                break

        return self.finish(sess, now)

    # -- CM_* books ----------------------------------------------------------
    def ledgers(self, report: ServeReport) -> dict:
        """rid -> CM_* counts (requires a programmed engine)."""
        from repro.runtime.batcher import request_ledgers
        if self.program is None:
            raise ValueError("CM_* ledgers require an AimcProgram")
        if self.rotation is not None:
            raise ValueError(
                "per-request CM_* ledgers are ill-defined under rotation: "
                "a request's vectors span states with different analog "
                "sets; use report.swap_events + placement.reconcile_swaps")
        return request_ledgers(self.program, report.records)

    def core_ledgers(self, report: ServeReport) -> dict:
        """core -> CM_* totals for this run's useful vectors (requires a
        `CoreSchedule`). The per-core split of `ledgers`: summed over cores
        the dequeue/initialize books close exactly against
        ``program.mvm_counts()`` (`batcher.reconcile_cores`)."""
        from repro.runtime.batcher import aggregate_core_ledgers
        if self.schedule is None:
            raise ValueError("per-core ledgers require a CoreSchedule")
        return aggregate_core_ledgers(self.schedule, report.records)


class ShardedServeEngine(ServeEngine):
    """`ServeEngine` with its device state laid out over a real JAX mesh.

    The multi-device join of the three prior subsystems (DESIGN.md §11):
    the installed `AimcProgram`'s crossbar states column-shard their bit
    lines over the mesh's ``model`` axis (`shardings.serve_engine_param_
    specs` — the layout `core.schedule` proves exact), every digital leaf
    replicates over ``data`` (weights-stationary serving), and the decode
    slots — KV caches, recurrent state, the token buffer, the retirement
    state rows — shard over the data axes so each data-parallel device
    advances its own lanes. All three closures are compiled ONCE with `NamedSharding`-pinned
    inputs AND outputs, so the cache lives sharded on-device across the
    whole serving session; the host-side loop (admission, slots,
    accounting) is inherited unchanged.

    Correctness bar: no reduction dimension is ever sharded — column splits
    concatenate and batch rows are independent — so decode output is
    BIT-EQUAL to the single-device `ServeEngine` on the same trace
    (tests/test_sharded_engine.py, forced 2-device host-platform mesh).

    When a `CoreSchedule` is attached, `schedule.mesh_placement` maps its
    virtual cores onto the model-axis devices and `device_ledgers` reports
    CM_* totals per mesh device; per-request ledgers aggregate across
    shards exactly as the single-core path (`batcher.reconcile_cores`).

    ``n_slots`` should divide the data-axis size (and crossbar Np the
    model-axis size) for the sharding to take effect; non-dividing
    dimensions fall back to replicated rather than failing.
    """

    def __init__(self, model, cfg, exe: Execution, params, *, mesh,
                 model_axis: str = "model", **kw):
        if kw.get("rotation") is not None:
            raise ValueError(
                "ShardedServeEngine does not serve rotation plans: state "
                "swaps would re-place every parameter tree on the mesh "
                "mid-trace (use the single-device engine for overflow)")
        self.mesh = mesh
        self.model_axis = model_axis
        super().__init__(model, cfg, exe, params, **kw)

    def _build_closures(self, max_retries: int):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import dp_axes
        from repro.launch.shardings import (fit_spec, serve_engine_param_specs,
                                            slot_cache_specs, slot_state_specs,
                                            to_named)
        mesh = self.mesh

        def named_replicated(shape_tree):
            return jax.tree.map(
                lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
                shape_tree)

        params_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        pspecs = serve_engine_param_specs(params_shape, mesh, self.model_axis)
        self._param_sh = to_named(pspecs, mesh)
        # place the (installed) tree once, outside the serving clock
        self.params = jax.device_put(self.params, self._param_sh)

        if self._paged_kv:
            # paged cache dict: pools split at the PAGE axis over data (a
            # page never splits across the reduction dim — heads/rows stay
            # whole), table + lengths at the slot axis like the dense state
            pool_shape = jax.eval_shape(lambda: self.model.init_paged_cache(
                self.cfg, self.pages.n_pages, self.page_size,
                self.cache_dtype))
            cache_shape = {
                "kp": pool_shape["kp"], "vp": pool_shape["vp"],
                "pt": jax.ShapeDtypeStruct(
                    (self.n_slots, self._pt_width), jnp.int32),
                "len": jax.ShapeDtypeStruct((self.n_slots,), jnp.int32)}
            self._cache_sh = to_named(
                slot_cache_specs(cache_shape, self._paged_axes(), mesh),
                mesh)
        else:
            cache_shape = jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, self.n_slots, self.max_seq, self.cache_dtype))
            self._cache_sh = to_named(
                slot_cache_specs(cache_shape, self._axes, mesh), mesh)
        dp = dp_axes(mesh)
        tok_sh = NamedSharding(
            mesh, fit_spec(P(dp, None), (self.n_slots, 1), mesh))
        self._tok_sh = tok_sh
        state_shape = jax.eval_shape(lambda: ServeEngine._empty_state(self))
        self._state_sh = to_named(slot_state_specs(state_shape, mesh), mesh)
        repl = NamedSharding(mesh, P())   # fully replicated, any rank
        # chunk outputs: per-step [n, n_slots] rows follow the lane split
        # (slots over data axes); the spec is shape-free, so one sharding
        # serves every compiled ladder length
        ys_row = NamedSharding(mesh, fit_spec(
            P(None, dp), (self.decode_chunk, self.n_slots), mesh))
        ys_sh = (ys_row, ys_row, ys_row)

        tokens_s = jax.ShapeDtypeStruct((1, self.prompt_pad), jnp.int32)
        vl_s = jax.ShapeDtypeStruct((1,), jnp.int32)
        cache1_shape = jax.eval_shape(self._prefill_fn, params_shape,
                                      tokens_s, vl_s)[1]
        cache1_sh = named_replicated(cache1_shape)   # [1, ...]: nothing to split

        self._jit_prefill = jax.jit(
            self._prefill_fn,
            in_shardings=(self._param_sh, repl, repl),
            out_shardings=(repl, cache1_sh))
        if self._paged_kv:
            # dense insert is unused when paged, but keep it compiled
            # against the DENSE cache layout for API parity
            dense_shape = jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, self.n_slots, self.max_seq, self.cache_dtype))
            dense_sh = to_named(
                slot_cache_specs(dense_shape, self._axes, mesh), mesh)
        else:
            dense_sh = self._cache_sh
        self._jit_insert = jax.jit(
            self._insert_fn, donate_argnums=(0, 2, 4),
            in_shardings=(dense_sh, cache1_sh, tok_sh, repl,
                          self._state_sh, repl, repl, repl),
            out_shardings=(dense_sh, tok_sh, self._state_sh))
        decode_fn = self._decode_fn
        if self._paged_kv:
            decode_fn = self._decode_paged_fn
            self._jit_insert_paged = jax.jit(
                self._insert_paged_fn, donate_argnums=(0, 2, 4),
                in_shardings=(self._cache_sh, cache1_sh, tok_sh, repl,
                              self._state_sh, repl, repl, repl, repl, repl),
                out_shardings=(self._cache_sh, tok_sh, self._state_sh))
        if self._use_legs:
            kp_sh, vp_sh = self._cache_sh["kp"], self._cache_sh["vp"]
            self._jit_leg = jax.jit(
                self._leg_fn, donate_argnums=(2, 3),
                in_shardings=(self._param_sh, repl, kp_sh, vp_sh, repl,
                              repl, repl),
                out_shardings=(repl, kp_sh, vp_sh))
            self._jit_register = jax.jit(
                self._register_fn, donate_argnums=(0, 1, 2, 4),
                in_shardings=(self._cache_sh["pt"], self._cache_sh["len"],
                              tok_sh, repl, self._state_sh, repl, repl,
                              repl, repl),
                out_shardings=(self._cache_sh["pt"], self._cache_sh["len"],
                               tok_sh, self._state_sh))
        if self._legs_rec:
            # pin the carried [1, ...] state replicated: snap_get outputs
            # and fresh init_cache trees must key ONE executable each
            self._jit_leg_rec = jax.jit(
                self._leg_rec_fn, donate_argnums=(1,),
                in_shardings=(self._param_sh, cache1_sh, repl, repl),
                out_shardings=(repl, cache1_sh))
        if self._snap:
            pool_shape = jax.eval_shape(lambda: self.model.init_cache(
                self.cfg, self.pages.n_pages, self.max_seq,
                self.cache_dtype))
            pool_sh = named_replicated(pool_shape)
            self._jit_snap_put = jax.jit(
                self._snap_put_fn, donate_argnums=(0,),
                in_shardings=(pool_sh, cache1_sh, repl),
                out_shardings=pool_sh)
            self._jit_snap_get = jax.jit(
                self._snap_get_fn, in_shardings=(pool_sh, repl),
                out_shardings=cache1_sh)
        self._decode_jits = {
            n: jax.jit(
                functools.partial(decode_fn, length=n),
                in_shardings=(self._param_sh, self._cache_sh, tok_sh,
                              self._state_sh),
                out_shardings=(tok_sh, self._cache_sh, self._state_sh,
                               ys_sh))
            for n in self._ladder}
        self._safe_decodes = {
            n: resilient_step(f, max_retries=max_retries,
                              on_retry=lambda attempt, e: self._count_retry())
            for n, f in self._decode_jits.items()}

    def _set_params(self, params):
        # re-pin the updated tree to the mesh layout the closures were
        # compiled against (identical treedef/shapes -> no recompile)
        self.params = jax.device_put(params, self._param_sh)

    def _fresh_pools(self):
        pools = self.model.init_paged_cache(
            self.cfg, self.pages.n_pages, self.page_size, self.cache_dtype,
            shardings={"kp": self._cache_sh["kp"],
                       "vp": self._cache_sh["vp"]})
        return pools["kp"], pools["vp"]

    def _paged_cache_dict(self, kp, vp):
        # pools were placed by _fresh_pools; commit table + lengths
        cache = ServeEngine._paged_cache_dict(self, kp, vp)
        cache["pt"] = jax.device_put(cache["pt"], self._cache_sh["pt"])
        cache["len"] = jax.device_put(cache["len"], self._cache_sh["len"])
        return cache

    def _empty_cache(self):
        if self._paged_kv:
            return ServeEngine._empty_cache(self)
        # created ON the mesh placement (models' sharding-annotated init)
        return self.model.init_cache(self.cfg, self.n_slots, self.max_seq,
                                     self.cache_dtype,
                                     shardings=self._cache_sh)

    def _empty_tok_buf(self):
        return jax.device_put(super()._empty_tok_buf(), self._tok_sh)

    def _empty_state(self):
        return jax.device_put(super()._empty_state(), self._state_sh)

    def device_ledgers(self, report: ServeReport) -> dict:
        """model-axis device slot -> CM_* totals for this run, through the
        schedule's core->device placement (`CoreSchedule.mesh_placement`)."""
        if self.schedule is None:
            raise ValueError("device ledgers require a CoreSchedule")
        n_vec = report.useful_vectors
        return {dev: led.cm.scaled(n_vec)
                for dev, led in self.schedule.device_ledgers(
                    self.mesh, self.model_axis).items()}


# ---------------------------------------------------------------------------
# the legacy static-batch path (A/B baseline + bit-equality oracle)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _static_closures(model, cfg, exe, max_seq, cache_dtype):
    """Jitted static-path closures, cached per configuration — a fresh
    `jax.jit(lambda ...)` per call would recompile every invocation and
    bill the A/B baseline for jit time the engine's warmup doesn't pay."""
    prefill = jax.jit(lambda pr, tk: model.prefill(
        pr, tk, cfg, exe, max_seq=max_seq, cache_dtype=cache_dtype))
    decode = jax.jit(lambda pr, ca, tk: model.decode_step(pr, ca, tk, cfg,
                                                          exe))
    return prefill, decode


def static_generate(model, cfg, exe: Execution, params, prompts, gen: int,
                    max_seq: int | None = None, cache_dtype=jnp.float32):
    """The monolithic serve loop this engine replaced: one synchronized
    batch, one prompt length, ``gen`` lockstep decode steps. Kept as the
    oracle the continuous-batching tests compare against bit-for-bit, and
    as the bench's static-batching baseline.

    prompts: [B, P] int32. Returns ([B, gen] tokens, wall seconds
    (prefill_s, decode_s)). ``gen=1`` is prefill-only: no decode loop runs
    and the decode time is honestly 0.0.
    """
    b, p = prompts.shape
    max_seq = max_seq or (p + gen)
    prefill, decode = _static_closures(model, cfg, exe, max_seq, cache_dtype)
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]]
    jax.block_until_ready(out[-1])
    t_prefill = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, out[-1])
        out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
    if gen > 1:
        jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0 if gen > 1 else 0.0
    return jnp.concatenate(out, axis=1), (t_prefill, t_decode)
