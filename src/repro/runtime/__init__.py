"""Runtime resilience: retries, straggler detection, heartbeats, re-mesh."""
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           elastic_mesh_shapes, resilient_step)
