"""Runtime subsystem: the continuous-batching serving engine (request
admission, slot-based decode, per-request CM_* accounting) plus resilience
(bounded retry of transient failures, straggler detection, heartbeats,
elastic re-mesh tables).

Layering: `runtime/` sits between `models/` (whose prefill/decode_step it
drives) and `launch/` (whose CLIs and mesh placement drive it); it never
imports from `launch/` except the sharding-spec helpers. The re-exports
below are the subsystem's public surface — `ServeEngine` /
`ShardedServeEngine` for serving, `ModelServer`/`build_server` for
multi-tenant multi-model serving over one accelerator pool,
`TenantPolicy`/`mixed_poisson_trace` for tenant load,
`Request`/trace builders for load, `reconcile*` for the CM_* books,
`resilient_step`/`StragglerMonitor` for the failure model,
`HealthMonitor`/`build_health` + `FaultInjector`/`parse_chaos` for
drift-aware serving and chaos-grade fault injection
(DESIGN.md §10-§12, §14)."""
from repro.runtime.batcher import (Batcher, Request, RequestRecord,
                                   SlotAllocator, poisson_trace, reconcile,
                                   reconcile_cores, request_core_ledgers,
                                   request_ledgers, synchronized_trace)
from repro.runtime.chaos import (FaultEvent, FaultInjector, corrupt_entries,
                                 parse_chaos)
from repro.runtime.engine import (EngineSession, ServeEngine, ServeReport,
                                  ShardedServeEngine, static_generate)
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           backoff_schedule,
                                           elastic_mesh_shapes, is_transient,
                                           resilient_step)
from repro.runtime.health import (HealthMonitor, HealthPolicy, ProbeSample,
                                  RecalEvent, Recalibrator, build_health,
                                  reconcile_recal)
from repro.runtime.server import (ModelServer, ModelSpec, ServerReport,
                                  build_server)
from repro.runtime.tenancy import (TenantPolicy, TenantRequest, TenantStats,
                                   fair_shares, jains_index,
                                   mixed_poisson_trace, pick_tenant,
                                   reconcile_tenants, tenant_ledgers,
                                   tenant_stats)
