"""Runtime subsystem: the continuous-batching serving engine (request
admission, slot-based decode, per-request CM_* accounting) plus resilience
(bounded retry of transient failures, straggler detection, heartbeats,
elastic re-mesh tables)."""
from repro.runtime.batcher import (Batcher, Request, RequestRecord,
                                   SlotAllocator, poisson_trace, reconcile,
                                   request_ledgers, synchronized_trace)
from repro.runtime.engine import ServeEngine, ServeReport, static_generate
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           elastic_mesh_shapes, is_transient,
                                           resilient_step)
