"""Multi-tenant model server: co-programmed models, per-tenant SLOs, one
accelerator pool (`runtime/` front door, DESIGN.md §12).

ALPINE's tight CPU/AIMC integration exists precisely so ONE crossbar pool
can serve flexible workloads — the 64-core PCM chip and the heterogeneous
IMC cluster (PAPERS.md) both keep many models/layers resident at once.
`AimcProgram` already makes programmed models cheap to keep resident and
`TileAllocator` capacity-checks multi-context placement; this module is the
registry + routing + policy layer on top:

  model registry   ``{model id -> ServeEngine}``. AIMC models are
                   co-programmed against one shared `core.program.TilePool`
                   (capacity checked against the SUM of resident programs —
                   `CapacityError` instead of silent tile overlap); digital
                   models ride along on the same host.

  routing          every `tenancy.TenantRequest` routes by its tenant's
                   ``model`` id to that model's engine. Each engine keeps
                   its own slots/closures; the server drives one
                   `EngineSession` per model under ONE shared clock.

  tenant policy    per-tenant admission queues (fifo/sjf —
                   `tenancy.TenantPolicy`), weighted fair-share decode-slot
                   quotas (`tenancy.pick_tenant`: weighted-deficit,
                   work-conserving — under saturation every tenant gets
                   ≥ its ``weight / sum(weights)`` share of its model's
                   slots, so nobody starves), and per-tenant SLO tracking
                   (p50/p99 TTFT, per-output-token latency).

  accounting       per-tenant CM_*/token books ride the existing
                   `RequestRecord` ledgers; per model, the summed
                   per-tenant ledgers must reconcile EXACTLY against
                   ``program.mvm_counts()`` (`tenancy.reconcile_tenants`).

The serving loop is round-robin over models in registry order: admit
tenant-fairly into every model's free slots, then run one SYNCHRONOUS
decode chunk (``decode_chunk`` scanned steps — `ServeEngine.step`,
DESIGN.md §13) per model with busy lanes, advancing the shared clock by
measured wall time. Retirements, slot releases and therefore tenant-quota
accounting all land on chunk boundaries. A single-model server at chunk 1
is the PR-4 engine loop verbatim (the session primitives only factor it),
so single-model output is bit-equal to `ServeEngine.serve` — and stays
bit-equal at any chunk size, because decode lanes are row-independent.

Public surface
  * `ModelSpec`    — one registry entry (name, arch, aimc|digital).
  * `build_server` — init + co-program + wrap: specs -> `ModelServer`.
  * `ModelServer`  — `warmup()`, `serve(trace) -> ServerReport`,
    `reconcile(report)`, `fair_shares(model)`.
  * `ServerReport` — per-model `ServeReport`s + per-tenant stats/fairness.

Invariants (pinned by tests/test_server.py)
  * single-model serving through the server is BIT-EQUAL to
    `ServeEngine.serve` on the same trace;
  * under a saturated trace every tenant's decode-slot share is within one
    slot-step of its weighted entitlement (no starvation);
  * per-model: observed vectors == per-request books, and the summed
    per-tenant ledgers close exactly against ``program.mvm_counts()``;
  * two programs that exceed the shared pool together raise
    `CapacityError` at build time, never overlapping tiles.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.runtime.batcher import Batcher, RequestRecord
from repro.runtime.engine import ServeEngine, ServeReport
from repro.runtime.tenancy import (TenantPolicy, TenantRequest, TenantStats,
                                   fair_shares, jains_index, pick_tenant,
                                   reconcile_tenants, tenant_stats)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """One model-registry entry for `build_server`."""
    name: str                      # registry id requests route by
    arch: str                      # configs.get_arch id
    exec_mode: str = "digital"     # "aimc" (co-programmed) | "digital"

    def __post_init__(self):
        if self.exec_mode not in ("aimc", "digital"):
            raise ValueError(f"model {self.name!r}: exec_mode must be "
                             f"'aimc' or 'digital', got {self.exec_mode!r}")


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServerReport:
    """Everything one `ModelServer.serve` run produced."""
    model_reports: dict[str, ServeReport]
    tenant_of: dict[int, str]              # rid -> tenant name
    policies: dict[str, TenantPolicy]
    makespan_s: float = 0.0

    def tenant_records(self, tenant: str) -> dict[int, RequestRecord]:
        """That tenant's records, across every model it touched."""
        out = {}
        for rep in self.model_reports.values():
            out.update({rid: rec for rid, rec in rep.records.items()
                        if self.tenant_of[rid] == tenant})
        return out

    def tenant_stats(self) -> dict[str, TenantStats]:
        return {name: tenant_stats(pol, self.tenant_records(name),
                                   self.makespan_s)
                for name, pol in self.policies.items()}

    def fairness(self, model: str) -> float:
        """Jain's index over weight-normalized tenant throughput on one
        model (1.0 = shares match weights exactly). Single-tenant models
        are trivially fair."""
        stats = self.tenant_stats()
        xs = [stats[p.name].generated_tokens / p.weight
              for p in self.policies.values() if p.model == model]
        return jains_index(xs)

    @property
    def generated_tokens(self) -> int:
        return sum(r.generated_tokens for r in self.model_reports.values())

    def summary(self) -> str:
        lines = [f"{sum(len(r.records) for r in self.model_reports.values())}"
                 f" requests, {self.generated_tokens} tokens in "
                 f"{self.makespan_s:.2f}s engine-time across "
                 f"{len(self.model_reports)} model(s)"]
        for name, st in sorted(self.tenant_stats().items()):
            lines.append("  " + st.row())
        models = {p.model for p in self.policies.values()}
        fair = ", ".join(f"{m}={self.fairness(m):.3f}"
                         for m in sorted(models))
        lines.append(f"  quota fairness (Jain, weight-normalized): {fair}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------

class ModelServer:
    """Routes a mixed-tenant request stream over co-resident model engines.

    ``engines``: model id -> warmed or warmable `ServeEngine` (sharded ones
    included — the server only uses the session primitives). ``tenants``:
    every tenant's policy; each must route to a registered model. ``pool``:
    the shared `TilePool` the AIMC members were co-programmed against
    (capacity stats; optional).
    """

    def __init__(self, engines: Mapping[str, ServeEngine],
                 tenants: Sequence[TenantPolicy], *, pool=None):
        if not engines:
            raise ValueError("ModelServer needs at least one engine")
        if not tenants:
            raise ValueError("ModelServer needs at least one tenant")
        names = [p.name for p in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.engines = dict(engines)
        for m, eng in self.engines.items():
            if getattr(eng, "rotation", None) is not None:
                raise ValueError(
                    f"model {m!r} serves a capacity-overflow rotation plan "
                    f"(core.placement); the multi-tenant server interleaves "
                    f"engines under one clock, so a swap from one model's "
                    f"cadence would stall every tenant — serve rotation "
                    f"plans single-model via ServeEngine.serve")
        self.policies = {p.name: p for p in tenants}
        for p in tenants:
            if p.model not in self.engines:
                raise ValueError(
                    f"tenant {p.name!r} routes to unregistered model "
                    f"{p.model!r} (registered: {sorted(self.engines)})")
        self.pool = pool
        # model -> its tenants, in stable (name-sorted) order
        self._tenants_of = {
            m: sorted(p.name for p in tenants if p.model == m)
            for m in self.engines}

    # -- setup ---------------------------------------------------------------
    def warmup(self) -> dict[str, dict[str, int]]:
        """Warm every engine (compile outside the serving clock)."""
        return {m: eng.warmup() for m, eng in self.engines.items()}

    def compile_counts(self) -> dict[str, dict[str, int]]:
        return {m: eng.compile_counts() for m, eng in self.engines.items()}

    def fair_shares(self, model: str) -> dict[str, float]:
        """tenant -> entitled decode slots on ``model``."""
        return fair_shares(list(self.policies.values()), model,
                           self.engines[model].n_slots)

    # -- the serving loop ----------------------------------------------------
    def serve(self, trace: Sequence[TenantRequest],
              max_steps: int = 100_000, heartbeat=None) -> ServerReport:
        """Serve a mixed-tenant trace to completion under one shared clock.

        The clock starts at 0 and advances by the measured wall time of
        every device call (models serialize on the host — honest for a
        single-host pool); when everything is idle it jumps to the next
        arrival. Admission is tenant-fair per model (`tenancy.pick_tenant`)
        with each tenant's own queue order; decode is round-robin, one
        dense step per model with busy lanes per pass.

        ``heartbeat`` (a `fault_tolerance.Heartbeat`) is beaten once per
        decode pass with slot occupancy per model and the wall timestamp
        of the last completed chunk — the liveness probe an external
        supervisor watches to distinguish a wedged loop from a slow one."""
        for tr in trace:
            if tr.tenant not in self.policies:
                raise ValueError(f"request {tr.request.rid}: unknown tenant "
                                 f"{tr.tenant!r}")
        rids = [tr.request.rid for tr in trace]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be globally unique")

        tenant_of = {tr.request.rid: tr.tenant for tr in trace}
        queues = {
            name: Batcher([tr.request for tr in trace if tr.tenant == name],
                          policy=pol.admission)
            for name, pol in self.policies.items()}
        sessions = {m: eng.begin() for m, eng in self.engines.items()}
        in_flight = {name: 0 for name in self.policies}   # decode slots held
        capped: set[str] = set()                          # hit max_steps
        now = 0.0
        n_pass = 0

        def queued(m: str) -> int:
            return sum(len(queues[t]) for t in self._tenants_of[m])

        while True:
            # ---- tenant-fair admission + slot refill ----------------------
            for m, eng in self.engines.items():
                if m in capped:
                    continue
                sess = sessions[m]
                while sess.slots.n_free:
                    cands = [t for t in self._tenants_of[m]
                             if queues[t].has_ready(now)]
                    admitted = False
                    while cands:
                        t = pick_tenant(cands, in_flight, self.policies)
                        req = queues[t].peek_ready(now)
                        # paged engines gate admission on page supply and
                        # the tenant's page quota BEFORE popping: a blocked
                        # tenant is eliminated from this pass (its queue
                        # order is preserved; another tenant may use the
                        # slot — work-conserving), never dropped
                        pol = self.policies[t]
                        if pol.max_pages is not None:
                            held = eng.tenant_pages(sess, tenant_of)
                            if (held.get(t, 0) + eng.pages_needed(req)
                                    > pol.max_pages):
                                cands.remove(t)
                                continue
                        if not eng.can_admit(sess, req):
                            cands.remove(t)
                            continue
                        queues[t].pop_ready(now)
                        busy0 = sess.slots.n_busy
                        now = eng.admit(sess, req, now)
                        if sess.slots.n_busy > busy0:   # took a slot (not
                            in_flight[t] += 1           # prefill-only retired)
                        admitted = True
                        break
                    if not admitted:
                        break

            # ---- one decode chunk per busy model (quota accounting lands
            # on the chunk boundary: step() syncs every retirement) ----------
            stepped = False
            for m, eng in self.engines.items():
                sess = sessions[m]
                if not sess.slots.n_busy:
                    continue
                if sess.report.n_steps >= max_steps:
                    for rec in sess.slot_rec.values():
                        in_flight[tenant_of[rec.request.rid]] -= 1
                    eng.cancel_active(sess, now)
                    capped.add(m)
                    continue
                before = dict(sess.slot_rec)
                now = eng.step(sess, now)
                for slot in set(before) - set(sess.slot_rec):
                    in_flight[tenant_of[before[slot].request.rid]] -= 1
                stepped = True

            if stepped:
                n_pass += 1
                if heartbeat is not None:
                    import time
                    heartbeat.beat(
                        n_pass,
                        last_chunk_s=time.time(),
                        engine_clock_s=now,
                        slots={m: {"busy": sessions[m].slots.n_busy,
                                   "free": sessions[m].slots.n_free}
                               for m in self.engines},
                        n_steps={m: sessions[m].report.n_steps
                                 for m in self.engines},
                        n_recals={m: sessions[m].report.n_recals
                                  for m in self.engines})
                continue
            # ---- idle: jump to the next arrival, or done -------------------
            arrivals = [queues[t].next_arrival()
                        for m in self.engines if m not in capped
                        for t in self._tenants_of[m] if len(queues[t])]
            arrivals = [a for a in arrivals if a is not None]
            if not arrivals:
                break
            nxt = min(arrivals)
            if nxt <= now and any(queued(m) for m in self.engines
                                  if m not in capped):
                # ready requests exist but no model could admit them (all
                # slots busy is handled above; this is every model capped or
                # zero-slot progress) — nothing will ever change, stop
                break
            now = max(now, nxt)

        report = ServerReport(
            model_reports={m: self.engines[m].finish(sessions[m], now)
                           for m in self.engines},
            tenant_of=tenant_of,
            policies=dict(self.policies),
            makespan_s=now)
        return report

    # -- CM_* books ----------------------------------------------------------
    def reconcile(self, report: ServerReport) -> dict[str, bool | None]:
        """model -> whether its books close exactly (None: no program).

        Two checks per programmed model: the device loop's independent
        vector count equals the per-request books, and the summed
        per-tenant CM_* ledgers equal ``program.mvm_counts()`` scaled by
        that observed count (`tenancy.reconcile_tenants`)."""
        out: dict[str, bool | None] = {}
        for m, eng in self.engines.items():
            rep = report.model_reports[m]
            counts_agree = rep.observed_vectors == rep.useful_vectors
            if eng.program is None:
                out[m] = None if counts_agree else False
                continue
            led_sum, static = reconcile_tenants(
                eng.program, rep.records, report.tenant_of,
                rep.observed_vectors)
            out[m] = counts_agree and led_sum == static
        return out


# ---------------------------------------------------------------------------
# build_server — init + co-program + wrap
# ---------------------------------------------------------------------------

def build_server(specs: Sequence[ModelSpec],
                 tenants: Sequence[TenantPolicy] | None = None, *,
                 smoke: bool = True, n_slots: int = 4, prompt_pad: int = 12,
                 max_seq: int | None = None, n_contexts: int = 1,
                 tiles_per_context: int | None = None, aimc_cfg=None,
                 seed: int = 0, eos_id: int | None = None, mesh=None,
                 cache_dtype=None, decode_chunk: int = 1,
                 page_size: int = 0, n_pages: int = 0,
                 prefix_cache: bool = False,
                 prefill_chunk: int = 0) -> ModelServer:
    """Initialize every registered model, co-program the AIMC members
    against ONE shared `TilePool`, and wrap the engines in a `ModelServer`.

    ``tenants=None`` defaults to one tenant per model (weight 1, fifo).
    ``mesh`` (a named JAX mesh) serves every model through
    `ShardedServeEngine` on that mesh. ``decode_chunk`` sets every
    engine's scanned-decode chunk size (tokens are chunk-invariant;
    quota accounting lands on chunk boundaries). ``page_size`` /
    ``n_pages`` / ``prefix_cache`` / ``prefill_chunk`` configure the
    paged slot cache (DESIGN.md §15) on every transformer-module engine
    (recurrent engines take the snapshot path; other modules get the
    dense cache). The default ``aimc_cfg`` uses the
    deployment configuration (fixed DAC input scale) so programmed output
    is batch-shape independent. Raises `core.program.CapacityError` when
    the co-programmed models exceed ``tiles_per_context`` together."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core.aimc import AimcConfig
    from repro.core.program import MappingPlan, TilePool, program_model
    from repro.models.layers import Execution
    from repro.runtime.engine import ShardedServeEngine

    if not specs:
        raise ValueError("build_server needs at least one ModelSpec")
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate model names: {names}")
    if tenants is None:
        tenants = [TenantPolicy(name=s.name, model=s.name) for s in specs]
    cache_dtype = cache_dtype or jnp.float32
    max_seq = max_seq or prompt_pad + 16

    pool = None
    if any(s.exec_mode == "aimc" for s in specs):
        aimc_cfg = aimc_cfg or AimcConfig(impl="ref", input_scale=0.1)
        pool = TilePool(aimc_cfg, n_contexts=n_contexts,
                        tiles_per_context=tiles_per_context)

    engines: dict[str, ServeEngine] = {}
    for i, spec in enumerate(specs):
        arch = get_arch(spec.arch)
        if arch.family == "audio":
            raise ValueError(f"model {spec.name!r}: the enc-dec audio "
                             f"family decodes via launch.steps, not the "
                             f"serving engine")
        cfg = arch.smoke_cfg if smoke else arch.model_cfg
        model = arch.model_module()
        params = model.init(jax.random.PRNGKey(seed + i), cfg)
        program = None
        if spec.exec_mode == "aimc":
            exe = Execution(mode="aimc", aimc=aimc_cfg,
                            compute_dtype="float32", programmed=True)
            program = program_model(
                params, MappingPlan(), aimc_cfg,
                jax.random.PRNGKey(seed + 100 + i),
                pool=pool, label=spec.name)
            params = program.install(params)
        else:
            exe = Execution(compute_dtype="float32")
        kw = dict(n_slots=n_slots, prompt_pad=prompt_pad, max_seq=max_seq,
                  cache_dtype=cache_dtype, family=arch.family,
                  module=arch.module, program=program, eos_id=eos_id,
                  decode_chunk=decode_chunk)
        if page_size > 0:
            # only modules with a paged path take the flags; the rest of a
            # mixed registry keeps the dense cache (documented above)
            from repro.runtime.engine import RECURRENT_MODULES
            rec = arch.module in RECURRENT_MODULES
            legs_ok = (arch.family != "vlm"
                       and not getattr(cfg, "is_moe", False)
                       and cache_dtype == jnp.float32)
            if arch.module == "transformer":
                kw.update(page_size=page_size, n_pages=n_pages)
                if legs_ok:
                    kw.update(prefix_cache=prefix_cache,
                              prefill_chunk=prefill_chunk)
            elif rec and legs_ok and (prefix_cache or prefill_chunk):
                kw.update(page_size=page_size, n_pages=n_pages,
                          prefix_cache=prefix_cache,
                          prefill_chunk=prefill_chunk)
        if mesh is not None:
            engines[spec.name] = ShardedServeEngine(model, cfg, exe, params,
                                                    mesh=mesh, **kw)
        else:
            engines[spec.name] = ServeEngine(model, cfg, exe, params, **kw)
    return ModelServer(engines, tenants, pool=pool)
