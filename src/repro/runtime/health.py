"""Online health monitoring + hot recalibration for drift-aware serving.

Real PCM crossbars do not stay programmed: conductances decay along a power
law (core.noise.drift_gain_at), cores vary, and — at fleet scale — whole
cores die mid-trace. This module is the serve-loop counterpart of
`fault_tolerance.resilient_step`: it detects analog degradation ONLINE and
repairs it without dropping traffic.

The loop (driven by `ServeEngine._resilience_tick` at chunk boundaries):

  1. **Drift refresh** — `HealthMonitor.drifted_entries(t_now)` re-derives
     every installed state from the program's FRESH codes with the current
     power-law gain (`AimcLinearState.with_gain`). Same shapes, same
     treedef: refreshing drift never recompiles a serve closure.
  2. **Probe** — `probe(entries, t_now)` pushes a few fixed probe vectors
     through the LIVE states via the reference kernel (`kernels/ref.py`,
     the digital oracle path) and compares against the fresh-program
     outputs captured at build time. The per-core error is exact: a pure
     drift gain g shows up as relative error 1-g, a dead crossbar as 1.0.
  3. **Recalibrate** — past `HealthPolicy.threshold` (or on a core marked
     dead by the chaos harness), `recalibrate(cores, t_now)` reprograms the
     failing cores' matrices from reference weights under their ORIGINAL
     programming keys (`Recalibrator`), so the repaired state is bit-equal
     to the fresh program. Dead cores are first drained onto survivors
     (`AimcProgram.remap_context` — spare tiles, re-claimed placements).
     The CM_INITIALIZE cost is returned to the caller and charged to the
     serve report — NEVER silently.

Invariants (pinned by tests/test_resilience.py): probe error is 0 on a
fresh program (the oracle is the same code path); reprogramming under the
original key is bit-exact; recalibration charges exactly
`program.reprogram_counts(names)`; MVM-count reconciliation is invariant
under remap (counts are shape-only).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.core import noise as noise_lib
from repro.core.aimc import AimcConfig, AimcLinearState, aimc_apply, \
    program_stacked
from repro.core.program import AimcProgram, MappingPlan, iter_mapped_leaves


@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """When to probe and when to repair (hashable; defaults serve smokes)."""

    threshold: float = 0.05     # per-core relative probe error triggering recal
    probe_batch: int = 2        # probe vectors per matrix
    probe_interval_s: float = 0.0  # min seconds between probes; 0 = every tick
    seed: int = 0               # probe vectors + per-core drift-nu variation


@dataclasses.dataclass(frozen=True)
class RecalEvent:
    """One hot recalibration, as charged to the serve report."""

    t: float                    # serve-clock instant
    reason: str                 # "drift" | "dead_core" | ...
    cores: tuple[int, ...]      # failing cores repaired
    names: tuple[str, ...]      # matrices reprogrammed
    initialize: int             # CM_INITIALIZE device writes charged
    wall_s: float               # host+device wall spent repairing


@dataclasses.dataclass(frozen=True)
class ProbeSample:
    t: float
    errors: dict[int, float]    # core -> max relative probe error


def _apply_state(st: AimcLinearState, x: jnp.ndarray,
                 cfg: AimcConfig) -> jnp.ndarray:
    """Probe MVM through the analog pipeline; stacked instances vmapped."""
    if not st.stack_shape:
        return aimc_apply(st, x, cfg)
    wq = st.w_q.reshape((-1,) + st.w_q.shape[-3:])
    sw = st.s_w.reshape((-1,) + st.s_w.shape[-2:])

    def one(wq_i, sw_i):
        return aimc_apply(AimcLinearState(w_q=wq_i, s_w=sw_i,
                                          k=st.k, n=st.n), x, cfg)

    return jax.vmap(one)(wq, sw)


def _rel_err(y: jnp.ndarray, y_ref: jnp.ndarray) -> float:
    num = float(jnp.linalg.norm((y - y_ref).ravel()))
    den = float(jnp.linalg.norm(y_ref.ravel()))
    return num / (den + 1e-12)


class Recalibrator:
    """Reference weights + programming keys for bit-exact hot reprogramming.

    After `install()` the raw float weights leave the parameter tree, so a
    mid-serve repair needs them captured up front. This replays the exact
    `program_model` walk (`iter_mapped_leaves` is the shared contract) over
    the RAW parameter tree: matrix i gets `fold_in(key, i)` — the same key
    it was originally programmed under — so `fresh_state(name)` reproduces
    the program's state bit-for-bit, programming noise included."""

    def __init__(self, program: AimcProgram, params_raw,
                 plan: MappingPlan | None, key: jax.Array | None):
        self.cfg = program.cfg
        self.refs: dict[str, tuple[jnp.ndarray, jax.Array | None]] = {}
        for pkey, w, idx in iter_mapped_leaves(params_raw, plan):
            if pkey in program:
                sub = (jax.random.fold_in(key, idx)
                       if key is not None else None)
                self.refs[pkey] = (jnp.asarray(w), sub)
        missing = set(program.names) - set(self.refs)
        if missing:
            raise ValueError(
                f"Recalibrator: raw params/plan do not cover program "
                f"matrices {sorted(missing)} (was the program built by "
                f"program_model with this plan?)")

    def fresh_state(self, name: str) -> AimcLinearState:
        w, key = self.refs[name]
        return program_stacked(w, self.cfg, key)

    def reference_weight(self, name: str) -> jnp.ndarray:
        return self.refs[name][0]


class HealthMonitor:
    """Per-core online error tracking + the hot-recalibration authority.

    Owns the CURRENT program (updated on every repair — the engine mirrors
    it), a `Recalibrator` for bit-exact reprogramming, and the drift model
    evolving the installed states. Construct via `build_health` when
    starting from raw params + plan."""

    def __init__(self, program: AimcProgram, recal: Recalibrator,
                 policy: HealthPolicy | None = None,
                 noise: noise_lib.NoiseModel | None = None):
        self.program = program
        self.recal = recal
        self.policy = policy or HealthPolicy()
        self.noise = program.cfg.noise if noise is None else noise
        self.dead: set[int] = set()
        self.history: list[ProbeSample] = []
        self.events: list[RecalEvent] = []
        self._last_probe_t: float | None = None
        self._applied_gains: dict[str, float] | None = None
        # probe kit: fixed vectors, fresh-path references (the digital
        # oracle through kernels/ref.py), and the quantization floor of
        # each matrix (analog fresh vs float matmul) for reporting.
        probe_cfg = dataclasses.replace(program.cfg, impl="ref")
        self._probe_cfg = probe_cfg
        key = jax.random.PRNGKey(self.policy.seed)
        self._probes: dict[str, jnp.ndarray] = {}
        self._refs: dict[str, jnp.ndarray] = {}
        self.quant_floor: dict[str, float] = {}
        for i, (name, st) in enumerate(zip(program.names, program.states)):
            x = jax.random.normal(jax.random.fold_in(key, i),
                                  (self.policy.probe_batch, st.k),
                                  jnp.float32)
            y_fresh = _apply_state(st, x, probe_cfg)
            self._probes[name] = x
            self._refs[name] = y_fresh
            w = recal.reference_weight(name)
            y_dig = jnp.einsum("bk,...kn->...bn", x, w.astype(jnp.float32))
            self.quant_floor[name] = _rel_err(y_fresh, y_dig)

    # -- drift --------------------------------------------------------------
    @property
    def drift_active(self) -> bool:
        return self.noise.enabled and self.noise.drift_nu != 0.0

    def drifted_entries(self, t_now: float) -> dict[str, AimcLinearState]:
        """Decayed views of the current program at ``t_now`` — {} when the
        gains have not moved since the last application (avoids re-device-
        putting identical states every chunk).

        With `drift_compensate` on, each matrix's decay gain (per-core
        actual exponent) is multiplied by the age-based dequant correction
        `compensation_gain_at` (NOMINAL exponent — the compensator cannot
        see per-core variation). At zero core spread the product is exactly
        1.0 between recals; with spread, the probe error collapses from
        ~(1-g) to the nominal/actual residual."""
        if not self.drift_active:
            return {}
        gains = self.program.drift_gains(t_now, self.noise, self.policy.seed)
        if self.noise.drift_compensate:
            ages = self.program.ages(t_now)
            gains = {n: g * self.noise.compensation_gain_at(ages[n])
                     for n, g in gains.items()}
        if gains == self._applied_gains:
            return {}
        self._applied_gains = gains
        if all(g == 1.0 for g in gains.values()):
            return {}
        return {n: st.with_gain(gains[n])
                for n, st in zip(self.program.names, self.program.states)}

    # -- probes -------------------------------------------------------------
    def due(self, t_now: float) -> bool:
        if self._last_probe_t is None or self.policy.probe_interval_s <= 0.0:
            return True
        return t_now - self._last_probe_t >= self.policy.probe_interval_s

    def probe(self, entries: dict[str, AimcLinearState],
              t_now: float) -> ProbeSample:
        """Measure per-core output error of the LIVE states against the
        fresh-program oracle. ``entries`` are the states actually installed
        in the engine's parameter tree (drifted, corrupted, or repaired —
        whatever serving traffic sees)."""
        self._last_probe_t = t_now
        errors: dict[int, float] = {}
        for name, ctx in zip(self.program.names, self.program.contexts):
            st = entries.get(name)
            if st is None:
                continue
            err = _rel_err(_apply_state(st, self._probes[name],
                                        self._probe_cfg), self._refs[name])
            errors[ctx] = max(errors.get(ctx, 0.0), err)
        sample = ProbeSample(t=t_now, errors=errors)
        self.history.append(sample)
        return sample

    def failing_cores(self, sample: ProbeSample) -> tuple[int, ...]:
        over = {c for c, e in sample.errors.items()
                if e > self.policy.threshold}
        return tuple(sorted(over | self.dead))

    # -- failure marking (the chaos harness's entry point) -------------------
    def mark_dead(self, core: int):
        self.dead.add(core)

    # -- repair --------------------------------------------------------------
    def recalibrate(self, cores, t_now: float):
        """Hot-reprogram every matrix on ``cores``; dead cores drain first.

        Returns ``(entries, names, cm)``: the freshly-programmed states to
        `install_updates`, the matrices repaired, and the CM_INITIALIZE
        bill. Updates `self.program` (remapped contexts + reset ages); the
        caller must mirror it and charge ``cm`` to its books."""
        cores = set(cores)
        prog = self.program
        names = tuple(n for n, c in zip(prog.names, prog.contexts)
                      if c in cores)
        if not names:
            self.dead -= cores
            return {}, (), isa.CmCounts()
        for c in sorted(cores & self.dead):
            prog = prog.remap_context(c)
        entries = {n: self.recal.fresh_state(n) for n in names}
        cm = prog.reprogram_counts(names)
        self.program = prog.reprogrammed(entries, t_now)
        self.dead -= cores
        self._applied_gains = None  # reprogrammed ages restart the decay law
        return entries, names, cm


def build_health(program: AimcProgram, params_raw,
                 plan: MappingPlan | None, key: jax.Array | None,
                 policy: HealthPolicy | None = None,
                 noise: noise_lib.NoiseModel | None = None) -> HealthMonitor:
    """The one-call front door: capture references off the RAW params (the
    tree BEFORE `install`) and stand up the monitor."""
    return HealthMonitor(program, Recalibrator(program, params_raw, plan, key),
                         policy=policy, noise=noise)


def reconcile_recal(program: AimcProgram, report) -> bool:
    """The recal books must close exactly: every event's CM_INITIALIZE bill
    equals `reprogram_counts` recomputed from the program's shapes, and the
    report's total charge equals the per-event sum. Shape-only accounting —
    no instrumentation inside jit — exactly like `mvm_counts`
    reconciliation. A repair that went unbilled (or double-billed) fails
    here even though token outputs look fine."""
    events = getattr(report, "recal_events", [])
    for ev in events:
        if ev.initialize != program.reprogram_counts(ev.names).initialize:
            return False
    return report.recal_initialize == sum(ev.initialize for ev in events)
