"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialization.

Axis semantics (DESIGN.md §5):
  pod    — inter-pod data parallelism (DCN-connected in production)
  data   — in-pod data parallel / FSDP axis
  model  — tensor parallel axis (also: MoE experts, decode KV sequence chunks)

`fsdp_axes` returns the tuple of axes the parameter/optimizer shards span in
addition to `model` — on a multi-pod mesh parameters shard over pod+data too,
so 512 chips hold one copy of (param, grad, moments).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for elastic re-shapes / tests (e.g. (1, 1) on CPU)."""
    return jax.make_mesh(shape, axes)


def fsdp_axes(mesh) -> tuple[str, ...]:
    """Parameter-sharding (FSDP) axes = every non-'model' axis."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-sharding axes (same set as FSDP for this framework)."""
    return fsdp_axes(mesh)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n
