"""While-aware roofline statistics from optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body (every ``lax.scan``:
layer stacks, gradient-accumulation microbatches, CE vocab chunks, attention
chunk loops) exactly ONCE — verified empirically on this container — so a
scanned 80-layer model under-reports FLOPs by ~80x. The roofline table would
be garbage. This module re-derives the three roofline terms from the
optimized HLO text itself, multiplying every while body by its
``known_trip_count`` (annotated by XLA in ``backend_config``), nested loops
multiplying through.

What is counted (per-device — the SPMD module is already per-partition):

  flops        2*M*N*K for ``dot`` (from result shape x lhs contracting dims),
               2 * out_elems * kernel_elems / out_features for ``convolution``,
               1 flop/elem for arithmetic/transcendental element-wise ops and
               reduces (inside fusions too). Dots dominate every cell here.
  bytes        HBM-traffic approximation in the XLA style: for every
               *materializing* top-level instruction, result bytes + operand
               bytes. Fusion internals are free (they live in registers/VMEM);
               parameter/constant/GTE/tuple/bitcast are free; the ``while`` op
               itself is free (its traffic is its body's, already multiplied).
  collectives  wire bytes per device with ring-algorithm factors:
               all-reduce 2x size, all-gather/reduce-scatter the large side x
               (n-1)/n ~ 1, all-to-all operand size, collective-permute size.
               Async ``-start``/``-done`` pairs are counted once (at start).

The analyzer is validated against ``cost_analysis()`` on scan-free programs
(tests/test_hlostats.py): flops match exactly, bytes within a few percent.
"""

from __future__ import annotations

import dataclasses
import json
import re

# --------------------------------------------------------------------------
# shape parsing
# --------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "s4": 1, "u4": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def shapes_of(type_str: str) -> list[tuple[str, list[int]]]:
    """All (dtype, dims) array shapes inside an HLO type string (handles
    tuples). Token types (s32[] scalars) parse as dims=[]."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def type_elems(type_str: str) -> int:
    """Element count of the FIRST array shape in a type string."""
    shp = shapes_of(type_str)
    if not shp:
        return 0
    n = 1
    for d in shp[0][1]:
        n *= d
    return n


# --------------------------------------------------------------------------
# HLO text parsing
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    line: str


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"          # result name
    # type: tuple "(...)" (may contain /*index=N*/ comments) or one array
    r"((?:\([^()]*\))|(?:[\w\[\],]+(?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\("                                    # opcode
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")


def _args_segment(line: str) -> str:
    """The text inside the opcode's argument parens (balanced)."""
    i = line.find("(")
    # the opcode's paren is the one right after '= <type> <opcode>'
    m = _INSTR_RE.match(line)
    if not m:
        return ""
    start = m.end() - 1
    depth = 0
    for j in range(start, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1: j]
    return line[start + 1:]


def parse_module(text: str):
    """-> (computations: {name: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and "=" not in line.split("(")[0]:
            cur_name = m.group(2)
            cur = []
            comps[cur_name] = cur
            if m.group(1):
                entry = cur_name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, tstr, opcode = im.group(1), im.group(2), im.group(3)
        operands = _OPERAND_RE.findall(_args_segment(line))
        cur.append(Instr(name, tstr, opcode, operands, line))
    if entry is None and comps:
        entry = next(reversed(comps))
    return comps, entry


# --------------------------------------------------------------------------
# cost rules
# --------------------------------------------------------------------------

# 1 flop per output element (approximation; dots dominate all our cells)
_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "tanh", "log",
    "log-plus-one", "rsqrt", "sqrt", "power", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "sign", "atan2",
    "logistic", "cbrt", "erf", "clamp", "select", "compare", "remainder",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "token", "iota", "rng-get-and-update-state", "partition-id",
    "replica-id", "opt-barrier", "optimization-barrier", "custom-call",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}
_COLLECTIVE_DONE = {
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        # module-wide name -> type (HLO printer keeps names unique per module)
        self.types: dict[str, str] = {}
        for instrs in self.comps.values():
            for ins in instrs:
                self.types[ins.name] = ins.type_str
        self._memo: dict[str, Cost] = {}
        self._fusion_eff: dict[str, dict] = {}

    # -- slice-aware fusion boundary accounting ------------------------------
    #
    # XLA's convention charges a fusion the FULL bytes of every operand, but
    # a fusion that consumes a stacked [L, ...] parameter only through
    # ``dynamic-slice`` (the lax.scan weight-slicing pattern) actually DMAs
    # one slice, and an in-place ``dynamic-update-slice`` root (scan gradient
    # stacking) writes one slice of an aliased buffer. Without this
    # correction an 80-layer scan over stacked weights overcounts HBM bytes
    # by ~80x and the memory roofline term is meaningless.

    def _fusion_param_effective(self, called: str) -> dict:
        """-> {param_index: effective_bytes or ('dus_root', update_bytes)}."""
        if called in self._fusion_eff:
            return self._fusion_eff[called]
        comp = self.comps.get(called, [])
        out: dict = {}
        pidx: dict[str, int] = {}
        for ins in comp:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    pidx[ins.name] = int(m.group(1))
        # convert/bitcast/copy are transparent: a param consumed through a
        # dtype-convert chain still only DMAs what the slice op reads
        canon: dict[str, str] = {}
        for ins in comp:
            if (ins.opcode in ("convert", "bitcast", "copy")
                    and len(ins.operands) == 1):
                src = ins.operands[0]
                canon[ins.name] = canon.get(src, src)
        uses: dict[str, list[Instr]] = {p: [] for p in pidx}
        root = comp[-1] if comp else None
        root_op0 = (canon.get(root.operands[0], root.operands[0])
                    if root is not None and root.operands else None)
        for ins in comp:
            if ins.opcode == "parameter":
                continue
            for op in ins.operands:
                op = canon.get(op, op)
                if op in uses and ins.opcode not in ("convert", "bitcast",
                                                     "copy"):
                    uses[op].append(ins)
        for pname, idx in pidx.items():
            u = uses[pname]
            if u and all(x.opcode == "dynamic-slice" for x in u):
                out[idx] = sum(type_bytes(x.type_str) for x in u)
            elif (root is not None and root.opcode == "dynamic-update-slice"
                  and u == [root] and root_op0 == pname):
                out[idx] = 0                      # aliased in-place buffer
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = root.operands[1] if len(root.operands) > 1 else None
            ub = type_bytes(self.types.get(upd, "")) if upd else 0
            if not ub and upd:
                # update computed in-fusion: look up its local declaration
                for ins in comp:
                    if ins.name == upd:
                        ub = type_bytes(ins.type_str)
                        break
            out["__root_dus__"] = ub or None
        # a convert root wrapping a DUS (CPU bf16 emulation) counts the same
        if (root is not None and root.opcode == "convert" and root.operands):
            src = root.operands[0]
            for ins in comp:
                if ins.name == src and ins.opcode == "dynamic-update-slice":
                    upd = ins.operands[1] if len(ins.operands) > 1 else None
                    ub = 0
                    for i2 in comp:
                        if upd and i2.name == upd:
                            ub = type_bytes(i2.type_str)
                            break
                    out["__root_dus__"] = ub or None
                    # the stack param feeding the DUS is aliased, not read
                    dsrc = canon.get(ins.operands[0], ins.operands[0])
                    if dsrc in pidx:
                        out[pidx[dsrc]] = 0
        self._fusion_eff[called] = out
        return out

    _CONVERT_ONLY = {"parameter", "convert", "bitcast", "copy",
                     "get-tuple-element", "tuple", "constant"}

    def _is_pure_convert(self, called: str) -> bool:
        comp = self.comps.get(called, [])
        return bool(comp) and all(i.opcode in self._CONVERT_ONLY
                                  for i in comp)

    def _fusion_bytes(self, ins: Instr, called: str) -> int:
        # Pure dtype-convert fusions (bf16<->f32 round trips of whole
        # buffers) are XLA:CPU emulation artifacts — the CPU backend has no
        # native bf16 compute/loop-carry support. The TPU backend this
        # roofline targets consumes bf16 natively and never materializes
        # them, so they are counted as free.
        if self._is_pure_convert(called):
            return 0
        eff = self._fusion_param_effective(called)
        total = 0
        for i, opn in enumerate(ins.operands):
            full = type_bytes(self.types.get(opn, ""))
            total += eff[i] if i in eff else full
        res = type_bytes(ins.type_str)
        if "__root_dus__" in eff and eff["__root_dus__"] is not None:
            res = eff["__root_dus__"]             # in-place write of the slice
        return total + res

    # -- per-instruction ----------------------------------------------------
    def _operand_bytes(self, ins: Instr) -> int:
        return sum(type_bytes(self.types.get(o, "")) for o in ins.operands)

    def _dot_flops(self, ins: Instr) -> float:
        out_elems = type_elems(ins.type_str)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        if not m or not ins.operands:
            return 2.0 * out_elems  # degenerate
        lhs_shape = shapes_of(self.types.get(ins.operands[0], ""))
        if not lhs_shape:
            return 2.0 * out_elems
        dims = lhs_shape[0][1]
        k = 1
        for ci in m.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
        return 2.0 * out_elems * k

    def _conv_flops(self, ins: Instr) -> float:
        out_elems = type_elems(ins.type_str)
        if len(ins.operands) < 2:
            return 2.0 * out_elems
        kshape = shapes_of(self.types.get(ins.operands[1], ""))
        if not kshape:
            return 2.0 * out_elems
        kelems = 1
        for d in kshape[0][1]:
            kelems *= d
        # output feature count: dim labelled 'f' on the output side
        m = re.search(r"dim_labels=\S*->(\w+)", ins.line)
        oshape = shapes_of(ins.type_str)
        cout = 1
        if m and oshape:
            lab = m.group(1)
            if "f" in lab and len(lab) == len(oshape[0][1]):
                cout = oshape[0][1][lab.index("f")]
        return 2.0 * out_elems * (kelems / max(cout, 1))

    def _collective_wire_bytes(self, ins: Instr) -> float:
        op = ins.opcode.replace("-start", "")
        res = type_bytes(ins.type_str)
        opb = self._operand_bytes(ins)
        if op == "all-reduce":
            return 2.0 * min(res, opb) if opb else 2.0 * res
        if op == "all-gather":
            return float(res)
        if op == "reduce-scatter":
            return float(opb or res)
        if op in ("all-to-all", "ragged-all-to-all"):
            return float(opb or res)
        return float(opb or res)  # collective-permute / broadcast

    # -- per-computation ----------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        total = Cost()
        self._memo[name] = total  # cycle guard (self-recursion impossible)
        for ins in self.comps.get(name, []):
            oc = ins.opcode
            if oc == "while":
                m = _TRIP_RE.search(ins.line)
                trips = int(m.group(1)) if m else 1
                bm = _BODY_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                if bm:
                    total.add(self.comp_cost(bm.group(1)), trips)
                if cm:
                    total.add(self.comp_cost(cm.group(1)), trips)
            elif oc == "conditional":
                bs = _BRANCHES_RE.search(ins.line)
                if bs:
                    names = [s.strip().lstrip("%") for s in
                             bs.group(1).split(",") if s.strip()]
                    for n2 in names:  # upper bound: all branches
                        total.add(self.comp_cost(n2), 1.0 / max(len(names), 1))
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc in ("call", "async-start"):
                cm = _CALLS_RE.search(ins.line) or _TO_APPLY_RE.search(ins.line)
                if cm:
                    total.add(self.comp_cost(cm.group(1)))
            elif oc == "fusion":
                cm = _CALLS_RE.search(ins.line)
                if cm:
                    inner = self.comp_cost(cm.group(1))
                    total.flops += inner.flops
                    # fusion internals touch no HBM; the boundary does —
                    # with dynamic-(update-)slice params charged at slice size
                    total.bytes += self._fusion_bytes(ins, cm.group(1))
                else:
                    total.bytes += (type_bytes(ins.type_str)
                                    + self._operand_bytes(ins))
            elif oc in _COLLECTIVES:
                wire = self._collective_wire_bytes(ins)
                key = ins.opcode.replace("-start", "")
                total.coll[key] = total.coll.get(key, 0.0) + wire
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc in _COLLECTIVE_DONE or oc in _FREE:
                continue
            elif oc == "dot":
                total.flops += self._dot_flops(ins)
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc == "convolution":
                total.flops += self._conv_flops(ins)
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc == "reduce":
                total.flops += self._operand_bytes(ins) / 4.0  # ~1 flop/elem
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc in _ELEMWISE:
                total.flops += type_elems(ins.type_str)
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
            elif oc == "dynamic-slice":
                # reads the slice, writes the slice — not the source buffer
                total.bytes += 2 * type_bytes(ins.type_str)
            elif oc == "dynamic-update-slice":
                upd = (type_bytes(self.types.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                total.bytes += 2 * upd            # in-place slice write
            else:
                # copy, broadcast, transpose, reshape, slice, scatter,
                # gather, pad, concatenate, convert, rng, sort, ...:
                # data movement only
                total.bytes += type_bytes(ins.type_str) + self._operand_bytes(ins)
        self._memo[name] = total
        return total

    def analyze(self) -> dict:
        c = self.comp_cost(self.entry)
        coll = dict(c.coll)
        coll["total"] = sum(coll.values())
        return {"flops": c.flops, "bytes": c.bytes, "collectives": coll}


def analyze_hlo(text: str) -> dict:
    """-> {'flops', 'bytes', 'collectives': {kind: wire_bytes, 'total': ...}}

    All values are per-device; while bodies are multiplied by their static
    trip counts (nested loops multiply through)."""
    return HloAnalyzer(text).analyze()


if __name__ == "__main__":  # pragma: no cover — ad-hoc CLI
    import sys
    print(json.dumps(analyze_hlo(open(sys.argv[1]).read()), indent=1))
