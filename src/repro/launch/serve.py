"""Serving CLI — a thin front door over the continuous-batching engine
(`runtime.engine.ServeEngine`).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --requests 8 --prompt-len 16 --gen 12 [--exec aimc] [--int8] \
        [--trace poisson:200] [--slots 4] [--static]

The paper's deployment model made literal (§IV-B, Fig. 4): with ``--exec
aimc`` the whole network is programmed ONCE (CM_INITIALIZE, outside the
region of interest), the `AimcProgram` is install()ed into the parameter
tree, and every token vector afterwards pays only queue/process/dequeue on
stationary crossbar weights. The engine then serves a REQUEST STREAM against
that installed program: ragged prompts, staggered arrivals, per-request
decode budgets, slot-based continuous batching with jit-stable shapes.

Load shapes:
  (default)            synchronized arrivals — every request at t=0, one
                       prompt length, one decode budget (the legacy regime)
  --trace poisson:RATE staggered Poisson arrivals at RATE req/s with ragged
                       prompt lengths in [prompt_len/2, prompt_len] and
                       per-request max_new in [1, gen]
  --arrivals a,b,c     explicit arrival offsets (seconds), one per request
  --static             the legacy monolithic static-batch loop (one batched
                       prefill + lockstep decode) for A/B against the engine

``--reprogram`` restores the per-call STE path (the network re-programs
every forward) for A/B measurement of the program-once speedup. ``--int8``
stores the digital weights in the paper's number format. Recurrent archs
(xlstm, rglru) serve through per-slot hidden-state insertion/reset — no
longer rejected.

``--mesh data:D,model:M`` serves through the SHARDED engine
(`runtime.engine.ShardedServeEngine`, DESIGN.md §11): decode slots shard
over the data axis, programmed crossbar bit lines over the model axis, and
the decode output is bit-equal to the single-device engine. Combined with
``--cores N`` the per-core CM_* ledgers additionally report per mesh
device (`CoreSchedule.mesh_placement`). The legacy ``DxM`` spelling keeps
the single-device engine. CPU hosts must force the device count BEFORE
launch: ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--models NAME:EXEC[,NAME:EXEC...]`` switches to the MULTI-TENANT server
(`runtime.server.ModelServer`, DESIGN.md §12): every listed model is kept
resident in one process — the AIMC ones co-programmed against a single
shared crossbar budget (`core.program.TilePool`; cap it with
``--tile-budget``) — and an interleaved Poisson trace is routed by tenant:

    PYTHONPATH=src python -m repro.launch.serve --smoke \
        --models granite-8b:aimc,xlstm-350m:digital \
        --tenants premium:granite-8b:2,standard:granite-8b:1:sjf,\
batch:xlstm-350m --requests 16 --trace poisson:200

``--tenants NAME:MODEL[:WEIGHT[:ADMISSION]]`` declares each tenant's
routing target, fair-share weight for its model's decode slots, and
admission order (default: one fifo tenant per model, weight 1). The run
prints per-tenant tok/s, p50/p99 TTFT/TPOT, Jain's quota-fairness index
and the pool utilization, and exits nonzero if any per-tenant CM_* ledger
fails to reconcile or a tenant with requests was starved of all tokens.

``--page-size P`` swaps the dense per-slot KV cache for a paged one
(DESIGN.md §15): fixed P-row pages in one pool, addressed through a traced
page table, bit-equal to the dense engine. On top of it ``--prefix-cache``
shares content-hashed prompt-prefix pages across requests (a hit admits
without re-running the shared span's prefill — shape the trace with
``--shared-prefix K``) and ``--prefill-chunk C`` runs long prefills as
bounded legs interleaved with decode. ``--paged-verify`` makes the run exit
nonzero unless the page ledger reconciles exactly, nothing recompiled after
warmup, and the exactly-once prefill contract held. All of it passes
through to the multi-tenant server (``--models``), where
`tenancy.TenantPolicy.max_pages` additionally caps each tenant's page take.

``--drift NU`` ages the programmed conductances along the power law on the
serve clock and ``--chaos kill:CORE@CHUNK,corrupt:CORE@CHUNK[:MAG]``
injects deterministic faults on the chunk-dispatch clock (DESIGN.md §14):
the engine probes the live states at chunk boundaries against the digital
oracle, drains dead cores onto peers, hot-reprograms past
``--health-threshold``, and the run exits nonzero unless every request
retires, every fault fires, and the CM_* + recal CM_INITIALIZE books close
exactly. ``--heartbeat PATH`` beats a liveness file per chunk/pass.
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12,
                    help="decode budget: max_new per request (includes the "
                         "prefill's first token)")
    ap.add_argument("--slots", type=int, default=0,
                    help="decode slots (continuous-batching batch rows); "
                         "0 -> min(requests, 8)")
    ap.add_argument("--trace", default="",
                    help="synthetic load: poisson:RATE (req/s, staggered "
                         "ragged arrivals); default synchronized")
    ap.add_argument("--arrivals", default="",
                    help="explicit comma-separated arrival offsets in "
                         "seconds, one per request")
    ap.add_argument("--static", action="store_true",
                    help="legacy monolithic static-batch loop (A/B baseline)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="EOS token id for early retirement (-1: disabled)")
    ap.add_argument("--admission", default="fifo", choices=["fifo", "sjf"])
    ap.add_argument("--decode-chunk", dest="decode_chunk", type=int,
                    default=1,
                    help="decode steps per jitted scan chunk (k): retirement"
                         " runs on-device and the host syncs once per k "
                         "steps, double-buffered (DESIGN.md §13); 1 = the "
                         "per-step loop")
    ap.add_argument("--page-size", dest="page_size", type=int, default=0,
                    help="paged slot cache (DESIGN.md §15): KV pages of "
                         "this many token rows behind a traced page table "
                         "(transformer archs; recurrent archs page state "
                         "snapshots). Decode stays bit-equal to the dense "
                         "cache. 0 = dense")
    ap.add_argument("--pages", type=int, default=0,
                    help="page-pool size including the scratch page "
                         "(0: sized so every slot can hold a max-length "
                         "request)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true",
                    help="content-hashed prefix cache over full pages: a "
                         "request whose prompt prefix is resident admits "
                         "WITHOUT re-running the shared span's prefill "
                         "(needs --page-size)")
    ap.add_argument("--prefill-chunk", dest="prefill_chunk", type=int,
                    default=0,
                    help="run prefills as bounded legs of this many tokens, "
                         "interleaved with decode chunks (needs "
                         "--page-size; 0 = one full-width prefill)")
    ap.add_argument("--shared-prefix", dest="shared_prefix", type=int,
                    default=0,
                    help="make the first K prompt tokens identical across "
                         "every request (the shared-system-prompt shape "
                         "the prefix cache exists for)")
    ap.add_argument("--paged-verify", dest="paged_verify",
                    action="store_true",
                    help="hard acceptance for a paged run: exit nonzero "
                         "unless the page ledger reconciles exactly, no "
                         "closure recompiled after warmup, and (with "
                         "--shared-prefix + --prefix-cache, synchronized, "
                         "unchunked) the shared span was prefilled exactly "
                         "once")
    ap.add_argument("--mesh", default="1x1",
                    help="device mesh: 'data:D,model:M' serves through the "
                         "sharded engine (slots over data, crossbar bit "
                         "lines over model; bit-equal to the single-device "
                         "path); legacy 'DxM' keeps the single-device "
                         "engine. Needs D*M visible devices (CPU: set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N before launch)")
    ap.add_argument("--exec", dest="exec_mode", default="digital",
                    choices=["digital", "aimc"])
    ap.add_argument("--reprogram", action="store_true",
                    help="legacy AIMC path: re-program every forward call "
                         "(per-call STE) instead of program-once/apply-many")
    ap.add_argument("--cores", type=int, default=1,
                    help="virtual AIMC cores: the MappingPlan spreads the "
                         "programmed matrices over this many per-core tile "
                         "contexts and serving reports per-core CM_*/comm "
                         "ledgers (core.schedule)")
    ap.add_argument("--pipeline", action="store_true",
                    help="price the multi-core schedule with the "
                         "position-pipelined latency law instead of the "
                         "sequential mutex chain")
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--drift", type=float, default=0.0,
                    help="conductance drift exponent nu (power-law decay of "
                         "the programmed weights on the serve clock); the "
                         "health monitor probes at chunk boundaries and "
                         "hot-reprograms cores whose output error passes "
                         "--health-threshold (DESIGN.md §14). 0 = off")
    ap.add_argument("--drift-t0", dest="drift_t0", type=float, default=0.05,
                    help="drift reference time t0 in seconds (decay starts "
                         "once program age exceeds t0)")
    ap.add_argument("--chaos", default="",
                    help="deterministic fault injection on the chunk-"
                         "dispatch clock: kill:CORE@CHUNK / "
                         "corrupt:CORE@CHUNK[:MAG], comma-joined (e.g. "
                         "'corrupt:0@2:0.5,kill:1@4'). The engine must "
                         "detect, drain and hot-reprogram with exact CM_* "
                         "books — the run exits nonzero if any request is "
                         "lost, any event never fires, or the recal ledger "
                         "drifts")
    ap.add_argument("--health-threshold", dest="health_threshold",
                    type=float, default=0.05,
                    help="per-core relative probe error that triggers hot "
                         "recalibration")
    ap.add_argument("--heartbeat", default="",
                    help="liveness file beaten once per chunk (engine) or "
                         "pass (server) with slot occupancy and the last-"
                         "chunk wall timestamp (fault_tolerance.Heartbeat)")
    ap.add_argument("--models", default="",
                    help="multi-tenant server: NAME:EXEC[,NAME:EXEC...] "
                         "(EXEC: aimc|digital) keeps every listed model "
                         "resident — AIMC ones co-programmed on ONE shared "
                         "TilePool — and routes a mixed trace by tenant "
                         "(supersedes --arch/--exec)")
    ap.add_argument("--tenants", default="",
                    help="with --models: NAME:MODEL[:WEIGHT[:ADMISSION]]"
                         "[,...] — routing target, fair-share slot weight "
                         "and fifo/sjf admission per tenant (default: one "
                         "fifo tenant per model, weight 1)")
    ap.add_argument("--tile-budget", type=int, default=0,
                    help="with --models: cap the shared pool at this many "
                         "crossbar tiles per context (0: uncapped); "
                         "co-programmed models exceeding it together fail "
                         "with CapacityError at program time")
    ap.add_argument("--placement", default="",
                    help="auto[:BUDGET] — search the analog/digital split "
                         "per layer with the cost-model placer "
                         "(core.placement, DESIGN.md §16) instead of the "
                         "default MappingPlan patterns. BUDGET caps the "
                         "crossbar tiles per context; a model whose "
                         "profitable layers exceed it serves through a "
                         "time-multiplexed rotation plan, reprogramming "
                         "cold groups at decode-chunk boundaries (billed "
                         "as CM_INITIALIZE per swap). Needs --exec aimc")
    ap.add_argument("--placement-verify", dest="placement_verify",
                    action="store_true",
                    help="hard acceptance for a --placement run: exit "
                         "nonzero unless every request served, tokens are "
                         "bit-equal to the all-digital static oracle, "
                         "every rotation state packs within the budget, "
                         "the per-swap CM_INITIALIZE books close exactly, "
                         "and no closure recompiled after warmup")
    ap.add_argument("--swap-every", dest="swap_every", type=int, default=1,
                    help="with an overflowing --placement auto:BUDGET: "
                         "advance the rotation one state every this many "
                         "decode chunks (default 1)")
    ap.add_argument("--tile-rows", dest="tile_rows", type=int, default=0,
                    help="crossbar word lines per physical tile "
                         "(0: AimcConfig default, 512). Smaller tiles "
                         "split matrices into more row blocks — the knob "
                         "CI uses to force capacity overflow on smoke "
                         "models")
    ap.add_argument("--adc-alpha", dest="adc_alpha", type=float, default=0.0,
                    help="ADC clipping alpha (0: AimcConfig default)")
    args = ap.parse_args(argv)
    args.placement_budget = 0
    if args.placement:
        mode, _, budget = args.placement.partition(":")
        if mode != "auto" or (budget and not budget.isdigit()):
            ap.error(f"--placement {args.placement!r}: expected "
                     "auto or auto:BUDGET (BUDGET a positive integer)")
        if budget and int(budget) < 1:
            ap.error(f"--placement budget must be >= 1, got {budget}")
        args.placement_budget = int(budget) if budget else 0
        if args.exec_mode != "aimc" or args.reprogram:
            ap.error("--placement searches the programmed AIMC path "
                     "(--exec aimc, without --reprogram)")
        for on, name in [(args.models, "--models"),
                         (args.static, "--static"),
                         (args.drift, "--drift"), (args.chaos, "--chaos"),
                         (args.prefix_cache, "--prefix-cache"),
                         (args.prefill_chunk, "--prefill-chunk")]:
            if on:
                ap.error(f"--placement cannot combine with {name} "
                         "(rotation swaps and cached/chunked prefill "
                         "spans or mid-trace repairs do not compose)")
    if args.placement_verify:
        if not args.placement:
            ap.error("--placement-verify requires --placement")
        if args.trace or args.arrivals or args.eos >= 0:
            ap.error("--placement-verify compares against the synchronized "
                     "static oracle: drop --trace/--arrivals/--eos")
    if args.swap_every < 1:
        ap.error(f"--swap-every must be >= 1, got {args.swap_every}")
    if args.tile_rows < 0 or args.adc_alpha < 0:
        ap.error("--tile-rows/--adc-alpha must be >= 0")
    if args.chaos or args.drift:
        flag = "--chaos" if args.chaos else "--drift"
        if args.exec_mode != "aimc" or args.reprogram:
            ap.error(f"{flag} degrades/repairs PROGRAMMED crossbar states: "
                     "it requires --exec aimc without --reprogram")
        if args.static or args.models:
            ap.error(f"{flag} runs through the engine's chunk-boundary "
                     "resilience tick (drop --static/--models)")
    if args.chaos and args.cores < 2:
        ap.error("--chaos needs --cores >= 2: a killed core drains onto "
                 "surviving peers, so there must be at least one")
    if args.drift < 0:
        ap.error(f"--drift must be >= 0, got {args.drift}")
    if args.models:
        for on, name in [(args.static, "--static"), (args.int8, "--int8"),
                         (args.reprogram, "--reprogram"),
                         (args.cores > 1, "--cores"),
                         (args.pipeline, "--pipeline"),
                         (args.arrivals, "--arrivals"),
                         (args.paged_verify, "--paged-verify"),
                         (args.shared_prefix, "--shared-prefix")]:
            if on:
                ap.error(f"{name} is a single-model option; --models serves "
                         "through the multi-tenant ModelServer")
    elif args.tenants or args.tile_budget:
        ap.error("--tenants/--tile-budget require --models")
    if ((args.cores > 1 or args.pipeline)
            and (args.exec_mode != "aimc" or args.reprogram)):
        ap.error("--cores/--pipeline require the programmed AIMC path "
                 "(--exec aimc, without --reprogram): the multi-core "
                 "schedule lowers an installed AimcProgram")
    if args.trace and args.arrivals:
        ap.error("--trace and --arrivals are mutually exclusive")
    if args.static and (args.trace or args.arrivals):
        ap.error("--static serves one synchronized batch; staggered "
                 "traces/arrivals need the engine")
    if args.decode_chunk < 1:
        ap.error(f"--decode-chunk must be >= 1, got {args.decode_chunk}")
    if args.static and args.decode_chunk > 1:
        ap.error("--decode-chunk applies to the engine's scanned decode "
                 "loop; --static is the legacy lockstep baseline")
    if args.page_size < 0 or args.pages < 0 or args.prefill_chunk < 0 \
            or args.shared_prefix < 0:
        ap.error("--page-size/--pages/--prefill-chunk/--shared-prefix "
                 "must be >= 0")
    if args.page_size == 0 and (args.prefix_cache or args.prefill_chunk
                                or args.pages or args.paged_verify):
        ap.error("--prefix-cache/--prefill-chunk/--pages/--paged-verify "
                 "require --page-size")
    if args.page_size and args.static:
        ap.error("--page-size serves through the slot engine; --static is "
                 "the legacy dense-batch baseline")
    if args.shared_prefix and args.shared_prefix >= args.prompt_len:
        ap.error(f"--shared-prefix {args.shared_prefix} must leave every "
                 f"request a unique continuation (< --prompt-len "
                 f"{args.prompt_len})")
    return args


def parse_mesh(arg: str):
    """(shape, axes, sharded) from a --mesh string.

    ``data:D,model:M`` (any subset/order of named axes) selects the sharded
    engine; the legacy ``DxM`` / ``PxDxM`` positional syntax keeps the
    single-device `ServeEngine` (mesh used for context only, as before)."""
    if ":" in arg:
        pairs = [p.split(":", 1) for p in arg.split(",")]
        bad = [p for p in pairs if len(p) != 2 or not p[1].isdigit()
               or int(p[1]) < 1]
        if bad or not pairs:
            raise SystemExit(f"--mesh {arg!r}: expected AXIS:SIZE[,AXIS:SIZE]"
                             " with SIZE >= 1 (e.g. data:2,model:1)")
        axes = tuple(name for name, _ in pairs)
        if len(set(axes)) != len(axes):
            raise SystemExit(f"--mesh {arg!r}: duplicate axis")
        return tuple(int(s) for _, s in pairs), axes, True
    try:
        shape = tuple(int(s) for s in arg.split("x"))
    except ValueError:
        raise SystemExit(f"--mesh {arg!r}: expected DxM / PxDxM or the "
                         "named AXIS:SIZE[,AXIS:SIZE] syntax") from None
    if len(shape) not in (2, 3) or any(s < 1 for s in shape):
        raise SystemExit(f"--mesh {arg!r}: positional syntax takes 2 (DxM) "
                         "or 3 (PxDxM) sizes, each >= 1")
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
    return shape, axes, False


def parse_named_mesh(arg: str):
    """(shape, axes) from a --mesh string, REQUIRING the named syntax.

    The benchmark/sharded entry points take only ``data:D,model:M`` — the
    legacy positional spelling means "single-device engine" in this CLI and
    must not silently select the sharded one elsewhere."""
    shape, axes, sharded = parse_mesh(arg)
    if not sharded:
        raise SystemExit(f"--mesh {arg!r}: this path takes the named "
                         "AXIS:SIZE[,AXIS:SIZE] syntax (e.g. "
                         "data:2,model:1); the positional DxM spelling "
                         "selects the single-device engine in launch.serve")
    return shape, axes


def force_host_device_count(arg: str):
    """Parse a named --mesh spec and force the XLA host-platform device
    count to fit it. MUST run before the first jax backend use (the device
    count is fixed at backend init) — call it at the top of a ``__main__``
    entry point, never from library code. Returns (shape, axes).

    The flag is a silent no-op once the backend is up, so after setting it
    this VERIFIES the device count actually covers the mesh (initializing
    the backend right here if it was not already) and exits nonzero
    otherwise — a data:2 run must never proceed on 1 device while claiming
    a 2-device mesh."""
    import math
    import os
    shape, axes = parse_named_mesh(arg)
    need = math.prod(shape)
    if need > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need} "
            + os.environ.get("XLA_FLAGS", ""))
        import jax
        have = jax.device_count()
        if have < need:
            raise SystemExit(
                f"--mesh {arg!r} needs {need} devices but the JAX backend "
                f"is already initialized with {have}: XLA_FLAGS was set too "
                f"late to take effect. Export XLA_FLAGS=--xla_force_host_"
                f"platform_device_count={need} before the process first "
                f"touches jax, or call force_host_device_count() before "
                f"any jax use.")
    return shape, axes


def build_requests(args, vocab: int, min_prompt: int = 1):
    """The synthetic request stream the CLI serves. ``min_prompt`` floors
    the ragged prompt lengths (vlm prompts must cover the patch prefix)."""
    from repro.runtime.batcher import poisson_trace, synchronized_trace
    n, p, g = args.requests, args.prompt_len, args.gen
    if p < min_prompt:
        raise SystemExit(f"--prompt-len {p} < minimum prompt length "
                         f"{min_prompt} for this arch")
    if args.trace:
        kind, _, param = args.trace.partition(":")
        if kind != "poisson":
            raise SystemExit(f"unknown --trace kind {kind!r} "
                             "(supported: poisson:RATE)")
        rate = float(param or "100")
        return poisson_trace(n, rate, seed=args.seed,
                             prompt_len=(max(min_prompt, p // 2), p),
                             max_new=(1, g), vocab=vocab)
    base = synchronized_trace(n, prompt_len=p, max_new=g, seed=args.seed,
                              vocab=vocab)
    if args.arrivals:
        offs = [float(x) for x in args.arrivals.split(",")]
        if len(offs) != n:
            raise SystemExit(f"--arrivals needs {n} offsets, got {len(offs)}")
        base = [dataclasses.replace(r, arrival=t) for r, t in zip(base, offs)]
    return base


def apply_shared_prefix(requests, k: int):
    """Overwrite the first ``k`` tokens of every prompt with request 0's —
    the shared-system-prompt shape the prefix cache exists for. Prompts
    shorter than ``k`` become a prefix of the shared span."""
    if not k:
        return requests
    shared = requests[0].prompt[:k]
    return [dataclasses.replace(
        r, prompt=(shared + r.prompt[k:] if len(r.prompt) > k
                   else shared[:len(r.prompt)]))
        for r in requests]


def parse_models(arg: str):
    """``NAME:EXEC[,NAME:EXEC...]`` -> list of `runtime.server.ModelSpec`.
    NAME is an arch-registry id (aliases fine) and doubles as the model id
    requests route by."""
    from repro.runtime.server import ModelSpec
    specs = []
    for part in arg.split(","):
        name, _, mode = part.partition(":")
        if not name:
            raise SystemExit(f"--models {arg!r}: empty model name")
        try:
            specs.append(ModelSpec(name=name, arch=name,
                                   exec_mode=mode or "digital"))
        except ValueError as e:
            raise SystemExit(f"--models {arg!r}: {e}") from None
    return specs


def parse_tenants(arg: str, specs):
    """``NAME:MODEL[:WEIGHT[:ADMISSION]][,...]`` -> `TenantPolicy` list."""
    from repro.runtime.tenancy import TenantPolicy
    known = {s.name for s in specs}
    out = []
    for part in arg.split(","):
        fields = part.split(":")
        if len(fields) < 2 or len(fields) > 4:
            raise SystemExit(f"--tenants {arg!r}: expected "
                             "NAME:MODEL[:WEIGHT[:ADMISSION]], got {part!r}")
        name, model = fields[0], fields[1]
        if model not in known:
            raise SystemExit(f"--tenants {arg!r}: tenant {name!r} routes to "
                             f"{model!r}, not in --models ({sorted(known)})")
        try:
            out.append(TenantPolicy(
                name=name, model=model,
                weight=float(fields[2]) if len(fields) > 2 else 1.0,
                admission=fields[3] if len(fields) > 3 else "fifo"))
        except ValueError as e:
            raise SystemExit(f"--tenants {arg!r}: {e}") from None
    return out


def _run_server(args):
    """The --models path: multi-tenant multi-model serving over one pool."""
    from repro.compat import use_mesh
    from repro.configs import get_arch
    from repro.launch.mesh import make_mesh
    from repro.runtime.server import build_server
    from repro.runtime.tenancy import mixed_poisson_trace

    specs = parse_models(args.models)
    tenants = parse_tenants(args.tenants, specs) if args.tenants else None
    shape, axes, sharded = parse_mesh(args.mesh)
    mesh = make_mesh(shape, axes) if sharded else None

    rate = 100.0
    if args.trace:
        kind, _, param = args.trace.partition(":")
        if kind != "poisson":
            raise SystemExit(f"unknown --trace kind {kind!r} "
                             "(supported: poisson:RATE)")
        rate = float(param or "100")

    p, g = args.prompt_len, args.gen
    n_slots = args.slots or 4
    with use_mesh(mesh) if mesh is not None else _nullcontext():
        t0 = time.time()
        server = build_server(
            specs, tenants, smoke=args.smoke, n_slots=n_slots,
            prompt_pad=p, max_seq=p + g, seed=args.seed,
            tiles_per_context=args.tile_budget or None,
            eos_id=None if args.eos < 0 else args.eos, mesh=mesh,
            page_size=args.page_size, n_pages=args.pages,
            prefix_cache=args.prefix_cache,
            prefill_chunk=args.prefill_chunk)
        server.warmup()
        print(f"[serve] {len(specs)} model(s) resident, "
              f"{len(server.policies)} tenant(s), {n_slots} slots each; "
              f"built+warmed in {time.time() - t0:.2f}s")
        if server.pool is not None:
            print(f"[serve] {server.pool.summary()} "
                  f"(crossbar-capacity utilization "
                  f"{server.pool.utilization * 100:.0f}%)")

        def vocab(s):
            a = get_arch(s.arch)
            return (a.smoke_cfg if args.smoke else a.model_cfg).vocab

        trace = mixed_poisson_trace(
            list(server.policies.values()), args.requests, rate,
            vocab_of={s.name: vocab(s) for s in specs}, seed=args.seed,
            prompt_len=(max(1, p // 2), p), max_new=(1, g))
        heartbeat = None
        if args.heartbeat:
            from repro.runtime.fault_tolerance import Heartbeat
            heartbeat = Heartbeat(args.heartbeat)
        report = server.serve(trace, heartbeat=heartbeat)
        print(f"[serve] {report.summary()}")
        for m in server.engines:
            shares = server.fair_shares(m)
            print(f"  {m}: entitled slots "
                  + ", ".join(f"{t}={v:.2f}" for t, v in sorted(shares.items())))

        recon = server.reconcile(report)
        for m, ok in sorted(recon.items()):
            label = {True: "True", False: "FAILED", None: "n/a (digital)"}[ok]
            print(f"  {m}: per-tenant CM_* ledgers reconcile against "
                  f"program.mvm_counts(): {label}")
        stats = report.tenant_stats()
        starved = [name for name, st in stats.items()
                   if st.n_requests > 0 and st.generated_tokens == 0]
        if starved:
            print(f"[serve] STARVED tenants (had requests, got 0 tokens): "
                  f"{starved}")
        if any(ok is False for ok in recon.values()) or starved:
            raise SystemExit(1)
        return report


def _nullcontext():
    import contextlib
    return contextlib.nullcontext()


def main(argv=None):
    args = parse_args(argv)
    if args.models:
        return _run_server(args)
    import jax
    import jax.numpy as jnp

    from repro.compat import use_mesh
    from repro.configs import get_arch
    from repro.core.aimc import AimcConfig
    from repro.launch.mesh import make_mesh
    from repro.models.layers import Execution
    from repro.runtime.engine import ServeEngine, ShardedServeEngine

    spec = get_arch(args.arch)
    if args.smoke:
        spec = dataclasses.replace(spec, model_cfg=spec.smoke_cfg)
    cfg = spec.model_cfg
    if spec.family == "audio":
        raise SystemExit("serve.py drives decoder-only LMs; the enc-dec "
                         "audio family decodes via launch.steps")

    shape, axes, sharded = parse_mesh(args.mesh)
    if sharded and args.static:
        raise SystemExit("--static is the single-device A/B oracle; "
                         "the sharded engine needs the named-mesh engine "
                         "path (drop --static or use the legacy DxM syntax)")
    mesh = make_mesh(shape, axes)
    aimc_kw = {}
    if args.tile_rows:
        aimc_kw["tile_rows"] = args.tile_rows
    if args.adc_alpha:
        aimc_kw["adc_alpha"] = args.adc_alpha
    aimc_cfg = AimcConfig(impl="ref", **aimc_kw)
    exe = (Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                     programmed=not args.reprogram)
           if args.exec_mode == "aimc"
           else Execution(compute_dtype="float32" if args.smoke
                          else "bfloat16", serve_int8=args.int8))

    model = spec.model_module()
    b, p, g = args.requests, args.prompt_len, args.gen
    max_seq = p + g
    requests = build_requests(
        args, cfg.vocab,
        min_prompt=cfg.n_patches if spec.family == "vlm" else 1)
    requests = apply_shared_prefix(requests, args.shared_prefix)

    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed), cfg)
        if args.int8:
            from repro.core.quant import quantize_params_int8
            from repro.launch.shardings import (EXPERT_IN, EXPERT_OUT,
                                                IN_PROJ, OUT_PROJ)
            params = quantize_params_int8(
                params, IN_PROJ | OUT_PROJ | EXPERT_IN | EXPERT_OUT
                | {"unembed"})

        program = None
        schedule = None
        health = None
        chaos = None
        rotation = None
        rotation_params = None
        placement = None
        params_raw = params
        if args.exec_mode == "aimc" and not args.reprogram:
            # CM_INITIALIZE: program the whole network once, outside the
            # serving loop (paper §IV-B). --cores spreads the matrices over
            # per-core tile contexts (paper Fig. 2).
            from repro.core.program import MappingPlan, program_model
            from repro.core.schedule import CoreSchedule
            t0 = time.time()
            plan = MappingPlan(n_contexts=args.cores)
            if args.placement:
                # cost-model-driven auto-placement (DESIGN.md §16): search
                # the analog/digital split under the tile budget; an
                # overflowing model gets a rotation plan whose states
                # time-multiplex the freed headroom
                from repro.core.placement import plan_placement
                placement = plan_placement(
                    params, plan, aimc_cfg,
                    tiles_per_context=args.placement_budget or None,
                    n_contexts=args.cores, swap_every=args.swap_every)
                print(f"[serve] {placement.summary()}")
                plan = (placement.rotation.plan()
                        if placement.rotation is not None
                        else placement.plan)
            prog_key = jax.random.PRNGKey(args.seed + 2)
            program = program_model(params, plan, aimc_cfg, prog_key)
            if placement is not None and placement.rotation is not None:
                # one uncapped program over every sometimes-analog layer;
                # each rotation state installs only its resident subset
                # (the rest serve digitally from the raw weights)
                rotation = placement.rotation
                rotation_params = tuple(
                    program.install_subset(params_raw, ns)
                    for ns in rotation.states())
                params = rotation_params[0]
            else:
                params = program.install(params)
            jax.block_until_ready(
                [st.w_q for st in program.states])
            print(f"[serve] programmed in {time.time() - t0:.2f}s: "
                  f"{program.summary()}")
            schedule = CoreSchedule.from_program(program,
                                                 pipelined=args.pipeline)
            if args.cores > 1 or args.pipeline:
                print(f"[serve] {schedule.summary()}")
            if args.drift or args.chaos:
                # drift-aware serving (DESIGN.md §14): reference weights +
                # programming keys captured off the RAW tree so hot
                # reprogramming is bit-exact
                from repro.core import noise as noise_lib
                from repro.runtime.chaos import parse_chaos
                from repro.runtime.health import HealthPolicy, build_health
                noise = (noise_lib.drift_only(nu=args.drift,
                                              t0=args.drift_t0)
                         if args.drift else None)
                health = build_health(
                    program, params_raw, plan, prog_key,
                    policy=HealthPolicy(threshold=args.health_threshold),
                    noise=noise)
                chaos = parse_chaos(args.chaos) if args.chaos else None
                what = " + ".join(
                    ([f"drift nu={args.drift:g} t0={args.drift_t0:g}s"]
                     if args.drift else [])
                    + ([f"chaos [{', '.join(e.describe() for e in chaos.events)}]"]
                       if chaos else []))
                print(f"[serve] resilience: {what}; probe threshold "
                      f"{args.health_threshold:g}")

        print(f"[serve] {spec.arch_id} exec={args.exec_mode} "
              f"int8={args.int8} requests={b}"
              + (" (per-call reprogram)" if args.exec_mode == "aimc"
                 and args.reprogram else ""))

        if args.static:
            return _run_static(args, spec, cfg, exe, model, params, program,
                               schedule, requests, max_seq, jnp)

        # ---- continuous batching (the deployment path) --------------------
        n_slots = args.slots or min(b, 8)
        heartbeat = None
        if args.heartbeat:
            from repro.runtime.fault_tolerance import Heartbeat
            heartbeat = Heartbeat(args.heartbeat)
        common = dict(n_slots=n_slots, prompt_pad=p, max_seq=max_seq,
                      cache_dtype=jnp.float32, family=spec.family,
                      module=spec.module, program=program, schedule=schedule,
                      eos_id=None if args.eos < 0 else args.eos,
                      admission=args.admission,
                      decode_chunk=args.decode_chunk,
                      page_size=args.page_size, n_pages=args.pages,
                      prefix_cache=args.prefix_cache,
                      prefill_chunk=args.prefill_chunk,
                      health=health, chaos=chaos, heartbeat=heartbeat,
                      rotation=rotation, rotation_params=rotation_params)
        if sharded:
            engine = ShardedServeEngine(model, cfg, exe, params, mesh=mesh,
                                        **common)
        else:
            engine = ServeEngine(model, cfg, exe, params, **common)
        t0 = time.time()
        counts0 = engine.warmup()
        print(f"[serve] engine warmed up in {time.time() - t0:.2f}s "
              f"({n_slots} slots, prompt_pad={p}, max_seq={max_seq}, "
              f"decode_chunk={args.decode_chunk}"
              + (f"; sharded over {dict(zip(axes, shape))}" if sharded
                 else "")
              + f"; compiled {counts0})")
        if args.page_size and engine.pages is not None:
            print(f"[serve] paged cache: {engine.pages.n_pages} pages x "
                  f"{args.page_size} rows (+1 scratch in the count), "
                  f"prefix_cache={args.prefix_cache}, "
                  f"prefill_chunk={args.prefill_chunk or 'off'}"
                  + (f", shared_prefix={args.shared_prefix}"
                     if args.shared_prefix else ""))

        report = engine.serve(requests)
        print(f"[serve] {report.summary()}")
        if report.n_steps == 0:
            print("  prefill-only run: no decode steps executed "
                  f"({report.n_prefills} prefills, "
                  f"{report.wall_prefill_s:.2f}s) — no decode tok/s to "
                  "report")
        else:
            print(f"  decode: {report.n_steps} batch steps in "
                  f"{report.wall_decode_s:.2f}s "
                  f"({report.wall_decode_s / report.n_steps * 1e3:.1f} "
                  f"ms/step); slot-idle lanes {report.idle_vectors}, "
                  f"retries {report.retries}, "
                  f"stragglers {len(report.stragglers)}")

        if program is not None and rotation is not None:
            # rotation books: the per-vector CM_* split varies by state, so
            # the per-request ledgers are ill-defined; what must close
            # exactly instead is the per-swap CM_INITIALIZE bill
            from repro.core.placement import reconcile_swaps
            init = program.initialize_counts()
            print(f"  CM_INITIALIZE: {init.initialize} device writes for "
                  f"the initial program ({rotation.n_states} rotation "
                  f"states over {len(rotation.all_names)} analog matrices)")
            print(f"  rotation: {report.n_swaps} swaps "
                  f"(every {rotation.swap_every} chunk(s)), swap "
                  f"CM_INITIALIZE={report.swap_initialize}, "
                  f"{report.wall_swap_s * 1e3:.0f}ms swap wall")
            for ev in report.swap_events[:3]:
                print(f"    swap@chunk{ev.chunk} -> state {ev.state}: "
                      f"{len(ev.incoming)} matrices, "
                      f"CM_INITIALIZE={ev.initialize}")
            print(f"  per-swap CM_INITIALIZE books close exactly: "
                  f"{reconcile_swaps(program, report)}")
        elif program is not None:
            init = program.initialize_counts()
            per_vec = program.mvm_counts()
            n_vec = report.useful_vectors
            roi = per_vec.scaled(n_vec)
            print(f"  CM_INITIALIZE: {init.initialize} device writes, once "
                  f"per session — independent of the {report.generated_tokens}"
                  f" generated tokens")
            print(f"  CM_* in the serving ROI ({n_vec} useful token "
                  f"vectors): queue={roi.queue} process={roi.process} "
                  f"dequeue={roi.dequeue} (per vector: {per_vec.queue}/"
                  f"{per_vec.process}/{per_vec.dequeue})")
            from repro.runtime.batcher import reconcile
            led_sum, static_sum = reconcile(program, report.records,
                                            report.observed_vectors)
            print(f"  per-request ledger sum reconciles with the program's "
                  f"static accounting: {led_sum == static_sum}")
            if sharded and schedule is not None:
                from repro.runtime.batcher import reconcile_cores
                core_sum, sched_total = reconcile_cores(
                    schedule, report.records, report.observed_vectors)
                print(f"  per-core ledgers (aggregated across shards) "
                      f"reconcile with the schedule totals: "
                      f"{core_sum == sched_total}")
                for dev, cm in sorted(engine.device_ledgers(report).items()):
                    print(f"    mesh device[{engine.model_axis}={dev}]: "
                          f"queue={cm.queue} process={cm.process} "
                          f"dequeue={cm.dequeue}")
        if health is not None:
            _verify_resilience(engine, report, requests, chaos)
        if args.page_size and engine.pages is not None:
            led = report.page_ledger
            print(f"  pages: {led.get('free', 0)} free / "
                  f"{led.get('held', 0)} held of "
                  f"{led.get('total', 0)} (ledger exact: "
                  f"{report.page_ledger_exact}); "
                  f"prefix hits {report.prefix_hits} "
                  f"({report.prefix_hit_vectors} prompt vectors never "
                  f"re-prefilled), evictions {report.page_evictions}; "
                  f"prefill legs {report.prefill_chunks}, "
                  f"prompt-pad waste {report.prefill_pad_vectors} vectors")
            if args.paged_verify:
                _verify_paged(engine, report, requests, args, counts0)
        if args.placement_verify:
            _verify_placement(engine, report, requests, args, placement,
                              program, params_raw, model, cfg, exe,
                              counts0, max_seq, jnp)
        _print_schedule(args, schedule)
        for rid in sorted(report.records)[:3]:
            rec = report.records[rid]
            print(f"  req{rid}: arrival={rec.request.arrival * 1e3:.1f}ms "
                  f"prompt={len(rec.request.prompt)} "
                  f"gen={len(rec.tokens)}/{rec.request.max_new} "
                  f"({rec.finish_reason}) ttft={rec.ttft * 1e3:.1f}ms "
                  f"latency={rec.latency * 1e3:.1f}ms "
                  f"tokens={rec.tokens[:6]}...")
        return report


def _run_static(args, spec, cfg, exe, model, params, program, schedule,
                requests, max_seq, jnp):
    """The legacy monolithic path: one synchronized batch, lockstep decode."""
    from repro.runtime.engine import static_generate
    b, p, g = args.requests, args.prompt_len, args.gen
    prompts = jnp.asarray([r.prompt for r in requests], jnp.int32)
    gen_toks, (t_prefill, t_decode) = static_generate(
        model, cfg, exe, params, prompts, g, max_seq=max_seq,
        cache_dtype=jnp.float32)
    print(f"  prefill: {b}x{p} tokens in {t_prefill:.2f}s")
    if g <= 1:
        # honest prefill-only report: a 0-step decode loop has no
        # throughput; the old script printed a tok/s line from
        # max(t_decode, 1e-9) here
        print("  decode:  0 steps (prefill-only, --gen 1) — no decode "
              "tok/s to report")
    else:
        print(f"  decode:  {g - 1} steps in {t_decode:.2f}s "
              f"({b * (g - 1) / max(t_decode, 1e-9):.1f} tok/s batched, "
              f"{t_decode / (g - 1) * 1e3:.1f} ms/step)")
    if program is not None:
        init = program.initialize_counts()
        per_vec = program.mvm_counts()
        n_vec = b * (p + g - 1)
        roi = per_vec.scaled(n_vec)
        print(f"  CM_INITIALIZE: {init.initialize} device writes, once "
              f"per session — independent of the {g} generated tokens")
        print(f"  CM_* in the serving ROI ({n_vec} token vectors): "
              f"queue={roi.queue} process={roi.process} "
              f"dequeue={roi.dequeue} (per vector: {per_vec.queue}/"
              f"{per_vec.process}/{per_vec.dequeue})")
    _print_schedule(args, schedule)
    for i in range(min(b, 3)):
        print(f"  req{i}: prompt={list(requests[i].prompt[:6])}... "
              f"-> gen={[int(t) for t in gen_toks[i]]}")
    return gen_toks


def _verify_resilience(engine, report, requests, chaos):
    """Hard acceptance for a drift/chaos run — the CI chaos smoke rides on
    this: exit nonzero if any request was lost, any scheduled fault never
    fired, the per-request CM_* books fail against the (possibly remapped)
    program, or the recalibration ledger does not close exactly."""
    from repro.runtime.batcher import reconcile
    from repro.runtime.health import reconcile_recal
    for ev in report.fault_events:
        print(f"  fault injected: {ev.describe()}")
    for ev in report.recal_events:
        print(f"  hot recal [{ev.reason}] cores={list(ev.cores)}: "
              f"{len(ev.names)} matrices reprogrammed, "
              f"CM_INITIALIZE={ev.initialize}, {ev.wall_s * 1e3:.0f}ms")
    print(f"  health: {report.probes} probes, {report.n_recals} recals, "
          f"recal CM_INITIALIZE={report.recal_initialize} (charged on top "
          f"of the session's program-once bill), "
          f"{report.wall_health_s:.2f}s health wall")
    failures = []
    if len(report.records) != len(requests):
        lost = ({r.rid for r in requests}
                - {rid for rid in report.records})
        failures.append(f"LOST {len(lost)} in-flight request(s): "
                        f"{sorted(lost)}")
    if chaos is not None and not chaos.exhausted:
        left = [e.describe() for e in chaos.events if e not in chaos.fired]
        failures.append(f"chaos events never fired: {left}")
    led_sum, static_sum = reconcile(engine.program, report.records,
                                    report.observed_vectors)
    if led_sum != static_sum:
        failures.append("per-request CM_* ledgers do not reconcile against "
                        "the recovered program")
    if not reconcile_recal(engine.program, report):
        failures.append("recalibration CM_INITIALIZE books do not close")
    if failures:
        for f in failures:
            print(f"  RESILIENCE FAILURE: {f}")
        raise SystemExit(1)
    print("  resilience books close exactly: no lost requests, every "
          "fault fired, CM_* + recal ledgers reconcile")


def _verify_paged(engine, report, requests, args, counts0):
    """Hard acceptance for a paged run — the CI paged smokes ride on this:
    exit nonzero unless every request retired, the page ledger reconciles
    exactly, no closure recompiled after warmup, the vector books close,
    and (shared-prefix + prefix-cache, synchronized, unchunked) the shared
    span was prefilled exactly once across the whole trace."""
    failures = []
    if len(report.records) != len(requests):
        lost = {r.rid for r in requests} - set(report.records)
        failures.append(f"{len(lost)} request(s) never served: "
                        f"{sorted(lost)}")
    if not report.page_ledger_exact:
        failures.append(f"page ledger does not reconcile: "
                        f"{report.page_ledger}")
    held = report.page_ledger.get("held", 0)
    cached = len(engine.prefix) if engine.prefix is not None else 0
    if held != cached:
        failures.append(f"{held} pages held at finish but {cached} prefix "
                        f"entries resident — a request leaked pages")
    if report.observed_vectors != report.useful_vectors:
        failures.append(f"device-loop vector count "
                        f"{report.observed_vectors} != per-request books "
                        f"{report.useful_vectors}")
    counts = engine.compile_counts()
    if counts != counts0:
        failures.append(f"closures recompiled after warmup: {counts0} -> "
                        f"{counts}")
    if (args.shared_prefix and args.prefix_cache and not args.prefill_chunk
            and not args.trace and not engine.recurrent):
        # synchronized + unchunked: admission is synchronous, so the
        # exactly-once contract is exact, not statistical
        span = (args.shared_prefix // args.page_size) * args.page_size
        plen = args.prompt_len
        paid = sorted(r.prefill_vectors for r in report.records.values())
        want = sorted([plen] + [plen - span] * (len(requests) - 1))
        if paid != want:
            failures.append(
                f"shared span not prefilled exactly once: per-request "
                f"prefill vectors {paid}, want one producer at {plen} and "
                f"{len(requests) - 1} sharers at {plen - span}")
        if report.prefix_hits != len(requests) - 1:
            failures.append(f"prefix hits {report.prefix_hits}, want "
                            f"{len(requests) - 1}")
    if failures:
        for f in failures:
            print(f"  PAGED FAILURE: {f}")
        raise SystemExit(1)
    print("  paged books close exactly: all requests served, page ledger "
          "reconciles, no recompiles"
          + (", shared span prefilled exactly once"
             if args.shared_prefix and args.prefix_cache
             and not args.prefill_chunk and not args.trace
             and not engine.recurrent else ""))


def _verify_placement(engine, report, requests, args, placement, program,
                      params_raw, model, cfg, exe, counts0, max_seq, jnp):
    """Hard acceptance for a --placement run — the CI placement smoke
    rides on this: exit nonzero unless every request retired, every token
    is bit-equal to the ALL-DIGITAL static oracle on the raw weights (the
    equality bar of DESIGN.md §16 — analog layers must be exact, not
    approximately right), every rotation state packs within the budget,
    the per-swap CM_INITIALIZE books close (`placement.reconcile_swaps`),
    an overflowing trace actually swapped, and nothing recompiled after
    warmup (swaps reuse the per-state executables)."""
    import dataclasses as _dc

    from repro.core.placement import reconcile_swaps
    from repro.core.tile import pack_contexts
    from repro.runtime.engine import static_generate
    failures = []
    if len(report.records) != len(requests):
        lost = {r.rid for r in requests} - set(report.records)
        failures.append(f"{len(lost)} request(s) never served: "
                        f"{sorted(lost)}")
    dig_exe = _dc.replace(exe, mode="digital")
    prompts = jnp.asarray([r.prompt for r in requests], jnp.int32)
    oracle, _ = static_generate(model, cfg, dig_exe, params_raw, prompts,
                                args.gen, max_seq=max_seq,
                                cache_dtype=jnp.float32)
    bad = [r.rid for i, r in enumerate(requests)
           if r.rid in report.records
           and report.tokens(r.rid) != [int(t) for t in oracle[i]]]
    if bad:
        failures.append(f"tokens diverge from the all-digital oracle for "
                        f"request(s) {bad}")
    rot = engine.rotation
    if rot is not None:
        for i, names in enumerate(rot.states()):
            resident = set(names)
            per = pack_contexts([c.item for c in placement.costs
                                 if c.path in resident],
                                rot.n_contexts, engine.program.cfg.tile_rows,
                                engine.program.cfg.tile_cols)
            if max(per) > rot.tiles_per_context:
                failures.append(
                    f"rotation state {i} packs to {max(per)} tiles > "
                    f"budget {rot.tiles_per_context}")
        if rot.n_states > 1 and report.n_swaps == 0:
            failures.append("overflowing plan never swapped (trace too "
                            "short for the swap cadence?)")
        if not reconcile_swaps(program, report):
            failures.append("per-swap CM_INITIALIZE books do not close")
    counts = engine.compile_counts()
    if counts != counts0:
        failures.append(f"closures recompiled after warmup: {counts0} -> "
                        f"{counts}")
    if failures:
        for f in failures:
            print(f"  PLACEMENT FAILURE: {f}")
        raise SystemExit(1)
    print("  placement books close exactly: all requests served, tokens "
          "bit-equal to the all-digital oracle"
          + (f", {report.n_swaps} swaps billed + reconciled"
             if rot is not None else "")
          + ", no recompiles")


def _print_schedule(args, schedule):
    if schedule is None or not (args.cores > 1 or args.pipeline):
        return
    from repro.core.schedule import pipelined_latency, sequential_latency
    print(f"  per-core ledgers, one token vector "
          f"(queue/process/dequeue, comm bytes, load+store bytes):")
    for led in schedule.ledgers():
        print(f"    core{led.core}: {led.cm.queue}/{led.cm.process}/"
              f"{led.cm.dequeue}  comm={led.comm_bytes}B  "
              f"io={led.load_bytes + led.store_bytes}B")
    times = schedule.phase_times()
    print(f"  modeled latency/vector (Table I-A system): "
          f"sequential={sequential_latency(times) * 1e6:.1f}us  "
          f"pipelined={pipelined_latency(times) * 1e6:.1f}us  "
          f"(law in effect: "
          f"{'pipelined' if args.pipeline else 'sequential'})")


if __name__ == "__main__":
    main()
