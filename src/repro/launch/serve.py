"""Batched serving driver (the paper is an inference paper — this is the
end-to-end deployment path).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --requests 8 --prompt-len 16 --gen 12 [--exec aimc] [--int8]

Continuous-batching-lite: requests arrive with a prompt, are prefilled as a
batch, then decoded step-by-step against the sharded KV cache.

``--exec aimc`` is the paper's deployment model made literal: the whole
network is programmed ONCE via ``core.program.program_model`` (CM_INITIALIZE,
outside the serving loop), the resulting `AimcProgram` is install()ed into
the parameter tree, and every decoded token pays only queue/process/dequeue
on the stationary crossbar weights. CM_* instruction totals are reported from
the program's static accounting — CM_INITIALIZE is independent of the number
of generated tokens. ``--reprogram`` restores the legacy per-call STE path
(the network re-programs every forward) for A/B measurement of the
program-once speedup. ``--int8`` stores the digital weights in the paper's
number format (int8 + per-channel scales), the §Perf serving optimization.
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--exec", dest="exec_mode", default="digital",
                    choices=["digital", "aimc"])
    ap.add_argument("--reprogram", action="store_true",
                    help="legacy AIMC path: re-program every forward call "
                         "(per-call STE) instead of program-once/apply-many")
    ap.add_argument("--cores", type=int, default=1,
                    help="virtual AIMC cores: the MappingPlan spreads the "
                         "programmed matrices over this many per-core tile "
                         "contexts and serving reports per-core CM_*/comm "
                         "ledgers (core.schedule)")
    ap.add_argument("--pipeline", action="store_true",
                    help="price the multi-core schedule with the "
                         "position-pipelined latency law (CNN-style, "
                         "latency = slowest core) instead of the "
                         "sequential mutex chain (sum of phases)")
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if ((args.cores > 1 or args.pipeline)
            and (args.exec_mode != "aimc" or args.reprogram)):
        ap.error("--cores/--pipeline require the programmed AIMC path "
                 "(--exec aimc, without --reprogram): the multi-core "
                 "schedule lowers an installed AimcProgram")
    return args


def main(argv=None):
    args = parse_args(argv)
    import jax
    import jax.numpy as jnp

    from repro.compat import use_mesh
    from repro.configs import get_arch
    from repro.core.aimc import AimcConfig
    from repro.launch.mesh import make_mesh
    from repro.models.layers import Execution

    spec = get_arch(args.arch)
    if args.smoke:
        spec = dataclasses.replace(spec, model_cfg=spec.smoke_cfg)
    cfg = spec.model_cfg
    if spec.module not in ("transformer",):
        raise SystemExit("serve.py drives the transformer family; "
                         "recurrent archs decode via launch.steps")

    shape = tuple(int(s) for s in args.mesh.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
    mesh = make_mesh(shape, axes)
    aimc_cfg = AimcConfig(impl="ref")
    exe = (Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                     programmed=not args.reprogram)
           if args.exec_mode == "aimc"
           else Execution(compute_dtype="float32" if args.smoke
                          else "bfloat16", serve_int8=args.int8))

    model = spec.model_module()
    b, p, g = args.requests, args.prompt_len, args.gen
    max_seq = p + g

    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(args.seed), cfg)
        if args.int8:
            from repro.core.quant import quantize_params_int8
            from repro.launch.shardings import (EXPERT_IN, EXPERT_OUT,
                                                IN_PROJ, OUT_PROJ)
            params = quantize_params_int8(
                params, IN_PROJ | OUT_PROJ | EXPERT_IN | EXPERT_OUT
                | {"unembed"})

        program = None
        schedule = None
        if args.exec_mode == "aimc" and not args.reprogram:
            # CM_INITIALIZE: program the whole network once, outside the
            # serving loop (paper §IV-B — the inference region of interest
            # never re-programs). --cores spreads the matrices over that
            # many per-core tile contexts (paper Fig. 2) and the schedule
            # lowers them onto virtual cores for per-core accounting.
            from repro.core.program import MappingPlan, program_model
            from repro.core.schedule import CoreSchedule
            t0 = time.time()
            program = program_model(params,
                                    MappingPlan(n_contexts=args.cores),
                                    aimc_cfg,
                                    jax.random.PRNGKey(args.seed + 2))
            params = program.install(params)
            jax.block_until_ready(
                [st.w_q for st in program.states])
            print(f"[serve] programmed in {time.time() - t0:.2f}s: "
                  f"{program.summary()}")
            schedule = CoreSchedule.from_program(program,
                                                 pipelined=args.pipeline)
            if args.cores > 1 or args.pipeline:
                print(f"[serve] {schedule.summary()}")

        key = jax.random.PRNGKey(args.seed + 1)
        prompts = jax.random.randint(key, (b, p), 1, cfg.vocab)
        pe = (jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))
              if spec.family == "vlm" else None)

        t0 = time.time()
        prefill = jax.jit(lambda pr, tk: model.prefill(
            pr, tk, cfg, exe, max_seq=max_seq, patch_embeds=pe,
            cache_dtype=jnp.float32))
        logits, cache = prefill(params, prompts)
        next_tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(next_tok)
        t_prefill = time.time() - t0

        decode = jax.jit(lambda pr, ca, tk: model.decode_step(pr, ca, tk,
                                                              cfg, exe))
        out = [next_tok]
        t0 = time.time()
        for _ in range(g - 1):
            logits, cache = decode(params, cache, out[-1])
            out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None])
        jax.block_until_ready(out[-1])
        t_decode = time.time() - t0

        gen = jnp.concatenate(out, axis=1)
        print(f"[serve] {spec.arch_id} exec={args.exec_mode} "
              f"int8={args.int8} batch={b}"
              + (" (per-call reprogram)" if args.exec_mode == "aimc"
                 and args.reprogram else ""))
        print(f"  prefill: {b}x{p} tokens in {t_prefill:.2f}s")
        print(f"  decode:  {g - 1} steps in {t_decode:.2f}s "
              f"({b * (g - 1) / max(t_decode, 1e-9):.1f} tok/s batched, "
              f"{t_decode / max(g - 1, 1) * 1e3:.1f} ms/step)")
        if program is not None:
            init = program.initialize_counts()
            # mvm_counts is per token VECTOR (one input row through every
            # mapped matrix): prefill pushes b*p vectors, each of the g-1
            # decode steps pushes b more.
            per_vec = program.mvm_counts()
            n_vec = b * (p + g - 1)
            roi = per_vec.scaled(n_vec)
            print(f"  CM_INITIALIZE: {init.initialize} device writes, once "
                  f"per session — independent of the {g} generated tokens")
            print(f"  CM_* in the serving ROI ({n_vec} token vectors): "
                  f"queue={roi.queue} process={roi.process} "
                  f"dequeue={roi.dequeue} (per vector: {per_vec.queue}/"
                  f"{per_vec.process}/{per_vec.dequeue})")
        if schedule is not None and (args.cores > 1 or args.pipeline):
            from repro.core.schedule import (pipelined_latency,
                                             sequential_latency)
            print(f"  per-core ledgers, one token vector "
                  f"(queue/process/dequeue, comm bytes, load+store bytes):")
            for led in schedule.ledgers():
                print(f"    core{led.core}: {led.cm.queue}/{led.cm.process}/"
                      f"{led.cm.dequeue}  comm={led.comm_bytes}B  "
                      f"io={led.load_bytes + led.store_bytes}B")
            times = schedule.phase_times()
            print(f"  modeled latency/vector (Table I-A system): "
                  f"sequential={sequential_latency(times) * 1e6:.1f}us  "
                  f"pipelined={pipelined_latency(times) * 1e6:.1f}us  "
                  f"(law in effect: "
                  f"{'pipelined' if args.pipeline else 'sequential'})")
        for i in range(min(b, 3)):
            print(f"  req{i}: prompt={list(map(int, prompts[i][:6]))}... "
                  f"-> gen={list(map(int, gen[i]))}")
        return gen


if __name__ == "__main__":
    main()
