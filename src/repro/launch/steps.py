"""Step-function builders: train_step / prefill_step / serve_step per
(architecture x shape cell), plus ``input_specs`` — the ShapeDtypeStruct
stand-ins the multi-pod dry-run lowers against (no device allocation).

Memory discipline baked in here (DESIGN.md §5):
  * gradient accumulation: the global batch splits into microbatches scanned
    inside the jit (activation memory ~ one microbatch);
  * chunked cross-entropy: logits are materialized 512 sequence positions at
    a time (a [B, 4096, 152k] logits tensor would be ~20 GB/chip);
  * remat: every model scans remat-wrapped blocks;
  * serving params cast to bf16 (or int8 codes — `Execution.serve_int8`,
    the paper's number format, a §Perf variant).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ArchSpec, ShapeCell
from repro.launch.mesh import axis_size, dp_axes
from repro.launch.shardings import (batch_specs, cache_specs, fit_spec,
                                    fit_specs, get_opt_specs,
                                    get_param_specs, shard_aimc_states,
                                    strip_fsdp)
from repro.models.layers import Execution
from repro.optim import make_optimizer

CE_CHUNK = 512


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def chunked_ce(h, unembed, labels, chunk: int = CE_CHUNK):
    """Cross entropy over [B, S] without materializing [B, S, V].

    labels < 0 are masked (VLM patch positions). Returns (sum_loss, n_tok).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    nc = s // chunk
    s_used = nc * chunk
    hc = h[:, :s_used].reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels[:, :s_used].reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        from repro.models.layers import shard_act
        hx, lx = xs
        logits = hx.astype(jnp.float32) @ unembed.astype(jnp.float32)
        # vocab-sharded logits: each model shard computes its vocab slice;
        # only the [B, chunk] logsumexp partials cross the mesh
        logits = shard_act(logits, model_dim=2)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        mask = (lx >= 0).astype(jnp.float32)
        loss = jnp.sum((logz - gold) * mask)
        return (carry[0] + loss, carry[1] + jnp.sum(mask)), None

    (loss, n), _ = jax.lax.scan(body, (0.0, 0.0), (hc, lc))
    return loss, n


# ---------------------------------------------------------------------------
# batch construction helpers (abstract + concrete share one shape source)
# ---------------------------------------------------------------------------

def batch_shapes(spec: ArchSpec, cell: ShapeCell) -> dict:
    """Logical [global] shapes+dtypes of one training/prefill batch."""
    b, s = cell.global_batch, cell.seq_len
    cfg = spec.model_cfg
    if spec.family == "audio":
        tgt = max(s // spec.tgt_ratio, 64)
        return {"frames": ((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": ((b, tgt), jnp.int32),
                "labels": ((b, tgt), jnp.int32)}
    out = {"tokens": ((b, s), jnp.int32), "labels": ((b, s), jnp.int32)}
    if spec.family == "vlm":
        out["patch_embeds"] = ((b, spec.n_patches, cfg.d_model), jnp.bfloat16)
    return out


def batch_kind(spec: ArchSpec) -> str:
    return {"audio": "encdec", "vlm": "vlm"}.get(spec.family, "lm")


def abstract_batch(spec: ArchSpec, cell: ShapeCell) -> dict:
    return {k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, dt) in batch_shapes(spec, cell).items()}


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run / launcher needs for one (arch, cell, mesh)."""
    fn: Callable                   # the step function to jit
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple         # ShapeDtypeStructs, positional
    donate_argnums: tuple = ()
    schedule: Any = None           # core.schedule.CoreSchedule, when serving
                                   # through a multi-core lowering


def _unwrap_program(program):
    """Serving steps accept either an `AimcProgram` or a multi-core
    `core.schedule.CoreSchedule`; installation always goes through the
    underlying program, and the schedule (when given) additionally
    column-shards the crossbar states and rides on the bundle for per-core
    ledger reporting (dry-run / serve stats)."""
    from repro.core.schedule import CoreSchedule
    if isinstance(program, CoreSchedule):
        return program.program, program
    return program, None


def _model_forward_hidden(model, spec, cfg, exe):
    """Uniform (params, batch, rng) -> (hidden, aux) across families."""
    fam = spec.family

    def fwd(params, batch, rng):
        if fam == "audio":
            return model.forward(params, batch, cfg, exe, rng,
                                 return_hidden=True)
        if fam == "vlm":
            return model.forward(params, batch["tokens"], cfg, exe, rng,
                                 patch_embeds=batch["patch_embeds"],
                                 return_hidden=True)
        return model.forward(params, batch["tokens"], cfg, exe, rng,
                             return_hidden=True)

    return fwd


def make_train_step(spec: ArchSpec, cell: ShapeCell, mesh,
                    exe: Execution = Execution(), lr_scale: float = 1.0):
    cfg = spec.model_cfg
    model = spec.model_module()
    opt_init, opt_update, _ = make_optimizer(spec.optimizer)
    dp = dp_axes(mesh)
    dp_total = axis_size(mesh, dp)
    micro_global = dp_total * spec.microbatch
    n_micro = max(1, cell.global_batch // micro_global)
    fwd = _model_forward_hidden(model, spec, cfg, exe)
    pdtype = jnp.dtype(spec.param_dtype)

    params_shape = jax.eval_shape(
        lambda k: jax.tree.map(lambda x: x.astype(pdtype),
                               model.init(k, cfg)), jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt_init, params_shape)
    pspecs = fit_specs(get_param_specs(params_shape, mesh), params_shape, mesh)
    ospecs = fit_specs(get_opt_specs(opt_shape, params_shape, mesh),
                       opt_shape, mesh)
    bspecs = fit_specs(batch_specs(mesh, batch_kind(spec)),
                       abstract_batch(spec, cell), mesh)

    def split_micro(x):
        mb = x.shape[0] // n_micro
        return jax.lax.with_sharding_constraint(
            x.reshape(n_micro, mb, *x.shape[1:]),
            P(None, dp, *([None] * (x.ndim - 1))))

    def train_step(params, opt_state, batch, rng):
        micro = jax.tree.map(split_micro, batch)

        def micro_loss(p, mb, key):
            h, aux = fwd(p, mb, key)
            unemb = model.unembed_matrix(p, cfg)
            loss_sum, n_tok = chunked_ce(h, unemb, mb["labels"])
            loss = loss_sum / jnp.maximum(n_tok, 1.0)
            return loss + 0.01 * aux, loss

        grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

        def constrain_like_params(tree):
            # keep the accumulated grads sharded exactly like the FSDP params;
            # without this XLA replicates the scan carry (27 GB/device for
            # olmoe) and all-reduces instead of reduce-scattering.
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                tree, pspecs, is_leaf=lambda x: x is None)

        def acc_body(carry, xs):
            g_acc, loss_acc, i = carry
            mb = xs
            key = jax.random.fold_in(rng, i)
            (_, loss), g = grad_fn(params, mb, key)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g)
            g_acc = constrain_like_params(g_acc)
            return (g_acc, loss_acc + loss / n_micro, i + 1), None

        g0 = constrain_like_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (grads, loss, _), _ = jax.lax.scan(
            acc_body, (g0, 0.0, 0), micro, length=n_micro)
        new_params, new_opt, metrics = opt_update(grads, opt_state, params,
                                                  lr_scale)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    abstract = (params_shape, opt_shape, abstract_batch(spec, cell),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
    in_sh = (pspecs, ospecs, bspecs, P())
    out_sh = (pspecs, ospecs, None)
    return StepBundle(train_step, in_sh, out_sh, abstract,
                      donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def _serve_params_shape(model, spec, cfg, int8: bool = False):
    """Serving parameter shapes: bf16, or int8 codes + per-channel scales
    (the paper's number format; Execution.serve_int8)."""
    from repro.launch.shardings import (EXPERT_IN, EXPERT_OUT, IN_PROJ,
                                        OUT_PROJ)
    quantizable = IN_PROJ | OUT_PROJ | EXPERT_IN | EXPERT_OUT | {"unembed"}
    shape = jax.eval_shape(lambda k: model.init(k, cfg),
                           jax.random.PRNGKey(0))

    def conv(path, leaf):
        name = ""
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if int8 and name in quantizable and leaf.ndim >= 2:
            return {"q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct(
                        leaf.shape[:-2] + (1, leaf.shape[-1]), jnp.float32)}
        return jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16)

    return jax.tree_util.tree_map_with_path(conv, shape)


def make_prefill_step(spec: ArchSpec, cell: ShapeCell, mesh,
                      exe: Execution = Execution(), program=None):
    cfg = spec.model_cfg
    model = spec.model_module()
    cache_dt = jnp.dtype(spec.cache_dtype)
    program, schedule = _unwrap_program(program)
    params_shape = _serve_params_shape(model, spec, cfg, int8=exe.serve_int8)
    if program is not None:     # program-once serving: mapped projections
        params_shape = program.install_shape(params_shape)  # are AIMC states
    pspecs = fit_specs(get_param_specs(params_shape, mesh), params_shape, mesh)
    if schedule is not None and schedule.n_cores > 1:
        # multi-core lowering: each device owns its cores' bit lines
        pspecs = shard_aimc_states(pspecs, params_shape, mesh)
    if exe.serve_int8:      # int8 weights replicate over data: no gathers
        pspecs = strip_fsdp(pspecs, mesh)
    bspecs = fit_specs(batch_specs(mesh, batch_kind(spec)),
                       abstract_batch(spec, cell), mesh)
    b, s = cell.global_batch, cell.seq_len

    # the model-facing prefill math lives in runtime.engine — one
    # implementation under both the static shape cells and the
    # continuous-batching ServeEngine
    from repro.runtime.engine import static_prefill_closure
    prefill = static_prefill_closure(model, cfg, exe, family=spec.family,
                                     module=spec.module, max_seq=s,
                                     cache_dtype=cache_dt)

    abstract_b = abstract_batch(spec, cell)
    cache_shape = jax.eval_shape(prefill, params_shape, abstract_b)[1]
    cspecs = (fit_specs(cache_specs(cache_shape, mesh), cache_shape, mesh)
              if cache_shape != () else ())
    dp = dp_axes(mesh)
    out_tok = fit_spec(P(dp, None), (b, 1), mesh)
    return StepBundle(prefill, (pspecs, bspecs), (out_tok, cspecs),
                      (params_shape, abstract_b), schedule=schedule)


def make_serve_step(spec: ArchSpec, cell: ShapeCell, mesh,
                    exe: Execution = Execution(), program=None):
    """One decode step against a seq_len KV cache (the decode_* cells)."""
    cfg = spec.model_cfg
    model = spec.model_module()
    cache_dt = jnp.dtype(spec.cache_dtype)
    program, schedule = _unwrap_program(program)
    params_shape = _serve_params_shape(model, spec, cfg, int8=exe.serve_int8)
    if program is not None:     # program-once serving (core.program)
        params_shape = program.install_shape(params_shape)
    pspecs = fit_specs(get_param_specs(params_shape, mesh), params_shape, mesh)
    if schedule is not None and schedule.n_cores > 1:
        # multi-core lowering: each device owns its cores' bit lines
        pspecs = shard_aimc_states(pspecs, params_shape, mesh)
    if exe.serve_int8:      # int8 weights replicate over data: no gathers
        pspecs = strip_fsdp(pspecs, mesh)
    b, s = cell.global_batch, cell.seq_len

    if spec.family == "audio":
        src = max(s // spec.tgt_ratio, 64)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, b, s, src, cache_dt))
    elif spec.module in ("rglru", "xlstm"):
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, b, s, cache_dt))
    else:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(cfg, b, s, cache_dt))
    cspecs = fit_specs(cache_specs(cache_shape, mesh), cache_shape, mesh)
    dp = dp_axes(mesh)

    # the lockstep decode math shared with the engine (runtime.engine)
    from repro.runtime.engine import static_decode_closure
    serve_step = static_decode_closure(model, cfg, exe)

    tok_spec = fit_spec(P(dp, None), (b, 1), mesh)
    abstract = (params_shape, cache_shape,
                jax.ShapeDtypeStruct((b, 1), jnp.int32))
    in_sh = (pspecs, cspecs, tok_spec)
    out_sh = (tok_spec, cspecs)
    return StepBundle(serve_step, in_sh, out_sh, abstract,
                      donate_argnums=(1,), schedule=schedule)


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------

def make_step(spec: ArchSpec, cell: ShapeCell, mesh,
              exe: Execution = Execution(), program=None) -> StepBundle:
    """`program` (an `core.program.AimcProgram`, or a multi-core
    `core.schedule.CoreSchedule` wrapping one) selects program-once AIMC
    serving: the step's parameter tree carries the installed crossbar states
    (training cells reject it — the STE path re-programs by design). A
    schedule additionally column-shards the states over `model` and rides
    on the bundle for per-core ledger reporting."""
    if cell.kind == "train":
        if program is not None:
            raise ValueError("AimcProgram is a serving-only handle; "
                             "noise-aware training re-programs per step")
        return make_train_step(spec, cell, mesh, exe)
    if cell.kind == "prefill":
        return make_prefill_step(spec, cell, mesh, exe, program)
    return make_serve_step(spec, cell, mesh, exe, program)


def input_specs(spec: ArchSpec, cell: ShapeCell, mesh,
                exe: Execution = Execution(), program=None) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step function
    (weak-type-correct, shardable, zero device allocation)."""
    return make_step(spec, cell, mesh, exe, program).abstract_inputs
