"""Analytic MODEL_FLOPS per (arch x shape) cell.

MODEL_FLOPS = 6 * N * D for training (2 fwd + 4 bwd), 2 * N * D for
inference, with N the *matmul-visible* parameter count (embedding table
excluded — lookups are gathers, not FLOPs; the unembed projection included)
and D the number of processed tokens. For MoE archs N is the ACTIVE count:
dense part + expert part * top_k / n_experts (+ the arctic dense-residual
branch, which every token also runs).

The ratio MODEL_FLOPS / HLO_FLOPS in EXPERIMENTS.md §Roofline measures how
much of the compiled compute is "useful" — remat recompute, attention
score/AV work (not in 6ND by convention) and capacity-padded MoE dispatch all
push it below 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchSpec, ShapeCell

_EXPERT_NAMES = {"we_gate", "we_up", "we_down"}


def param_counts(spec: ArchSpec) -> dict:
    """-> {'total', 'dense', 'expert', 'embed', 'active'} parameter counts."""
    model = spec.model_module()
    cfg = spec.model_cfg
    shape = jax.eval_shape(lambda k: model.init(k, cfg), jax.random.PRNGKey(0))
    counts = {"total": 0, "dense": 0, "expert": 0, "embed": 0}
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape)[0]:
        name = ""
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        n = 1
        for d in leaf.shape:
            n *= d
        counts["total"] += n
        if name == "embed":
            counts["embed"] += n
        elif name in _EXPERT_NAMES:
            counts["expert"] += n
        else:
            counts["dense"] += n
    if spec.family == "moe" and counts["expert"]:
        frac = spec.model_cfg.top_k / spec.model_cfg.n_experts
        counts["active"] = counts["dense"] + counts["expert"] * frac
    else:
        counts["active"] = counts["dense"] + counts["expert"]
    return counts


def model_flops(spec: ArchSpec, cell: ShapeCell) -> float:
    """Global (all-device) useful FLOPs of one step of this cell."""
    counts = param_counts(spec)
    n_active = counts["active"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        if spec.family == "audio":  # decoder runs tgt_len, encoder seq_len
            tokens = cell.global_batch * cell.seq_len  # enc+dec approximated
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
