"""PartitionSpec assignment for every pytree in the framework.

Name-driven rules (DESIGN.md §5): "in-projections" shard (reduction dim ->
FSDP, output dim -> model); "out-projections" the reverse; expert stacks
shard experts over model; embeddings shard vocab over model; norms/biases of
O(d) replicate. The same function serves any mesh — specs reference axis
NAMES, and multi-pod meshes simply bind `fsdp` to ("pod", "data").

Uneven dims (e.g. vocab 151655 over 16 shards, 2-head KV over 16) are left
sharded: GSPMD pads internally, which costs <1% and keeps the rules uniform.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, fsdp_axes

# projection weight names: [..., K(reduce), N(out)]
IN_PROJ = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "cq", "ck", "cv",
           "router", "w_rnn_in", "w_a", "w_x", "w_q", "w_k", "w_v", "w_if",
           "w_zifo", "w_ff_gate", "w_ff_up", "wd_gate", "wd_up", "w_gate"}
OUT_PROJ = {"wo", "w_down", "w_out", "co", "w_ff_down", "wd_down"}
EXPERT_IN = {"we_gate", "we_up"}
EXPERT_OUT = {"we_down"}
MODEL_OUT_BIAS = {"bq", "bk", "bv", "b_in"}   # bias on a model-sharded output


def _name_of(path) -> str:
    for k in reversed(path):
        if hasattr(k, "key"):
            name = str(k.key)
            if name in ("q", "s"):      # int8-quantized leaf {q, s} wrapper
                continue
            return name
    return ""


def param_spec(path, leaf, fsdp) -> P:
    name = _name_of(path)
    nd = leaf.ndim
    if any(hasattr(k, "name") for k in path):
        # attribute key => inside a programmed AimcLinearState
        # (core.program): crossbar codes/scales replicate — int8 states are
        # small and weights-stationary
        return P(*([None] * nd))
    if name == "embed":
        return P("model", fsdp)
    if name == "unembed":
        return P(fsdp, "model")
    if name in EXPERT_IN:                      # [L, E, K, N]
        return P(None, "model", fsdp, None)
    if name in EXPERT_OUT:                     # [L, E, N, K]
        return P(None, "model", None, fsdp)
    if name in IN_PROJ and nd >= 2:            # [L, K, N] (or [K, N])
        return P(*([None] * (nd - 2)), fsdp, "model")
    if name in OUT_PROJ and nd >= 2:
        return P(*([None] * (nd - 2)), "model", fsdp)
    if name in MODEL_OUT_BIAS:
        return P(*([None] * (nd - 1)), "model")
    if name == "r_zifo":                       # [L, H, dh, 4dh] small
        return P(None, None, None, None)
    if name == "conv_w":                       # [L, W, D]
        return P(None, None, fsdp)
    # norms, biases, gains: replicate (O(d) each)
    return P(*([None] * nd))


def get_param_specs(params_shape, mesh):
    fsdp = fsdp_axes(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l, fsdp), params_shape)


def get_opt_specs(opt_shape, params_shape, mesh):
    """Optimizer state mirrors param specs; Adafactor's factored moments drop
    the corresponding parameter axis (vr: last, vc: second-to-last)."""
    pspecs = get_param_specs(params_shape, mesh)
    flat_p = {"/".join(_path_str(p)): s for p, s in
              jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    def spec_for(path, leaf):
        keys = _path_str(path)
        root = keys[0] if keys else ""
        pkey = "/".join(keys[1:])
        if root in ("mu", "nu") and pkey in flat_p:
            return flat_p[pkey]
        if root in ("vr", "vc") and pkey in flat_p:
            base = flat_p[pkey]
            parts = list(base) + [None] * (len(base) == 0)
            if root == "vr":
                new = tuple(base[:-1]) if len(base) else ()
            else:
                new = tuple(base[:-2]) + tuple(base[-1:]) if len(base) >= 2 else ()
            # factored moments may have fewer dims than the spec suggests
            new = tuple(new[: leaf.ndim])
            new = new + (None,) * (leaf.ndim - len(new))
            return P(*new)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, opt_shape)


def _path_str(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
    return out


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_specs(mesh, kind: str = "lm") -> dict:
    dp = dp_axes(mesh)
    if kind == "lm":
        return {"tokens": P(dp, None), "labels": P(dp, None)}
    if kind == "vlm":
        return {"tokens": P(dp, None), "labels": P(dp, None),
                "patch_embeds": P(dp, None, None)}
    if kind == "encdec":
        return {"frames": P(dp, None, None), "tokens": P(dp, None),
                "labels": P(dp, None)}
    raise ValueError(kind)


def cache_specs(cache_shape, mesh) -> dict:
    """Serving caches: batch -> dp axes, long axis (seq / state dim) -> model.

    Transformer/encdec KV: [L, B, S, H, hd]  -> (None, dp, 'model', None, None)
    rglru window KV:       [U, B, W, H, hd]  -> same
    rglru r-state:         [U, B, Dr]        -> (None, dp, 'model')
    rglru conv state:      [U, B, W-1, Dr]   -> (None, dp, None, 'model')
    xlstm matrix memory:   [N, B, H, dh, dh] -> (None, dp, None, 'model', None)
    xlstm scalar states:   [N, B, D]         -> (None, dp, 'model')
    lengths:               [B]               -> (dp,)
    """
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name = _name_of(path)
        nd = leaf.ndim
        if name == "len":
            return P(dp)
        if nd == 5 and name in ("k", "v", "ck", "cv"):
            return P(None, dp, "model", None, None)
        if name == "m_C":
            return P(None, dp, None, "model", None)
        if name in ("m_n",):
            return P(None, dp, None, "model")
        if name in ("r_a", "r_b", "tail_r", "s_c", "s_n", "s_h", "s_m"):
            return P(None, dp, "model")
        if name in ("conv_a", "conv_b", "tail_conv"):
            return P(None, dp, None, "model")
        if name == "m_m":
            return P(None, dp, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def to_named(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def aimc_state_spec(leaf_ndim: int, axis: str = "model") -> P:
    """Column-shard a programmed `AimcLinearState` leaf over `axis`.

    w_q is [..., KB, M, Np] and s_w [..., KB, Np]; the last dim is the bit
    lines (output columns) in both — the dimension `core.schedule` splits
    across virtual cores. Sharding it over the model axis places each
    model-parallel device's slice of every crossbar with the device that
    consumes its outputs (multi-core schedule serving)."""
    return P(*([None] * (leaf_ndim - 1) + [axis]))


def shard_aimc_states(pspecs, params_shape, mesh, axis: str = "model"):
    """Rewrite the replicated `AimcLinearState` specs of `get_param_specs`
    into column-sharded ones. Used by `launch.steps` when serving through a
    multi-core `core.schedule.CoreSchedule`; non-state leaves keep their
    specs, and `fit_spec` drops the axis wherever Np does not divide."""
    def one(path, spec, leaf):
        if any(hasattr(k, "name") for k in path):   # inside an AimcLinearState
            return fit_spec(aimc_state_spec(leaf.ndim, axis), leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(
        one, pspecs, params_shape, is_leaf=lambda x: isinstance(x, P))


def serve_engine_param_specs(params_shape, mesh, axis: str = "model"):
    """Parameter placement for the sharded serving engine (DESIGN.md §11).

    Weights-stationary serving: every digital leaf REPLICATES (no per-token
    gathers, and replication keeps per-row math bit-identical to the
    single-device engine), while programmed `AimcLinearState` leaves
    column-shard their bit lines over ``axis`` — each model-parallel device
    owns a slice of every crossbar's output columns, the multi-core layout
    `core.schedule.select_columns` proves exact. `fit_spec` drops the axis
    wherever Np does not divide; a mesh without ``axis`` (data-only
    serving) replicates the states too."""
    repl = jax.tree.map(lambda l: P(*([None] * l.ndim)), params_shape)
    if axis not in mesh.axis_names:
        return repl
    return shard_aimc_states(repl, params_shape, mesh, axis)


def slot_cache_specs(cache_shape, batch_axes, mesh):
    """Decode-slot cache placement for the sharded engine.

    The engine's slot axis (the probed per-leaf batch axis) shards over the
    data axes — each data-parallel device advances its own decode lanes —
    and every other dimension replicates. No reduction dimension is ever
    sharded, so the per-lane math stays bit-identical to the single-device
    engine (the DESIGN.md §11 equality bar). Leaves whose slot count does
    not divide the data axes fall back to replicated via `fit_spec`."""
    dp = dp_axes(mesh)

    def one(leaf, ax):
        spec = [None] * leaf.ndim
        spec[ax] = dp
        return fit_spec(P(*spec), leaf.shape, mesh)

    return jax.tree.map(one, cache_shape, batch_axes)


def slot_state_specs(state_shape, mesh):
    """Decode-slot retirement-state placement for the sharded engine.

    The chunked decode loop keeps per-lane retirement rows ON DEVICE
    ({active, gen, pos, max_new}, each [n_slots] — `ServeEngine._empty_
    state`). They follow the lane split: [n_slots] leaves shard over the
    data axes exactly like the slot cache, anything else replicates, and
    `fit_spec` drops non-dividing axes — the same fallback rule as
    `slot_cache_specs`."""
    dp = dp_axes(mesh)
    return jax.tree.map(
        lambda leaf: (fit_spec(P(dp), leaf.shape, mesh) if leaf.ndim == 1
                      else P(*([None] * leaf.ndim))),
        state_shape)


def strip_fsdp(specs, mesh):
    """Serving weight placement: keep `model` sharding, drop the FSDP axes
    (weights replicate across data rows — no per-token all-gathers). Used by
    the int8 serving path, whose weights are small enough to hold resident
    (the paper's weights-stationary deployment model)."""
    fsdp = set(fsdp_axes(mesh))

    def one(spec):
        if not isinstance(spec, P):
            return spec
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in axes if a not in fsdp)
            out.append(kept if len(kept) > 1 else
                       (kept[0] if kept else None))
        return P(*out)

    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# divisibility fitting
# ---------------------------------------------------------------------------

def fit_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes a dimension cannot be evenly sharded over.

    jit in/out shardings require divisibility; cells like long_500k
    (global_batch=1) or vocab 151655 over model=16 otherwise fail. For a
    tuple assignment ('pod','data') the largest dividing prefix is kept.
    """
    if not isinstance(spec, P):
        return spec
    entries = list(spec)
    out = []
    for d, entry in enumerate(entries):
        if entry is None or d >= len(shape):
            out.append(None if d >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept, size = [], 1
        for a in axes:
            if shape[d] % (size * mesh.shape[a]) == 0:
                kept.append(a)
                size *= mesh.shape[a]
            else:
                break
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def fit_specs(specs, shapes, mesh):
    """Tree-wise `fit_spec`; `specs` and `shapes` must be matching trees."""
    return jax.tree.map(
        lambda s, x: fit_spec(s, x.shape, mesh), specs, shapes,
        is_leaf=lambda v: isinstance(v, P))
