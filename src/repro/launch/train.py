"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 1000 --mesh 16x16 --ckpt-dir /ckpts/run0 [--exec aimc]

On this CPU container use ``--smoke --mesh 1x1`` (reduced config); on a pod
the same command line runs the full config. The loop wires together every
substrate layer: deterministic sharded data, FSDP+TP step function (with
gradient accumulation + remat), atomic async checkpointing with auto-resume,
straggler detection, heartbeat, and the AIMC execution mode (noise-aware
training) when ``--exec aimc``.

XLA flags for real TPU runs (latency-hiding collectives) are appended to
XLA_FLAGS unless --no-xla-tuning.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

TPU_XLA_FLAGS = (
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
    " --xla_enable_async_all_gather=true"
)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM or PxDxM, e.g. 16x16 or 2x16x16")
    ap.add_argument("--global-batch", type=int, default=0,
                    help="0 = the train_4k cell's batch (or 4 with --smoke)")
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--exec", dest="exec_mode", default="digital",
                    choices=["digital", "aimc"])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-xla-tuning", action="store_true")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if not args.no_xla_tuning and not args.smoke:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + TPU_XLA_FLAGS)

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import checkpoint
    from repro.compat import use_mesh
    from repro.configs import ShapeCell, get_arch
    from repro.core.aimc import AimcConfig
    from repro.data.pipeline import DataConfig, host_batch, make_global_array
    from repro.launch.mesh import dp_axes, make_mesh
    from repro.launch.shardings import to_named
    from repro.launch.steps import make_step
    from repro.models.layers import Execution
    from repro.optim import make_optimizer
    from repro.optim.schedule import warmup_cosine
    from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                               resilient_step)

    spec = get_arch(args.arch)
    if args.smoke:
        spec = dataclasses.replace(spec, model_cfg=spec.smoke_cfg)
    cfg = spec.model_cfg

    shape = tuple(int(s) for s in args.mesh.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
    mesh = make_mesh(shape, axes)

    gb = args.global_batch or (4 if args.smoke else 256)
    sl = args.seq_len or (32 if args.smoke else 4096)
    cell = ShapeCell("train_cli", seq_len=sl, global_batch=gb, kind="train")
    exe = (Execution(mode="aimc", aimc=AimcConfig(impl="ref"),
                     compute_dtype="float32" if args.smoke else "bfloat16")
           if args.exec_mode == "aimc"
           else Execution(compute_dtype="float32" if args.smoke
                          else "bfloat16"))

    with use_mesh(mesh):
        bundle = make_step(spec, cell, mesh, exe)
        step_fn = jax.jit(bundle.fn,
                          in_shardings=to_named(bundle.in_shardings, mesh),
                          out_shardings=to_named(bundle.out_shardings, mesh),
                          donate_argnums=bundle.donate_argnums)

        model = spec.model_module()
        pdtype = jnp.dtype(spec.param_dtype)
        params = jax.tree.map(
            lambda x: x.astype(pdtype),
            model.init(jax.random.PRNGKey(args.seed), cfg))
        opt_state = make_optimizer(spec.optimizer)[0](params)

        start = 0
        if args.ckpt_dir:
            state_tpl = {"params": params, "opt": opt_state}
            got, tree, extra = checkpoint.restore_latest(args.ckpt_dir,
                                                         state_tpl)
            if got is not None:
                params, opt_state = tree["params"], tree["opt"]
                start = got
                print(f"[train] resumed from step {got}")

        dcfg = DataConfig(vocab=cfg.vocab, seq_len=sl, global_batch=gb,
                          seed=args.seed)
        dp = dp_axes(mesh)
        from jax.sharding import PartitionSpec as P
        bspec = P(dp, None)
        monitor = StragglerMonitor()
        hb = Heartbeat(os.path.join(args.ckpt_dir or ".", "heartbeat.json"))
        safe_step = resilient_step(step_fn)

        print(f"[train] {spec.arch_id} {args.mesh} gb={gb} seq={sl} "
              f"exec={args.exec_mode} steps {start}..{args.steps}")
        t_last = time.time()
        for step in range(start, args.steps):
            hbatch = host_batch(dcfg, step, 0, 1)
            batch = {k: jnp.asarray(v) for k, v in hbatch.items()}
            if spec.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (gb, cfg.n_patches, cfg.d_model), jnp.bfloat16)
                batch["labels"] = batch["labels"].at[:, :cfg.n_patches].set(-1)
            if spec.family == "audio":
                batch = {"frames": jnp.zeros((gb, sl, cfg.d_model),
                                             jnp.bfloat16),
                         "tokens": batch["tokens"][:, :max(sl // 8, 64)],
                         "labels": batch["labels"][:, :max(sl // 8, 64)]}
            if mesh.size > 1:
                batch = make_global_array(batch, mesh, bspec)
            rng = jnp.asarray([args.seed, step], jnp.uint32)
            lr = float(warmup_cosine(jnp.asarray(step), total=args.steps))
            params, opt_state, metrics = safe_step(params, opt_state, batch,
                                                   rng)
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                dt = (time.time() - t_last) / args.log_every
                t_last = time.time()
                monitor.record(step, dt)
                hb.beat(step, loss=loss)
                tok_s = gb * sl / max(dt, 1e-9)
                print(f"  step {step + 1:6d} loss {loss:8.4f} "
                      f"{dt * 1e3:8.1f} ms/step {tok_s:,.0f} tok/s lr×{lr:.3f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save_async(args.ckpt_dir, step + 1,
                                      {"params": params, "opt": opt_state},
                                      extra={"loss": float(metrics['loss'])})
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps,
                            {"params": params, "opt": opt_state})
        print("[train] done")
        return params


if __name__ == "__main__":
    main()
