import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

For each cell this script:
  1. builds the step function (train_step / prefill / serve_step),
  2. ``jax.jit(fn, in_shardings, out_shardings).lower(*input_specs(...))``
     with ShapeDtypeStruct stand-ins — no device allocation,
  3. ``.compile()`` against the 16x16 single-pod mesh and the 2x16x16
     multi-pod mesh (the latter proves the ``pod`` axis shards),
  4. records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     (FLOPs / bytes) and the collective-traffic histogram parsed from the
     optimized HLO, into ``experiments/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun --arch granite_8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --arch qwen15_110b --shape decode_32k \
      --exec aimc --variant aimc
"""

import argparse
import json
import time
import traceback

from repro.launch.hlostats import analyze_hlo

# TPU v5e hardware constants for the roofline terms (EXPERIMENTS.md §Roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 concurrent link)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             exec_mode: str = "digital", variant: str = "baseline",
             out_dir: str = "experiments/dryrun", save: bool = True) -> dict:
    import jax
    from repro.compat import use_mesh
    from repro.configs import SHAPES, get_arch
    from repro.core.aimc import AimcConfig
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shardings import to_named
    from repro.launch.steps import make_step
    from repro.models.layers import Execution

    spec = get_arch(arch_id)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    if exec_mode == "aimc":
        exe = Execution(mode="aimc", aimc=AimcConfig(impl="ref"))
    elif exec_mode == "int8":
        exe = Execution(serve_int8=True)
    else:
        exe = Execution()

    rec = {"arch": spec.arch_id, "shape": shape_name, "kind": cell.kind,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "devices": n_dev, "exec": exec_mode, "variant": variant}
    t0 = time.time()
    try:
        with use_mesh(mesh):
            bundle = make_step(spec, cell, mesh, exe)
            jitted = jax.jit(
                bundle.fn,
                in_shardings=to_named(bundle.in_shardings, mesh),
                out_shardings=to_named(bundle.out_shardings, mesh),
                donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*bundle.abstract_inputs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

            mem = compiled.memory_analysis()
            from repro.compat import cost_analysis
            cost = cost_analysis(compiled)
            # while-aware per-device stats: XLA's cost_analysis counts scan
            # bodies ONCE; hlostats multiplies by known_trip_count.
            stats = analyze_hlo(compiled.as_text())

        from repro.launch.modelstats import model_flops
        flops = float(stats["flops"])
        bytes_acc = float(stats["bytes"])
        coll = stats["collectives"]
        mflops_dev = model_flops(spec, cell) / n_dev
        rec |= {
            "ok": True,
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0)),
            },
            # while-aware per-device totals (launch/hlostats.py)
            "hlo_flops": flops,
            "hlo_bytes": bytes_acc,
            # raw XLA numbers for cross-reference (scan bodies counted once)
            "xla_flops_raw": float(cost.get("flops", 0.0)),
            "xla_bytes_raw": float(cost.get("bytes accessed", 0.0)),
            "model_flops_per_dev": mflops_dev,
            "useful_ratio": mflops_dev / flops if flops else 0.0,
            "collectives": coll,
            "roofline": {
                "compute_s": flops / PEAK_FLOPS,
                "memory_s": bytes_acc / HBM_BW,
                "collective_s": coll.get("total", 0.0) / ICI_BW,
            },
        }
        r = rec["roofline"]
        r["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
        r["step_s_bound"] = max(r["compute_s"], r["memory_s"],
                                r["collective_s"])
        r["roofline_fraction"] = (
            (mflops_dev / PEAK_FLOPS) / r["step_s_bound"]
            if r["step_s_bound"] else 0.0)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec |= {"ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}

    if save:
        os.makedirs(out_dir, exist_ok=True)
        fname = (f"{spec.arch_id}.{shape_name}."
                 f"{'multi' if multi_pod else 'single'}.{exec_mode}.{variant}"
                 ".json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--exec", dest="exec_mode",
                    choices=["digital", "aimc", "int8"], default="digital")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import all_cells, cells

    if args.all:
        todo = all_cells()
    elif args.arch and args.shape:
        todo = [(args.arch, args.shape)]
    elif args.arch:
        todo = cells(args.arch)
    else:
        ap.error("--arch/--shape or --all required")

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch_id, shape_name in todo:
        for multi in meshes:
            rec = run_cell(arch_id, shape_name, multi, args.exec_mode,
                           args.variant, args.out)
            tag = f"{arch_id}/{shape_name}/{'multi' if multi else 'single'}"
            if rec["ok"]:
                r = rec["roofline"]
                print(f"OK  {tag}: compile={rec['compile_s']}s "
                      f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                      f"flops={rec['hlo_flops']:.3g} "
                      f"coll={rec['collectives'].get('total',0)/2**30:.2f}GiB "
                      f"dominant={r['dominant']}")
            else:
                failures += 1
                print(f"FAIL {tag}: {rec['error']}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
