"""Decoder-only transformer family (granite, llama3.2, qwen1.5, glm4,
internvl2 backbone, arctic, olmoe).

One scanned, remat-wrapped block definition covers the dense and MoE members;
config flags select QKV bias (qwen), GQA group sizes, SwiGLU dims, MoE
(+ dense residual for arctic) and the VLM patch-embedding frontend stub
(internvl2: `input_specs` feeds precomputed patch embeddings; see the
assignment's frontend-STUB rule).

All stationary projections route through `layers.linear` and therefore run
digitally or through the simulated AIMC crossbars (the paper's technique as a
first-class execution mode). Serving uses program-once/apply-many: after
`core.program.program_model(...).install(params)`, the mapped projections
arrive here as stacked `AimcLinearState`s that `lax.scan` slices per layer —
no re-programming per token, no model-code changes. Parameters are stacked on
a leading layer axis and consumed by `lax.scan` — small HLO, fast multi-pod
compiles.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcLinearState, stack_states
from repro.models import moe as moe_lib
from repro.models.layers import (Execution, as_weight, decode_attention,
                                 dense_init, embed_init, flash_attention,
                                 linear, linear_stack, rmsnorm, rope,
                                 shard_act, swiglu)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_dense_ff: int = 0
    # VLM frontend stub
    n_patches: int = 0
    # attention chunking
    q_chunk: int = 1024
    kv_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key, cfg: TransformerConfig, dtype=jnp.float32) -> dict:
    l, d, hq, hkv, hd, ff = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                             cfg.n_kv_heads, cfg.hd, cfg.d_ff)
    ks = jax.random.split(key, 16)

    def stack(rng, k, n):
        return jax.vmap(lambda r: dense_init(r, k, n, dtype))(
            jax.random.split(rng, l))

    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab, d, dtype),
        "final_norm": jnp.ones((d,), dtype),
        "blocks": {
            "ln1": jnp.ones((l, d), dtype),
            "ln2": jnp.ones((l, d), dtype),
            "wq": stack(ks[1], d, hq * hd),
            "wk": stack(ks[2], d, hkv * hd),
            "wv": stack(ks[3], d, hkv * hd),
            "wo": stack(ks[4], hq * hd, d),
        },
    }
    if cfg.qkv_bias:
        params["blocks"] |= {
            "bq": jnp.zeros((l, hq * hd), dtype),
            "bk": jnp.zeros((l, hkv * hd), dtype),
            "bv": jnp.zeros((l, hkv * hd), dtype),
        }
    if cfg.is_moe:
        e = cfg.n_experts

        def estack(rng, k, n):
            return jax.vmap(lambda r: jax.vmap(
                lambda r2: dense_init(r2, k, n, dtype))(jax.random.split(r, e))
            )(jax.random.split(rng, l))

        params["blocks"] |= {
            "router": stack(ks[5], d, e),
            "we_gate": estack(ks[6], d, ff),
            "we_up": estack(ks[7], d, ff),
            "we_down": estack(ks[8], ff, d),
        }
        if cfg.moe_dense_residual:
            dff = cfg.moe_dense_ff or ff
            params["blocks"] |= {
                "wd_gate": stack(ks[9], d, dff),
                "wd_up": stack(ks[10], d, dff),
                "wd_down": stack(ks[11], dff, d),
            }
    else:
        params["blocks"] |= {
            "w_gate": stack(ks[9], d, ff),
            "w_up": stack(ks[10], d, ff),
            "w_down": stack(ks[11], ff, d),
        }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[12], d, cfg.vocab, dtype)
    return params


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def fuse_gate_stacks(params):
    """Post-`install()` rewrite: stack programmed same-shape projection
    groups into `[G, ...]` gate stacks so each group runs as ONE gate-fused
    multi-MVM kernel launch (kernel v2) per block:

      wq + wk + wv     -> wqkv  (MHA only — GQA K/V widths differ)
      w_gate + w_up    -> w_gu  (dense SwiGLU FFN)

    Gates stack at axis=1 (inside the layer-scan dim). Groups that are not
    all programmed `AimcLinearState`s of one shape pass through unchanged;
    outputs are bit-equal to the unfused path (noise off)."""
    blocks = dict(params["blocks"])
    for stacked_name, names in (("wqkv", ("wq", "wk", "wv")),
                                ("w_gu", ("w_gate", "w_up"))):
        leaves = [blocks.get(nm) for nm in names]
        if not all(isinstance(lf, AimcLinearState) for lf in leaves):
            continue
        if len({(lf.k, lf.n, lf.w_q.shape) for lf in leaves}) != 1:
            continue
        blocks[stacked_name] = stack_states([blocks.pop(nm) for nm in names],
                                            axis=1)
    return dict(params, blocks=blocks)


def _qkv(h, blk, cfg, exe, keys, positions):
    b, s, d = h.shape
    if "wqkv" in blk:      # gate-fused stack (fuse_gate_stacks, MHA)
        biases = (jnp.stack([blk["bq"], blk["bk"], blk["bv"]])
                  if "bq" in blk else None)
        q, k, v = linear_stack(h, blk["wqkv"], exe, keys[0], biases=biases)
    else:
        q = linear(h, blk["wq"], exe, keys[0], blk.get("bq"))
        k = linear(h, blk["wk"], exe, keys[1], blk.get("bk"))
        v = linear(h, blk["wv"], exe, keys[2], blk.get("bv"))
    q = rope(q.reshape(b, s, cfg.n_heads, cfg.hd), positions, cfg.rope_theta)
    k = rope(k.reshape(b, s, cfg.n_kv_heads, cfg.hd), positions, cfg.rope_theta)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    # Megatron-style TP: Q heads sharded over `model` (skipped when the head
    # count does not divide); GQA K/V usually have too few heads to shard.
    # At decode (s == 1) q stays replicated over `model` instead — the KV
    # cache shards its sequence axis there (flash-decoding, layers.py).
    if s > 1:
        q = shard_act(q, model_dim=2)
        k = shard_act(k, model_dim=2)
        v = shard_act(v, model_dim=2)
    else:
        q, k, v = shard_act(q), shard_act(k), shard_act(v)
    return q, k, v


def _ffn(h2, blk, cfg: TransformerConfig, exe: Execution, keys):
    if not cfg.is_moe:
        if "w_gu" in blk:  # gate-fused stack (fuse_gate_stacks)
            g, u = linear_stack(h2, blk["w_gu"], exe, keys[4])
            # same activation-sharding constraints the unfused swiglu applies
            g = shard_act(g, model_dim=h2.ndim - 1)
            u = shard_act(u, model_dim=h2.ndim - 1)
            return linear(jax.nn.silu(g) * u, blk["w_down"], exe, keys[5]), 0.0
        return swiglu(h2, blk["w_gate"], blk["w_up"], blk["w_down"], exe,
                      keys[4]), 0.0
    b, s, d = h2.shape
    y, aux = moe_lib.moe_ffn(
        h2.reshape(b * s, d), blk["router"], blk["we_gate"], blk["we_up"],
        blk["we_down"], top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        exe=exe, key=keys[4])
    y = y.reshape(b, s, d)
    if cfg.moe_dense_residual:
        y = y + swiglu(h2, blk["wd_gate"], blk["wd_up"], blk["wd_down"],
                       exe, keys[5])
    return y, aux


def block_forward(h, blk, cfg: TransformerConfig, exe: Execution, key,
                  positions):
    keys = list(jax.random.split(key, 6)) if key is not None else [None] * 6
    h = shard_act(h)
    q, k, v = _qkv(rmsnorm(h, blk["ln1"], cfg.norm_eps), blk, cfg, exe, keys,
                   positions)
    att = flash_attention(q, k, v, causal=True,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    b, s, _ = h.shape
    h = h + linear(att.reshape(b, s, -1), blk["wo"], exe, keys[3])
    h = shard_act(h)
    ff, aux = _ffn(rmsnorm(h, blk["ln2"], cfg.norm_eps), blk, cfg, exe, keys)
    return h + ff, aux


# ---------------------------------------------------------------------------
# full forward (training / prefill-style)
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: TransformerConfig, exe: Execution,
                 patch_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    if cfg.n_patches and patch_embeds is not None:
        # VLM frontend stub: positions [0, n_patches) carry precomputed
        # InternViT patch embeddings instead of token embeddings.
        h = jnp.concatenate(
            [patch_embeds.astype(exe.cdtype), h[:, cfg.n_patches:]], axis=1)
    return h


def forward(params, tokens, cfg: TransformerConfig, exe: Execution = None,
            rng=None, patch_embeds=None, return_hidden: bool = False):
    """tokens: [B, S] -> logits [B, S, V] (plus MoE aux loss).

    return_hidden=True returns the post-norm hidden states instead of logits
    (the train loop computes cross-entropy in vocab chunks — a [B,S,150k]
    logits tensor must never materialize)."""
    exe = exe or Execution()
    b, s = tokens.shape
    h = embed_tokens(params, tokens, cfg, exe, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    layer_keys = (jax.random.split(rng, cfg.n_layers) if rng is not None
                  else jnp.zeros((cfg.n_layers, 2), jnp.uint32))

    @jax.checkpoint
    def body(carry, xs):
        h, aux = carry
        blk, lk = xs
        key = lk if rng is not None else None
        h, a = block_forward(h, blk, cfg, exe, key, positions)
        return (h, aux + a), None

    (h, aux), _ = jax.lax.scan(body, (h, 0.0),
                               (params["blocks"], layer_keys))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, aux
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = h.astype(jnp.float32) @ as_weight(unembed, jnp.float32)
    return logits, aux


def unembed_matrix(params, cfg: TransformerConfig):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, shardings=None) -> dict:
    """KV cache [L, B, S, H, hd] + per-row lengths. ``shardings`` (a matching
    tree of `NamedSharding`s) creates each leaf directly on its mesh
    placement — the sharded serving engine's slot cache is born distributed
    instead of allocated replicated and moved (host-side callers only;
    inside jit leave it None)."""
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
             "len": jnp.zeros((batch,), jnp.int32)}
    if shardings is not None:
        cache = jax.tree.map(jax.device_put, cache, shardings)
    return cache


def prefill(params, tokens, cfg: TransformerConfig, exe: Execution = None,
            max_seq: int | None = None, patch_embeds=None,
            cache_dtype=jnp.bfloat16, valid_len=None):
    """Full-sequence forward that also materializes the KV cache.

    ``valid_len`` ([B] int32) serves ragged prompts at one padded shape (the
    engine's shape-stability contract): tokens at positions >= valid_len are
    right-padding, the returned logits are gathered at each row's own last
    valid position, and the cache lengths are set per row — decode then
    masks attention with the ragged ``len`` and overwrites the padding K/V
    slots as real tokens arrive."""
    exe = exe or Execution()
    b, s = tokens.shape
    max_seq = max_seq or s
    h = embed_tokens(params, tokens, cfg, exe, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, blk):
        keys = [None] * 6
        q, k, v = _qkv(rmsnorm(h, blk["ln1"], cfg.norm_eps), blk, cfg, exe,
                       keys, positions)
        att = flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk)
        h = h + linear(att.reshape(b, s, -1), blk["wo"], exe, keys[3])
        ff, _ = _ffn(rmsnorm(h, blk["ln2"], cfg.norm_eps), blk, cfg, exe, keys)
        kc = jnp.zeros((b, max_seq, cfg.n_kv_heads, cfg.hd), cache_dtype)
        vc = jnp.zeros((b, max_seq, cfg.n_kv_heads, cfg.hd), cache_dtype)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(cache_dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(cache_dtype), (0, 0, 0, 0))
        return h + ff, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    if valid_len is None:
        h_last = h[:, -1:]
        lens = jnp.full((b,), s, jnp.int32)
    else:
        lens = valid_len.astype(jnp.int32)
        idx = jnp.clip(lens - 1, 0, s - 1)
        h_last = h[jnp.arange(b), idx][:, None]                  # [B, 1, D]
    logits = h_last.astype(jnp.float32) @ as_weight(unembed, jnp.float32)
    cache = {"k": ks, "v": vs, "len": lens}
    return logits, cache


def decode_step(params, cache, tokens, cfg: TransformerConfig,
                exe: Execution = None, ragged: bool = False):
    """tokens: [B, 1] one new token per sequence -> (logits [B,1,V], cache).

    ``ragged=False`` is the lockstep fast path (decode_32k/long_500k cells:
    every sequence is at the same position, so one dynamic_update_slice
    writes the whole batch). ``ragged=True`` is the continuous-batching
    contract: each row writes its K/V at its OWN ``cache["len"]`` position
    (row scatter, `_scatter_kv`) and attends over its own valid length —
    slots prefilled at different times decode side by side in one batch."""
    exe = exe or Execution()
    b = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    positions = cache["len"][:, None]                              # [B, 1]
    max_seq = cache["k"].shape[2]
    pos0 = cache["len"][0]
    row_idx = jnp.clip(cache["len"], 0, max_seq - 1)               # [B]

    def body(h, xs):
        blk, kc, vc = xs
        keys = [None] * 6
        q, k, v = _qkv(rmsnorm(h, blk["ln1"], cfg.norm_eps), blk, cfg, exe,
                       keys, positions)
        if ragged:
            kc = _scatter_kv(kc, k, row_idx)
            vc = _scatter_kv(vc, v, row_idx)
        else:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, pos0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, pos0, 0, 0))
        att = decode_attention(q, kc, vc, kv_len=cache["len"] + 1)
        h = h + linear(att.reshape(b, 1, -1), blk["wo"], exe, keys[3])
        ff, _ = _ffn(rmsnorm(h, blk["ln2"], cfg.norm_eps), blk, cfg, exe, keys)
        return h + ff, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"],
                                         cache["k"], cache["v"]))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = h.astype(jnp.float32) @ as_weight(unembed, jnp.float32)
    new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
    return logits, new_cache


def _scatter_kv(cache_l, new, idx):
    """cache_l: [B, S, H, D]; new: [B, 1, H, D]; idx: [B] write positions.

    A row scatter (writes B rows in place) — NOT a one-hot multiply, which
    reads + rewrites the entire cache every layer."""
    b = cache_l.shape[0]
    return cache_l.at[jnp.arange(b), idx].set(new[:, 0].astype(cache_l.dtype))


# ---------------------------------------------------------------------------
# serving: paged KV cache (DESIGN.md §15)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: TransformerConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32, shardings=None) -> dict:
    """Physical K/V page pools [L, n_pages, P, Hkv, hd].

    Page 0 is the engine's scratch page (`runtime.pages.SCRATCH`): traced
    writes for inactive lanes land there and are never read unmasked. A
    slot's logical cache is the gather of its page-table row (`paged_view`);
    memory scales with pages actually allocated, not slots x max_seq."""
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    pools = {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}
    if shardings is not None:
        pools = jax.tree.map(jax.device_put, pools, shardings)
    return pools


def paged_view(kp, vp, pt, max_seq: int):
    """Gather the dense per-slot K/V view out of the page pools.

    kp/vp: [L, n_pages, P, H, hd]; pt: [S, M] page table -> k/v
    [L, S, max_seq, H, hd]. Rows past a slot's length map through whatever
    page the (possibly stale) table names — `decode_attention` masks
    everything at or beyond ``kv_len`` to exact 0.0 before the softmax, so
    garbage rows never contribute a bit (the §15 equality argument)."""
    l, _, p, h, hd = kp.shape
    s, m = pt.shape
    k = kp[:, pt].reshape(l, s, m * p, h, hd)[:, :, :max_seq]
    v = vp[:, pt].reshape(l, s, m * p, h, hd)[:, :, :max_seq]
    return k, v


def prefill_chunk(params, tokens, cfg: TransformerConfig, exe: Execution,
                  kp, vp, pt_row, pos0, span, *, page_size: int,
                  context_len: int):
    """One bounded prefill leg writing straight into the page pools.

    tokens: [1, C] — the leg's token window (rows past ``span`` are junk
    padding on the final leg); ``pt_row``: [M] this request's page table
    row; ``pos0``/``span``: traced absolute start + valid width. Earlier
    legs' K/V are read back from the pools (cache dtype must be float32 so
    the readback is bit-identical to the producing leg's activations — the
    engine enforces this), the leg attends with ``q_offset=pos0`` over
    exactly ``context_len`` rows (= the dense engine's prompt_pad, so the
    flash-attention chunk reduction order matches dense prefill bitwise),
    and touched pages [pos0//P, (pos0+span-1)//P] are scattered back;
    untouched page indices route to the scratch page 0. Returns
    ``(tok [1,1], kp, vp)`` — tok is argmax at the leg's last valid row,
    meaningful on the final leg only."""
    b, c = tokens.shape
    m = pt_row.shape[0]
    hkv, hd = cfg.n_kv_heads, cfg.hd
    n_rows = m * page_size
    h = embed_tokens(params, tokens, cfg, exe)
    positions = pos0 + jnp.broadcast_to(jnp.arange(c), (b, c))
    j = jnp.arange(m)
    j0 = pos0 // page_size
    j1 = (pos0 + span - 1) // page_size
    pids = jnp.where((j >= j0) & (j <= j1), pt_row, 0)

    def body(h, xs):
        blk, kpl, vpl = xs
        keys = [None] * 6
        q, k, v = _qkv(rmsnorm(h, blk["ln1"], cfg.norm_eps), blk, cfg, exe,
                       keys, positions)
        kc = kpl[pt_row].reshape(n_rows, hkv, hd)
        vc = vpl[pt_row].reshape(n_rows, hkv, hd)
        # extend by C rows so the slice write never clamps at the pool edge
        kx = jnp.concatenate([kc, jnp.zeros((c, hkv, hd), kc.dtype)])
        vx = jnp.concatenate([vc, jnp.zeros((c, hkv, hd), vc.dtype)])
        kx = jax.lax.dynamic_update_slice_in_dim(
            kx, k[0].astype(kx.dtype), pos0, axis=0)
        vx = jax.lax.dynamic_update_slice_in_dim(
            vx, v[0].astype(vx.dtype), pos0, axis=0)
        att = flash_attention(q, kx[None, :context_len], vx[None, :context_len],
                              causal=True, q_offset=pos0,
                              q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        h = h + linear(att.reshape(b, c, -1), blk["wo"], exe, keys[3])
        ff, _ = _ffn(rmsnorm(h, blk["ln2"], cfg.norm_eps), blk, cfg, exe, keys)
        kpl = kpl.at[pids].set(kx[:n_rows].reshape(m, page_size, hkv, hd))
        vpl = vpl.at[pids].set(vx[:n_rows].reshape(m, page_size, hkv, hd))
        return h + ff, (kpl, vpl)

    h, (kp, vp) = jax.lax.scan(body, h, (params["blocks"], kp, vp))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    idx = jnp.broadcast_to(jnp.clip(span - 1, 0, c - 1), (b,))
    h_last = h[jnp.arange(b), idx][:, None]
    logits = h_last.astype(jnp.float32) @ as_weight(unembed, jnp.float32)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return tok, kp, vp
