"""Encoder-decoder transformer backbone (seamless-m4t-large-v2).

The assignment specifies the transformer BACKBONE only; the speech/text
frontend is a STUB — `input_specs()` feeds precomputed frame embeddings
[B, S_src, d] directly into the encoder (conformer/w2v-BERT feature extractor
omitted per the frontend-STUB rule).

Shapes policy (documented in DESIGN.md): the per-cell `seq_len` is the
ENCODER frame count for train/prefill (decoder length = seq_len // 4) and the
DECODER self-attention cache length for decode cells (cross-attention K/V from
seq_len // 4 encoder frames).

Pre-LN transformer, GeLU FFN, learned-sinusoidal-free RoPE on decoder self
attention, bidirectional encoder. Cross-attention K/V *projections* are
stationary weights -> AIMC-mapped (program-once via `core.program`, like
every other projection here); the K/V activations themselves are not.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (as_weight, Execution, decode_attention, dense_init,
                                 embed_init, flash_attention, gelu_mlp, linear,
                                 layernorm, rope)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int = 24
    n_dec_layers: int = 24
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    d_ff: int = 8192
    vocab: int = 256206
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    q_chunk: int = 1024
    kv_chunk: int = 1024

    @property
    def hd(self):
        return self.d_model // self.n_heads


def _layer_stack(key, cfg, n, cross: bool, dtype):
    d, hq, hkv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                          cfg.d_ff)
    ks = jax.random.split(key, 12)

    def stack(rng, k_, n_):
        return jax.vmap(lambda r: dense_init(r, k_, n_, dtype))(
            jax.random.split(rng, n))

    p = {
        "ln1_s": jnp.ones((n, d), dtype), "ln1_b": jnp.zeros((n, d), dtype),
        "wq": stack(ks[0], d, hq * hd), "wk": stack(ks[1], d, hkv * hd),
        "wv": stack(ks[2], d, hkv * hd), "wo": stack(ks[3], hq * hd, d),
        "ln3_s": jnp.ones((n, d), dtype), "ln3_b": jnp.zeros((n, d), dtype),
        "w_in": stack(ks[4], d, ff), "b_in": jnp.zeros((n, ff), dtype),
        "w_out": stack(ks[5], ff, d), "b_out": jnp.zeros((n, d), dtype),
    }
    if cross:
        p |= {
            "ln2_s": jnp.ones((n, d), dtype), "ln2_b": jnp.zeros((n, d), dtype),
            "cq": stack(ks[6], d, hq * hd), "ck": stack(ks[7], d, hkv * hd),
            "cv": stack(ks[8], d, hkv * hd), "co": stack(ks[9], hq * hd, d),
        }
    return p


def init(key, cfg: EncDecConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "enc": _layer_stack(ks[1], cfg, cfg.n_enc_layers, False, dtype),
        "dec": _layer_stack(ks[2], cfg, cfg.n_dec_layers, True, dtype),
        "enc_norm_s": jnp.ones((cfg.d_model,), dtype),
        "enc_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "dec_norm_s": jnp.ones((cfg.d_model,), dtype),
        "dec_norm_b": jnp.zeros((cfg.d_model,), dtype),
        "unembed": dense_init(ks[3], cfg.d_model, cfg.vocab, dtype),
    }


def _self_attn(h, p, cfg, exe, keys, positions, causal):
    b, s, _ = h.shape
    hn = layernorm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
    q = rope(linear(hn, p["wq"], exe, keys[0]).reshape(b, s, cfg.n_heads, cfg.hd),
             positions, cfg.rope_theta)
    k = rope(linear(hn, p["wk"], exe, keys[1]).reshape(b, s, cfg.n_kv_heads, cfg.hd),
             positions, cfg.rope_theta)
    v = linear(hn, p["wv"], exe, keys[2]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    att = flash_attention(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk)
    return h + linear(att.reshape(b, s, -1), p["wo"], exe, keys[3]), (k, v)


def _cross_attn(h, enc_kv, p, cfg, exe, keys):
    b, s, _ = h.shape
    hn = layernorm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
    q = linear(hn, p["cq"], exe, keys[4]).reshape(b, s, cfg.n_heads, cfg.hd)
    ek, ev = enc_kv
    att = flash_attention(q, ek, ev, causal=False, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk)
    return h + linear(att.reshape(b, s, -1), p["co"], exe, keys[5])


def _ffn(h, p, cfg, exe, keys):
    hn = layernorm(h, p["ln3_s"], p["ln3_b"], cfg.norm_eps)
    return h + gelu_mlp(hn, p["w_in"], p["b_in"], p["w_out"], p["b_out"],
                        exe, keys[6])


def encode(params, frames, cfg: EncDecConfig, exe: Execution = None, rng=None):
    """frames: [B, S_src, d] precomputed frontend embeddings -> [B, S_src, d]."""
    exe = exe or Execution()
    b, s, _ = frames.shape
    h = frames.astype(exe.cdtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    n = cfg.n_enc_layers
    lkeys = (jax.random.split(rng, n) if rng is not None
             else jnp.zeros((n, 2), jnp.uint32))

    @jax.checkpoint
    def body(h, xs):
        blk, lk = xs
        keys = (list(jax.random.split(lk, 7)) if rng is not None
                else [None] * 7)
        h, _ = _self_attn(h, blk, cfg, exe, keys, positions, causal=False)
        h = _ffn(h, blk, cfg, exe, keys)
        return h, None

    h, _ = jax.lax.scan(body, h, (params["enc"], lkeys))
    return layernorm(h, params["enc_norm_s"], params["enc_norm_b"], cfg.norm_eps)


def _dec_cross_kv(params, enc_out, cfg, exe):
    """Precompute per-layer cross K/V from encoder output (done once)."""
    b, s, _ = enc_out.shape

    def body(_, blk):
        k = linear(enc_out, blk["ck"], exe).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = linear(enc_out, blk["cv"], exe).reshape(b, s, cfg.n_kv_heads, cfg.hd)
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec"])
    return ck, cv


def decode_train(params, enc_out, tokens, cfg: EncDecConfig,
                 exe: Execution = None, rng=None,
                 return_hidden: bool = False):
    """Teacher-forced decoder pass. tokens: [B, S_tgt] -> logits."""
    exe = exe or Execution()
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    se = enc_out.shape[1]
    n = cfg.n_dec_layers
    lkeys = (jax.random.split(jax.random.fold_in(rng, 1), n)
             if rng is not None else jnp.zeros((n, 2), jnp.uint32))

    @jax.checkpoint
    def body(h, xs):
        blk, lk = xs
        keys = (list(jax.random.split(lk, 7)) if rng is not None
                else [None] * 7)
        h, _ = _self_attn(h, blk, cfg, exe, keys, positions, causal=True)
        ek = linear(enc_out, blk["ck"], exe, keys[4] if rng is not None else None)
        ev = linear(enc_out, blk["cv"], exe, None)
        h = _cross_attn(h, (ek.reshape(b, se, cfg.n_kv_heads, cfg.hd),
                            ev.reshape(b, se, cfg.n_kv_heads, cfg.hd)),
                        blk, cfg, exe, keys)
        h = _ffn(h, blk, cfg, exe, keys)
        return h, None

    h, _ = jax.lax.scan(body, h, (params["dec"], lkeys))
    h = layernorm(h, params["dec_norm_s"], params["dec_norm_b"], cfg.norm_eps)
    if return_hidden:
        return h, 0.0
    logits = h.astype(jnp.float32) @ as_weight(params["unembed"], jnp.float32)
    return logits, 0.0


def forward(params, batch, cfg: EncDecConfig, exe: Execution = None, rng=None,
            return_hidden: bool = False):
    """batch = {frames [B,S,d], tokens [B,S_tgt]} -> decoder logits."""
    enc_out = encode(params, batch["frames"], cfg, exe, rng)
    return decode_train(params, enc_out, batch["tokens"], cfg, exe, rng,
                        return_hidden)


def unembed_matrix(params, cfg: EncDecConfig):
    return params["unembed"]


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: EncDecConfig, batch: int, max_seq: int, src_len: int,
               dtype=jnp.bfloat16):
    n, hkv, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((n, batch, max_seq, hkv, hd), dtype),
        "v": jnp.zeros((n, batch, max_seq, hkv, hd), dtype),
        "ck": jnp.zeros((n, batch, src_len, hkv, hd), dtype),
        "cv": jnp.zeros((n, batch, src_len, hkv, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, frames, tokens, cfg: EncDecConfig, exe: Execution = None,
            max_seq: int | None = None, cache_dtype=jnp.bfloat16):
    """Encode + teacher-forced decoder prefill, returning the decode cache."""
    exe = exe or Execution()
    enc_out = encode(params, frames, cfg, exe)
    b, s = tokens.shape
    se = enc_out.shape[1]
    max_seq = max_seq or s
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, blk):
        keys = [None] * 7
        h, (k, v) = _self_attn(h, blk, cfg, exe, keys, positions, causal=True)
        ek = linear(enc_out, blk["ck"], exe).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        ev = linear(enc_out, blk["cv"], exe).reshape(b, se, cfg.n_kv_heads, cfg.hd)
        h = _cross_attn(h, (ek, ev), blk, cfg, exe, keys)
        h = _ffn(h, blk, cfg, exe, keys)
        kc = jnp.zeros((b, max_seq, cfg.n_kv_heads, cfg.hd), cache_dtype)
        vc = jnp.zeros((b, max_seq, cfg.n_kv_heads, cfg.hd), cache_dtype)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(cache_dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(cache_dtype), (0, 0, 0, 0))
        return h, (kc, vc, ek.astype(cache_dtype), ev.astype(cache_dtype))

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["dec"])
    h = layernorm(h, params["dec_norm_s"], params["dec_norm_b"], cfg.norm_eps)
    logits = h[:, -1:].astype(jnp.float32) @ as_weight(params["unembed"], jnp.float32)
    cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs,
             "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens, cfg: EncDecConfig,
                exe: Execution = None):
    exe = exe or Execution()
    b = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    positions = cache["len"][:, None]

    def body(h, xs):
        blk, kc, vc, ck, cv = xs
        keys = [None] * 7
        hn = layernorm(h, blk["ln1_s"], blk["ln1_b"], cfg.norm_eps)
        q = rope(linear(hn, blk["wq"], exe).reshape(b, 1, cfg.n_heads, cfg.hd),
                 positions, cfg.rope_theta)
        k = rope(linear(hn, blk["wk"], exe).reshape(b, 1, cfg.n_kv_heads, cfg.hd),
                 positions, cfg.rope_theta)
        v = linear(hn, blk["wv"], exe).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        oh = jax.nn.one_hot(cache["len"], kc.shape[1], dtype=kc.dtype)
        kc = kc * (1 - oh[..., None, None]) + oh[..., None, None] * k.astype(kc.dtype)
        vc = vc * (1 - oh[..., None, None]) + oh[..., None, None] * v.astype(vc.dtype)
        att = decode_attention(q, kc, vc, kv_len=cache["len"] + 1)
        h = h + linear(att.reshape(b, 1, -1), blk["wo"], exe)
        # cross attention against precomputed encoder K/V
        hn2 = layernorm(h, blk["ln2_s"], blk["ln2_b"], cfg.norm_eps)
        cq = linear(hn2, blk["cq"], exe).reshape(b, 1, cfg.n_heads, cfg.hd)
        catt = decode_attention(cq, ck, cv)
        h = h + linear(catt.reshape(b, 1, -1), blk["co"], exe)
        h = _ffn(h, blk, cfg, exe, keys)
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (params["dec"], cache["k"], cache["v"],
                                         cache["ck"], cache["cv"]))
    h = layernorm(h, params["dec_norm_s"], params["dec_norm_b"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ as_weight(params["unembed"], jnp.float32)
    new_cache = dict(cache, k=ks, v=vs)
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# paged decoder self-attention K/V (DESIGN.md §15)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: EncDecConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> dict:
    """Decoder self-attention K/V page pools [N_dec, n_pages, P, Hkv, hd].

    Only the GROWING part of the cache pages: cross-attention K/V (ck/cv)
    are computed once from the encoder output and read-only for the whole
    decode, so they stay dense per slot. Page 0 is the reserved scratch
    page (`runtime.pages.SCRATCH`). The serving engine rejects the audio
    family today; these helpers carry the §15 layout so the whisper-style
    decode can adopt paging without a model-code change."""
    shape = (cfg.n_dec_layers, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    return {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}


def paged_view(kp, vp, pt, max_seq: int):
    """Gather dense per-slot decoder K/V [N, S, max_seq, H, hd] out of the
    page pools via the [S, M] page table (same contract as
    transformer.paged_view: rows at or past a slot's length are masked to
    exact 0.0 by `decode_attention` before the softmax)."""
    n, _, p, h, hd = kp.shape
    s, m = pt.shape
    k = kp[:, pt].reshape(n, s, m * p, h, hd)[:, :, :max_seq]
    v = vp[:, pt].reshape(n, s, m * p, h, hd)[:, :, :max_seq]
    return k, v
