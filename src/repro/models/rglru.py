"""RecurrentGemma / Griffin hybrid (recurrentgemma-9b): RG-LRU recurrent
blocks + local (sliding-window, MQA) attention in a 2:1 pattern.

Temporal mixing per layer type:
  * recurrent — two branches from the residual stream: GeLU gate branch, and
    conv1d(4) -> RG-LRU branch; merged multiplicatively, projected back.
    RG-LRU: r_t = a_t * r_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
            a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x)),  c = 8.
    Train/prefill run it as an associative scan (O(log S) depth); decode
    carries (conv window, r) — O(1) state, which is why this arch runs the
    long_500k cell (DESIGN.md §4).
  * local attention — sliding window 2048, kv_heads = 1 (MQA), RoPE.

Layers scan over (rec, rec, attn) units; n_layers % 3 trailing recurrent
blocks run as a second small scan. The recurrence itself is element-wise
(activation x activation) and stays digital — the paper's LSTM boundary —
while every projection is AIMC-mapped (and runs apply-only when an
`AimcProgram` is installed; the conv kernel and Lambda stay digital under the
default `MappingPlan`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (as_weight, Execution, decode_attention, dense_init,
                                 embed_init, flash_attention, linear,
                                 recurrent_prefill, rmsnorm, rope)

C_RGLRU = 8.0


@dataclasses.dataclass(frozen=True)
class RglruConfig:
    name: str
    n_layers: int = 38
    d_model: int = 4096
    n_heads: int = 16
    n_kv_heads: int = 1
    d_ff: int = 12288
    vocab: int = 256000
    d_rnn: int = 0                 # 0 -> d_model
    conv_width: int = 4
    window: int = 2048
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    q_chunk: int = 1024
    kv_chunk: int = 1024

    @property
    def hd(self):
        return self.d_model // self.n_heads

    @property
    def drnn(self):
        return self.d_rnn or self.d_model

    @property
    def n_units(self):
        return self.n_layers // 3

    @property
    def n_tail(self):
        return self.n_layers % 3


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _rec_params(key, cfg, n, dtype):
    d, dr = cfg.d_model, cfg.drnn
    ks = jax.random.split(key, 8)

    def stack(rng, k_, n_):
        return jax.vmap(lambda r: dense_init(r, k_, n_, dtype))(
            jax.random.split(rng, n))

    return {
        "ln": jnp.ones((n, d), dtype),
        "w_gate": stack(ks[0], d, dr),         # GeLU branch
        "w_rnn_in": stack(ks[1], d, dr),       # conv/RG-LRU branch
        "conv_w": jax.random.normal(ks[2], (n, cfg.conv_width, dr), dtype) * 0.02,
        "conv_b": jnp.zeros((n, dr), dtype),
        "w_a": stack(ks[3], dr, dr),           # recurrence gate
        "b_a": jnp.zeros((n, dr), dtype),
        "w_x": stack(ks[4], dr, dr),           # input gate
        "b_x": jnp.zeros((n, dr), dtype),
        "lam": jnp.full((n, dr), 0.649, dtype),  # softplus(lam)*c ~ a in [.9,.999]
        "w_out": stack(ks[5], dr, d),
        "ln2": jnp.ones((n, d), dtype),
        "w_ff_gate": stack(ks[6], d, cfg.d_ff),
        "w_ff_up": stack(ks[7], d, cfg.d_ff),
        "w_ff_down": jax.vmap(lambda r: dense_init(r, cfg.d_ff, d, dtype))(
            jax.random.split(jax.random.fold_in(key, 99), n)),
    }


def _attn_params(key, cfg, n, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)

    def stack(rng, k_, n_):
        return jax.vmap(lambda r: dense_init(r, k_, n_, dtype))(
            jax.random.split(rng, n))

    return {
        "ln": jnp.ones((n, d), dtype),
        "wq": stack(ks[0], d, hq * hd), "wk": stack(ks[1], d, hkv * hd),
        "wv": stack(ks[2], d, hkv * hd), "wo": stack(ks[3], hq * hd, d),
        "ln2": jnp.ones((n, d), dtype),
        "w_ff_gate": stack(ks[4], d, cfg.d_ff),
        "w_ff_up": stack(ks[5], d, cfg.d_ff),
        "w_ff_down": stack(ks[6], cfg.d_ff, d),
    }


def init(key, cfg: RglruConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "units": {
            "rec_a": _rec_params(ks[1], cfg, cfg.n_units, dtype),
            "rec_b": _rec_params(ks[2], cfg, cfg.n_units, dtype),
            "attn": _attn_params(ks[3], cfg, cfg.n_units, dtype),
        },
        "unembed": dense_init(ks[4], cfg.d_model, cfg.vocab, dtype),
    }
    if cfg.n_tail:
        params["tail"] = _rec_params(ks[5], cfg, cfg.n_tail, dtype)
    return params


# ---------------------------------------------------------------------------
# RG-LRU temporal mixing
# ---------------------------------------------------------------------------

def _rglru_gates(x, p, exe, keys):
    """x: [B, S, Dr] conv output -> (a [B,S,Dr], gated input [B,S,Dr])."""
    a_logit = linear(x, p["w_a"], exe, keys[0], p["b_a"]).astype(jnp.float32)
    i_logit = linear(x, p["w_x"], exe, keys[1], p["b_x"]).astype(jnp.float32)
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) \
        * jax.nn.sigmoid(a_logit)
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i_logit) * x.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))
    return a, beta * gated


def _causal_conv(x, w, b, state=None):
    """Depthwise temporal conv. x: [B,S,D], w: [W,D]. state: [B,W-1,D]."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i][None, None] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return out + b[None, None], new_state


def _rec_mix(h, p, cfg, exe, keys, conv_state=None, r_state=None):
    """Recurrent branch. Returns (out [B,S,D], new conv state, new r state)."""
    gate = jax.nn.gelu(linear(h, p["w_gate"], exe, keys[2]))
    xr = linear(h, p["w_rnn_in"], exe, keys[3])
    xc, conv_state = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)
    a, bx = _rglru_gates(xc, p, exe, keys)

    if r_state is None:
        # associative linear recurrence r_t = a_t r_{t-1} + bx_t over seq
        def combine(l, r_):
            return l[0] * r_[0], r_[0] * l[1] + r_[1]
        _, r = jax.lax.associative_scan(combine, (a, bx), axis=1)
        new_r = r[:, -1]
    else:
        r0 = r_state.astype(jnp.float32)

        def step(carry, xs):
            at, bt = xs
            rn = at * carry + bt
            return rn, rn
        # S is 1 during decode; transpose to scan over seq
        rT, rs = jax.lax.scan(step, r0, (jnp.moveaxis(a, 1, 0),
                                         jnp.moveaxis(bx, 1, 0)))
        r = jnp.moveaxis(rs, 0, 1)
        new_r = rT
    out = linear((gate.astype(jnp.float32) * r).astype(exe.cdtype),
                 p["w_out"], exe, keys[4])
    return out, conv_state, new_r


def _ffn(h, p, cfg, exe, keys):
    g = linear(h, p["w_ff_gate"], exe, keys[5])
    u = linear(h, p["w_ff_up"], exe, keys[6])
    return linear(jax.nn.gelu(g) * u, p["w_ff_down"], exe, keys[7])


def _rec_block(h, p, cfg, exe, key, conv_state=None, r_state=None):
    keys = list(jax.random.split(key, 8)) if key is not None else [None] * 8
    mix, conv_state, r_state = _rec_mix(
        rmsnorm(h, p["ln"], cfg.norm_eps), p, cfg, exe, keys, conv_state, r_state)
    h = h + mix
    h = h + _ffn(rmsnorm(h, p["ln2"], cfg.norm_eps), p, cfg, exe, keys)
    return h, conv_state, r_state


def _attn_block(h, p, cfg, exe, key, positions):
    keys = list(jax.random.split(key, 8)) if key is not None else [None] * 8
    b, s, _ = h.shape
    hn = rmsnorm(h, p["ln"], cfg.norm_eps)
    q = rope(linear(hn, p["wq"], exe, keys[0]).reshape(b, s, cfg.n_heads, cfg.hd),
             positions, cfg.rope_theta)
    k = rope(linear(hn, p["wk"], exe, keys[1]).reshape(b, s, cfg.n_kv_heads, cfg.hd),
             positions, cfg.rope_theta)
    v = linear(hn, p["wv"], exe, keys[2]).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    att = flash_attention(q, k, v, causal=True, window=cfg.window,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    h = h + linear(att.reshape(b, s, -1), p["wo"], exe, keys[3])
    h = h + _ffn(rmsnorm(h, p["ln2"], cfg.norm_eps), p, cfg, exe, keys)
    return h


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: RglruConfig, exe: Execution = None, rng=None,
            return_hidden: bool = False):
    exe = exe or Execution()
    b, s = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    n_units = cfg.n_units
    unit_keys = (jax.random.split(rng, n_units * 3).reshape(n_units, 3, 2)
                 if rng is not None else jnp.zeros((n_units, 3, 2), jnp.uint32))

    @jax.checkpoint
    def unit(h, xs):
        ps, uk = xs
        ka, kb, kc = (uk if rng is not None else (None, None, None))
        h, _, _ = _rec_block(h, ps["rec_a"], cfg, exe, ka)
        h, _, _ = _rec_block(h, ps["rec_b"], cfg, exe, kb)
        h = _attn_block(h, ps["attn"], cfg, exe, kc, positions)
        return h, None

    h, _ = jax.lax.scan(unit, h, (params["units"], unit_keys))

    if cfg.n_tail:
        tail_keys = (jax.random.split(jax.random.fold_in(rng, 7), cfg.n_tail)
                     if rng is not None else jnp.zeros((cfg.n_tail, 2), jnp.uint32))

        @jax.checkpoint
        def tail(h, xs):
            ps, tk = xs
            h, _, _ = _rec_block(h, ps, cfg, exe, tk if rng is not None else None)
            return h, None

        h, _ = jax.lax.scan(tail, h, (params["tail"], tail_keys))

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, 0.0
    logits = h.astype(jnp.float32) @ as_weight(params["unembed"], jnp.float32)
    return logits, 0.0


def unembed_matrix(params, cfg: RglruConfig):
    return params["unembed"]


# ---------------------------------------------------------------------------
# serving: O(1)-state decode (window cache + recurrent state)
# ---------------------------------------------------------------------------

def init_cache(cfg: RglruConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               shardings=None):
    """RG-LRU state + conv window + ring-buffer window KV + lengths.
    ``shardings`` (a matching tree of `NamedSharding`s) creates each leaf
    directly on its mesh placement for the sharded serving engine
    (host-side callers only; inside jit leave it None)."""
    w = min(cfg.window, max_seq)
    nu, dr, cw = cfg.n_units, cfg.drnn, cfg.conv_width
    cache = {
        "r_a": jnp.zeros((nu, batch, dr), jnp.float32),
        "r_b": jnp.zeros((nu, batch, dr), jnp.float32),
        "conv_a": jnp.zeros((nu, batch, cw - 1, dr), dtype),
        "conv_b": jnp.zeros((nu, batch, cw - 1, dr), dtype),
        "k": jnp.zeros((nu, batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((nu, batch, w, cfg.n_kv_heads, cfg.hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.n_tail:
        cache |= {"tail_r": jnp.zeros((cfg.n_tail, batch, dr), jnp.float32),
                  "tail_conv": jnp.zeros((cfg.n_tail, batch, cw - 1, dr), dtype)}
    if shardings is not None:
        cache = jax.tree.map(jax.device_put, cache, shardings)
    return cache


def prefill(params, tokens, cfg: RglruConfig, exe: Execution = None,
            max_seq: int | None = None, cache_dtype=jnp.bfloat16,
            valid_len=None):
    """Prompt ingestion for serving: scan the O(1) decode recurrence (conv
    window + RG-LRU state + ring-buffer window cache) over a (right-padded)
    prompt, freezing each row's state past its own ``valid_len``. Returns
    (last-valid logits [B,1,V], decode cache) for slot insertion by the
    continuous-batching engine."""
    exe = exe or Execution()
    cache0 = init_cache(cfg, tokens.shape[0], max_seq or tokens.shape[1],
                        cache_dtype)
    return recurrent_prefill(
        lambda cache, tok: decode_step(params, cache, tok, cfg, exe),
        cache0, tokens, cfg.vocab, valid_len)


def decode_step(params, cache, tokens, cfg: RglruConfig, exe: Execution = None):
    """tokens [B,1] -> (logits [B,1,V], new cache). Ring-buffer window cache."""
    exe = exe or Execution()
    b = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    w = cache["k"].shape[2]
    pos = cache["len"]                                             # [B]
    slot = pos % w

    def unit(h, xs):
        ps, ca, cb, ra, rb, kc, vc = xs
        keys = [None] * 8
        h, ca, ra = _rec_block(h, ps["rec_a"], cfg, exe, None, ca, ra)
        h, cb, rb = _rec_block(h, ps["rec_b"], cfg, exe, None, cb, rb)
        # local attention against the ring buffer
        pa = ps["attn"]
        hn = rmsnorm(h, pa["ln"], cfg.norm_eps)
        q = rope(linear(hn, pa["wq"], exe).reshape(b, 1, cfg.n_heads, cfg.hd),
                 pos[:, None], cfg.rope_theta)
        k = rope(linear(hn, pa["wk"], exe).reshape(b, 1, cfg.n_kv_heads, cfg.hd),
                 pos[:, None], cfg.rope_theta)
        v = linear(hn, pa["wv"], exe).reshape(b, 1, cfg.n_kv_heads, cfg.hd)
        oh = jax.nn.one_hot(slot, w, dtype=kc.dtype)               # [B, W]
        kc = kc * (1 - oh[..., None, None]) + oh[..., None, None] * k.astype(kc.dtype)
        vc = vc * (1 - oh[..., None, None]) + oh[..., None, None] * v.astype(vc.dtype)
        # ring buffer holds only in-window entries (RoPE applied at write
        # time, so slot order is irrelevant); mask unwritten slots during the
        # first < w steps.
        n_valid = jnp.minimum(pos + 1, w)
        att = decode_attention(q, kc, vc, kv_len=n_valid)
        h = h + linear(att.reshape(b, 1, -1), pa["wo"], exe)
        h = h + _ffn(rmsnorm(h, pa["ln2"], cfg.norm_eps), pa, cfg, exe, keys)
        return h, (ca, cb, ra, rb, kc, vc)

    h, (ca, cb, ra, rb, kc, vc) = jax.lax.scan(
        unit, h, (params["units"], cache["conv_a"], cache["conv_b"],
                  cache["r_a"], cache["r_b"], cache["k"], cache["v"]))
    new_cache = dict(cache, conv_a=ca, conv_b=cb, r_a=ra, r_b=rb, k=kc, v=vc,
                     **{"len": cache["len"] + 1})

    if cfg.n_tail:
        def tail(h, xs):
            ps, cs, rs = xs
            h, cs, rs = _rec_block(h, ps, cfg, exe, None, cs, rs)
            return h, (cs, rs)
        h, (tc, tr) = jax.lax.scan(tail, h, (params["tail"], cache["tail_conv"],
                                             cache["tail_r"]))
        new_cache |= {"tail_conv": tc, "tail_r": tr}

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ as_weight(params["unembed"], jnp.float32)
    return logits, new_cache


def prefill_chunk(params, cache, tokens, cfg: RglruConfig,
                  exe: Execution = None, span=None):
    """One bounded prefill leg from an ARBITRARY carried state (the
    recurrent-counterpart of transformer.prefill_chunk; see xlstm's
    docstring — same contract, here over the conv/RG-LRU/ring-buffer
    cache). Returns (last-valid logits [B,1,V], carried cache)."""
    exe = exe or Execution()
    b = tokens.shape[0]
    vl = (None if span is None
          else jnp.broadcast_to(jnp.asarray(span, jnp.int32), (b,)))
    return recurrent_prefill(
        lambda c, t: decode_step(params, c, t, cfg, exe),
        cache, tokens, cfg.vocab, vl)
