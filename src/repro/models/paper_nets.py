"""Executable-JAX twins of the paper's exploration networks (§VII-IX).

These run the *actual math* of the workloads the paper simulates in gem5 —
MLP (1024,1024)+ReLU, the PTB character LSTM, and CNN-F/M/S — in both digital
and AIMC-crossbar execution, so we can measure the paper's claim that analog
execution preserves task behaviour (iso-accuracy studies it cites) while the
cost model (`core.costmodel`) reproduces its timing/energy claims.

The AIMC variants follow the paper's mappings exactly:
  * MLP: both layer matrices mapped side by side on crossbars.
  * LSTM: the four gate matrices tiled side by side so ONE queue+process
    computes all gate pre-activations (§VIII-D); activations digital.
  * CNN: conv kernels flattened into crossbar columns (im2col, [43]);
    feature-map patches queued per output position; dense layers digital.

The ``*_forward_multicore`` variants execute the paper's MULTI-core mappings
(MLP cases 3/4, LSTM cases 3/4, the pipelined CNN) through
`core.schedule.CoreSchedule` — column-split crossbar shards per core, with
per-core CM_*/comm ledgers — and are numerically equal to the single-core
programmed path (noise off).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import schedule as schedule_lib
from repro.core.aimc import AimcConfig, aimc_apply, program_linear
from repro.core.aimclib import AimcContext


# ---------------------------------------------------------------------------
# MLP (paper Fig. 6)
# ---------------------------------------------------------------------------

def mlp_init(key, n: int = 1024, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    s = (2.0 / n) ** 0.5
    return {"w1": jax.random.normal(k1, (n, n), dtype) * s,
            "w2": jax.random.normal(k2, (n, n), dtype) * s}


def mlp_forward_digital(params, x):
    h = jax.nn.relu(x @ params["w1"])
    return jax.nn.relu(h @ params["w2"])


def mlp_forward_aimc(params, x, cfg: AimcConfig, key=None, ctx=None):
    """Pass a previously returned `ctx` to run program-once/apply-many:
    CM_INITIALIZE happens on the first call only (paper §IV-B). The relus
    ride the kernel-v2 fused epilogue (bit-equal to separate relu ops)."""
    if ctx is None:
        ctx = AimcContext(cfg, key)
        ctx.map_matrix("fc1", params["w1"])
        ctx.map_matrix("fc2", params["w2"])
    h = ctx.linear("fc1", x, activation="relu")
    return ctx.linear("fc2", h, activation="relu"), ctx


def mlp_program(params, cfg: AimcConfig, key=None):
    """Program the two MLP matrices (entries fc1/fc2) — the registry both
    the single-core ctx path and the multi-core schedules execute from."""
    ctx = AimcContext(cfg, key)
    ctx.map_matrix("fc1", params["w1"])
    ctx.map_matrix("fc2", params["w2"])
    return ctx.program()


def mlp_forward_multicore(params, x, cfg: AimcConfig, cores: int = 1,
                          key=None, schedule=None):
    """Paper Fig. 6 multi-core mappings through `core.schedule`:
    cores=1 -> case 1, cores=2 -> case 3 (layer per core), cores=4 ->
    case 4 (each layer column-split over two cores). Reuse the returned
    schedule across calls for program-once semantics."""
    if schedule is None:
        schedule = schedule_lib.mlp_schedule(mlp_program(params, cfg, key),
                                             cores)
    h = jax.nn.relu(schedule.apply("fc1", x))
    return jax.nn.relu(schedule.apply("fc2", h)), schedule


# ---------------------------------------------------------------------------
# LSTM (paper Fig. 9): one cell layer + dense softmax head
# ---------------------------------------------------------------------------

def lstm_init(key, nh: int, x_dim: int = 50, y_dim: int = 50, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    kin = nh + x_dim
    s = (1.0 / kin) ** 0.5
    return {
        "w_f": jax.random.normal(ks[0], (kin, nh), dtype) * s,
        "w_i": jax.random.normal(ks[1], (kin, nh), dtype) * s,
        "w_g": jax.random.normal(ks[2], (kin, nh), dtype) * s,
        "w_o": jax.random.normal(ks[3], (kin, nh), dtype) * s,
        "w_y": jax.random.normal(ks[4], (nh, y_dim), dtype) * (1.0 / nh) ** 0.5,
    }


def _lstm_cell_math(gates, c_prev, nh):
    f = jax.nn.sigmoid(gates[..., :nh])
    i = jax.nn.sigmoid(gates[..., nh:2 * nh])
    g = jnp.tanh(gates[..., 2 * nh:3 * nh])
    o = jax.nn.sigmoid(gates[..., 3 * nh:])
    c = f * c_prev + i * g
    return o * jnp.tanh(c), c


# Per-gate epilogues of the f/i/g/o stack — applied INSIDE the gate-fused
# kernel on the last row-block step (kernel v2).
LSTM_GATE_ACTS = ("sigmoid", "sigmoid", "tanh", "sigmoid")


def _lstm_cell_from_activated(f, i, g, o, c_prev):
    """Cell update on gate values the fused epilogue already activated."""
    c = f * c_prev + i * g
    return o * jnp.tanh(c), c


def lstm_forward_digital(params, xs, nh: int):
    """xs: [T, B, x_dim] -> softmax outputs [T, B, y]."""
    w_cell = jnp.concatenate([params["w_f"], params["w_i"], params["w_g"],
                              params["w_o"]], axis=1)
    b = xs.shape[1]

    def step(carry, x_t):
        h, c = carry
        gates = jnp.concatenate([h, x_t], axis=-1) @ w_cell
        h, c = _lstm_cell_math(gates, c, nh)
        y = jax.nn.softmax(h @ params["w_y"], axis=-1)
        return (h, c), y

    init = (jnp.zeros((b, nh)), jnp.zeros((b, nh)))
    _, ys = jax.lax.scan(step, init, xs)
    return ys


def lstm_forward_aimc(params, xs, nh: int, cfg: AimcConfig, key=None,
                      ctx=None, fuse_gates: bool | None = None):
    """The §VIII-D mapping: gate matrices side by side -> one CM_PROCESS.

    Reuse a returned `ctx` across calls to keep the gates stationary
    (program-once): only the first call pays CM_INITIALIZE.

    ``fuse_gates=True`` maps f/i/g/o as a `[4, ...]` stacked tenant instead
    and runs them through the gate-fused multi-MVM with per-gate
    sigmoid/tanh epilogues applied in-kernel — same CM_* profile, one kernel
    launch per step, and the gate activations never round-trip as a
    separate op. Outputs are bit-equal to the side-by-side path (noise
    off). A reused `ctx` fixes the layout at mapping time; passing a
    contradicting `fuse_gates` with it raises instead of silently running
    the other path."""
    if ctx is None:
        ctx = AimcContext(cfg, key)
        gates_w = [params["w_f"], params["w_i"], params["w_g"], params["w_o"]]
        if fuse_gates:
            ctx.map_gate_stack("cell", gates_w)
        else:
            ctx.map_gates("cell", gates_w)
        ctx.map_matrix("dense", params["w_y"])
    fused = ctx._state("cell").stack_shape != ()
    if fuse_gates is not None and fuse_gates != fused:
        raise ValueError(
            f"ctx maps 'cell' {'stacked' if fused else 'side-by-side'} but "
            f"fuse_gates={fuse_gates} was requested; map a fresh ctx")
    b = xs.shape[1]

    h = jnp.zeros((b, nh))
    c = jnp.zeros((b, nh))
    ys = []
    for t in range(xs.shape[0]):          # python loop: ctx counts CM_* ops
        hx = jnp.concatenate([h, xs[t]], axis=-1)
        if fused:
            f, i, g, o = ctx.linear_stack("cell", hx,
                                          activations=LSTM_GATE_ACTS)
            h, c = _lstm_cell_from_activated(f, i, g, o, c)
        else:
            gates = ctx.linear("cell", hx)
            h, c = _lstm_cell_math(gates, c, nh)
        ys.append(jax.nn.softmax(ctx.linear("dense", h), axis=-1))
    return jnp.stack(ys), ctx


def lstm_program(params, cfg: AimcConfig, key=None):
    """Program the §VIII-D mapping (gates side by side + dense head)."""
    ctx = AimcContext(cfg, key)
    ctx.map_gates("cell", [params["w_f"], params["w_i"], params["w_g"],
                           params["w_o"]])
    ctx.map_matrix("dense", params["w_y"])
    return ctx.program()


def lstm_forward_multicore(params, xs, nh: int, cfg: AimcConfig,
                           cores: int = 1, key=None, schedule=None):
    """Paper Table II-B multi-core mappings through `core.schedule`:
    cores=1 -> case 1/2, cores=2 -> case 3 (cell core + dense core),
    cores=5 -> case 4 (cell gate-sliced over four cores + a dense core).
    Gate slices reassemble to the full pre-activation vector, so the cell
    math — and the whole sequence output — matches single-core exactly."""
    if schedule is None:
        schedule = schedule_lib.lstm_schedule(
            lstm_program(params, cfg, key), cores, nh,
            x_dim=xs.shape[-1], y_dim=params["w_y"].shape[1])
    b = xs.shape[1]
    h = jnp.zeros((b, nh))
    c = jnp.zeros((b, nh))
    ys = []
    for t in range(xs.shape[0]):
        gates = schedule.apply("cell", jnp.concatenate([h, xs[t]], axis=-1))
        h, c = _lstm_cell_math(gates, c, nh)
        ys.append(jax.nn.softmax(schedule.apply("dense", h), axis=-1))
    return jnp.stack(ys), schedule


# ---------------------------------------------------------------------------
# CNN-F/M/S (paper Fig. 12): conv layers on crossbars via im2col
# ---------------------------------------------------------------------------

CNN_SPECS = {
    # (cin, k, cout, stride, pad, lrn, pool)
    "F": [(3, 11, 64, 4, 0, True, 2), (64, 5, 256, 1, 2, True, 2),
          (256, 3, 256, 1, 1, False, 1), (256, 3, 256, 1, 1, False, 1),
          (256, 3, 256, 1, 1, False, 2)],
    "M": [(3, 7, 96, 2, 0, True, 2), (96, 5, 256, 1, 2, True, 2),
          (256, 3, 512, 1, 1, False, 1), (512, 3, 512, 1, 1, False, 1),
          (512, 3, 512, 1, 1, False, 2)],
    "S": [(3, 7, 96, 2, 0, True, 3), (96, 5, 256, 1, 1, True, 2),
          (256, 3, 512, 1, 1, False, 1), (512, 3, 512, 1, 1, False, 1),
          (512, 3, 512, 1, 1, False, 3)],
}


def cnn_init(key, variant: str, img: int = 224, n_classes: int = 1000,
             dtype=jnp.float32):
    spec = CNN_SPECS[variant]
    params = {"convs": [], "dense": []}
    hw = img
    ks = jax.random.split(key, len(spec) + 3)
    for i, (cin, k, cout, stride, pad, _lrn, pool) in enumerate(spec):
        fan = k * k * cin
        params["convs"].append(
            jax.random.normal(ks[i], (k, k, cin, cout), dtype) * (2.0 / fan) ** 0.5)
        hw = (hw + 2 * pad - k) // stride + 1
        hw = hw // pool
    flat = hw * hw * spec[-1][2]
    dims = [flat, 4096, 4096, n_classes]
    for j in range(3):
        params["dense"].append(
            jax.random.normal(ks[len(spec) + j], (dims[j], dims[j + 1]), dtype)
            * (2.0 / dims[j]) ** 0.5)
    return params


def _lrn(x, n=5, alpha=1e-4, beta=0.75, k=2.0):
    sq = x * x
    pads = n // 2
    acc = sum(jnp.roll(sq, s, axis=-1) for s in range(-pads, pads + 1))
    return x / (k + alpha * acc) ** beta


def _pool(x, p):
    if p == 1:
        return x
    b, h, w, c = x.shape
    h2, w2 = h // p * p, w // p * p
    x = x[:, :h2, :w2].reshape(b, h2 // p, p, w2 // p, p, c)
    return jnp.max(x, axis=(2, 4))


def _im2col(x, k, stride, pad):
    """x: [B,H,W,C] -> patches [B, Ho*Wo, k*k*C]."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    b, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    idx_h = (jnp.arange(ho) * stride)[:, None] + jnp.arange(k)[None]
    idx_w = (jnp.arange(wo) * stride)[:, None] + jnp.arange(k)[None]
    patches = x[:, idx_h[:, None, :, None], idx_w[None, :, None, :], :]
    return patches.reshape(b, ho * wo, k * k * c), ho, wo


def cnn_forward(params, x, variant: str, cfg: AimcConfig | None = None,
                key=None, ctx=None):
    """x: [B, 224, 224, 3]. cfg=None -> digital; else conv layers on AIMC.

    As above, pass a returned `ctx` back in to skip re-programming the conv
    kernels (the im2col crossbar tenants stay stationary)."""
    spec = CNN_SPECS[variant]
    if cfg is not None and ctx is None:
        ctx = AimcContext(cfg, key)
    for i, (cin, k, cout, stride, pad, lrn, pool) in enumerate(spec):
        w = params["convs"][i]
        patches, ho, wo = _im2col(x, k, stride, pad)
        b, npos, kdim = patches.shape
        wmat = w.reshape(kdim, cout)
        if ctx is not None:
            name = f"conv{i}"
            if name not in ctx:
                ctx.map_matrix(name, wmat)
            # relu rides the kernel-v2 fused epilogue (commutes with reshape)
            y = ctx.linear(name, patches.reshape(b * npos, kdim),
                           activation="relu")
            x = y.reshape(b, ho, wo, cout)
        else:
            y = patches.reshape(b * npos, kdim) @ wmat
            x = jax.nn.relu(y.reshape(b, ho, wo, cout))
        if lrn:
            x = _lrn(x)
        x = _pool(x, pool)
    h = x.reshape(x.shape[0], -1)
    for j, w in enumerate(params["dense"]):      # dense: digital (paper §IX-A)
        h = h @ w
        h = jax.nn.relu(h) if j < 2 else jax.nn.softmax(h, axis=-1)
    return (h, ctx) if ctx is not None else h


def cnn_program(params, variant: str, cfg: AimcConfig, key=None):
    """Program every conv kernel (im2col-flattened) as entries conv0..4."""
    ctx = AimcContext(cfg, key)
    for i, w in enumerate(params["convs"]):
        ctx.map_matrix(f"conv{i}", w.reshape(-1, w.shape[-1]))
    return ctx.program()


def cnn_pipeline_stages(params, variant: str, cfg: AimcConfig, schedule):
    """Per-core stage callables of the §IX-A pipeline: stage i runs conv
    layer i on core i (im2col -> crossbar -> relu/lrn/pool); the final
    digital stage runs the dense head. Feed to `core.schedule.pipeline_run`
    to measure per-stage times, or chain sequentially — values are identical
    either way (pipelining changes timing, not math)."""
    spec = CNN_SPECS[variant]

    def make(i, row):
        _cin, k, cout, stride, pad, lrn, pool = row

        def stage(x):
            patches, ho, wo = _im2col(x, k, stride, pad)
            b, npos, kdim = patches.shape
            y = schedule.apply(f"conv{i}", patches.reshape(b * npos, kdim))
            x2 = jax.nn.relu(y.reshape(b, ho, wo, cout))
            if lrn:
                x2 = _lrn(x2)
            return _pool(x2, pool)

        return stage

    def dense_stage(x):
        h = x.reshape(x.shape[0], -1)
        for j, w in enumerate(params["dense"]):
            h = h @ w
            h = jax.nn.relu(h) if j < 2 else jax.nn.softmax(h, axis=-1)
        return h

    return [make(i, row) for i, row in enumerate(spec)] + [dense_stage]


def cnn_forward_multicore(params, x, variant: str, cfg: AimcConfig,
                          key=None, schedule=None):
    """The pipelined CNN mapping executed through `core.schedule`: one conv
    layer per core, position-level pipelined in the timing model (the
    schedule's `pipelined_latency` law); dense head digital."""
    if schedule is None:
        schedule = schedule_lib.cnn_schedule(
            cnn_program(params, variant, cfg, key), CNN_SPECS[variant],
            img=x.shape[1])
    for stage in cnn_pipeline_stages(params, variant, cfg, schedule):
        x = stage(x)
    return x, schedule
