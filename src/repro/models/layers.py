"""Shared model-zoo layers, all AIMC-capable.

Every stationary-weight projection in the zoo routes through `linear()`,
which executes one of three ways:

  * digital         — plain matmul (the paper's CPU+SIMD baseline);
  * AIMC, programmed — the weight arrives as a pre-programmed
    `AimcLinearState` (installed by `core.program.AimcProgram.install`):
    apply-only queue/process/dequeue, NO re-programming on the hot path.
    This is the paper's deployment model (weights stationary in crossbars)
    and the serving configuration;
  * AIMC, on-the-fly — `core.aimc.aimc_linear_ste` re-programs with a fresh
    noise draw every call and backprops straight-through (noise-aware
    training).

Attention uses a chunked online-softmax implementation (flash attention as a
pure-JAX double scan) so both 4k training and 32k prefill are O(seq) in
memory. GQA-aware; supports causal and sliding-window masks. Attention
score.V / QK^T products are *never* AIMC-mapped: both operands are
activations (see DESIGN.md §4 applicability boundary).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aimc import (AimcConfig, AimcLinearState, aimc_apply,
                             aimc_apply_stacked, aimc_linear_ste)
from repro.kernels.ref import EPILOGUE_FNS


# ---------------------------------------------------------------------------
# Execution context: how linears run, threaded through every model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Execution:
    """Static execution choices (hashable; safe as a jit static arg)."""
    mode: str = "digital"                  # digital | aimc
    aimc: AimcConfig = AimcConfig()
    compute_dtype: str = "bfloat16"
    # int8-native serving path (beyond-paper §Perf optimization): weights are
    # stored/streamed as int8 codes and dequantized in the MXU epilogue.
    serve_int8: bool = False
    # program-once/apply-many handle (core.program): True declares that an
    # AimcProgram has been install()ed into the parameter tree. Mapped
    # projections arrive at `linear` as AimcLinearState and run apply-only;
    # raw weights that remain (plan-excluded projections) stay DIGITAL
    # instead of silently re-programming per call — re-programming on the
    # hot path is exactly what the program API removes. `aimc` must be the
    # same AimcConfig the program was built with (ADC step/noise agreement).
    programmed: bool = False

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


DIGITAL = Execution()


# ---------------------------------------------------------------------------
# Activation sharding hints (§Perf iteration 1).
#
# Without explicit constraints GSPMD re-shards activations between every
# scanned layer (measured: 300-850 s collective terms on the 16x16 mesh).
# `shard_act` pins batch to the data axes and, when the dimension divides,
# one feature dimension (heads / d_ff / experts / vocab) to `model`. Applied
# only when a concrete mesh is active, so plain CPU tests are unaffected.
# ---------------------------------------------------------------------------

def _current_mesh():
    from repro.compat import current_mesh
    return current_mesh()                  # works inside and outside jit


def shard_act(x: jnp.ndarray, model_dim: int | None = None):
    """Constrain activation x: dim0 -> data axes, model_dim -> 'model'."""
    import os
    from jax.sharding import PartitionSpec as P
    if os.environ.get("REPRO_NO_ACTSHARD"):   # baseline reproduction switch
        return x
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    spec = [None] * x.ndim
    if x.shape[0] % dp_n == 0 and dp_n > 1:
        spec[0] = dp
    if (model_dim is not None
            and x.shape[model_dim] % mesh.shape["model"] == 0):
        spec[model_dim] = "model"
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def mask_batch_select(new, old, active, axis: int = 0):
    """Per-request freeze: keep `new` where `active`, else `old`.

    `active`: [B] bool; `axis` is the batch axis of the (same-shape) arrays.
    The continuous-batching engine uses this to make a retired/empty slot's
    state bit-frozen through a decode step — the dense batch still computes
    the slot's lane, but none of its cache/recurrent state advances."""
    shape = [1] * new.ndim
    shape[axis] = active.shape[0]
    return jnp.where(active.reshape(shape), new, old)


def recurrent_prefill(decode_fn, cache0, tokens, n_vocab, valid_len=None):
    """Serving prefill for O(1)-state archs (xlstm / rglru): scan the
    single-token decode recurrence over a (right-padded) prompt batch.

    ``decode_fn(cache, tok[B,1]) -> (logits [B,1,V], new_cache)`` is the
    model's own decode step with params/cfg closed over; the cache is a flat
    dict whose leaves carry batch at axis 1 except ``"len"`` (axis 0) — the
    shared recurrent-cache layout. Steps at positions >= ``valid_len`` are
    padding: the cache is bit-frozen through them (mask_batch_select), and
    the returned logits are each row's own last valid step. One fixed
    padded shape serves every ragged prompt (jit-stability contract)."""
    b, s = tokens.shape
    vl = (jnp.full((b,), s, jnp.int32) if valid_len is None
          else valid_len.astype(jnp.int32))

    def step(carry, xs):
        cache, last = carry
        t, tok_t = xs
        logits, new_cache = decode_fn(cache, tok_t[:, None])
        active = t < vl
        cache = {k: mask_batch_select(new_cache[k], cache[k], active,
                                      axis=0 if k == "len" else 1)
                 for k in new_cache}
        last = jnp.where((t == vl - 1)[:, None, None],
                         logits.astype(jnp.float32), last)
        return (cache, last), None

    last0 = jnp.zeros((b, 1, n_vocab), jnp.float32)
    (cache, logits), _ = jax.lax.scan(step, (cache0, last0),
                                      (jnp.arange(s), tokens.T))
    return logits, cache


def as_weight(w, dtype):
    """Materialize a weight that may be stored as int8 codes + scales.

    The paper's number format as a serving optimization (§Perf): weights live
    in HBM as int8 (half the bytes of bf16, quarter of f32) and dequantize in
    VMEM right before the MXU — the digital shadow of keeping them stationary
    in a crossbar."""
    if isinstance(w, dict) and "q" in w:
        return w["q"].astype(dtype) * w["s"].astype(dtype)
    return w.astype(dtype)


def linear(x: jnp.ndarray, w: jnp.ndarray, exe: Execution,
           key: jax.Array | None = None, bias: jnp.ndarray | None = None,
           activation: str = "none"):
    """The AIMC-or-digital projection. x: [..., K], w: [K, N] — or a
    pre-programmed `AimcLinearState` (program-once/apply-many serving).

    `bias`/`activation` are the layer epilogue: on the programmed AIMC path
    they fuse into the kernel's last row-block step (kernel v2, no separate
    XLA op); elsewhere they run as the equivalent post-ops."""
    if isinstance(w, AimcLinearState):
        # programmed crossbar tenant: apply-only, CM_INITIALIZE already paid;
        # the epilogue rides the kernel (cfg.fuse_epilogue) in f32.
        if exe.aimc is None:
            raise ValueError(
                "programmed AimcLinearState reached linear() but exe.aimc "
                "is None — install()ed params require an Execution carrying "
                "the AimcConfig the program was built with")
        return aimc_apply(w, x, exe.aimc, key, bias=bias,
                          activation=activation).astype(exe.cdtype)
    if exe.mode == "aimc" and not exe.programmed:
        y = aimc_linear_ste(x, as_weight(w, jnp.float32), key, exe.aimc)
        y = y.astype(exe.cdtype)
    else:
        y = x.astype(exe.cdtype) @ as_weight(w, exe.cdtype)
    if bias is not None:
        y = y + bias.astype(exe.cdtype)
    return EPILOGUE_FNS[activation](y)


def linear_stack(x: jnp.ndarray, ws, exe: Execution,
                 key: jax.Array | None = None, biases=None,
                 activations="none"):
    """Gate-fused multi-MVM projection: G same-shape matrices sharing one
    input (LSTM gates, attention QKV, gate/up FFN pairs) -> tuple of G
    outputs.

    `ws` is either a `[G, ...]`-stacked programmed `AimcLinearState` (built
    once at install time by a model's `fuse_gate_stacks`) — executed as ONE
    weight-stationary kernel launch sharing the input block and DAC scale —
    or a sequence of per-gate weights, which falls back to per-gate
    `linear()` calls (bit-equal noise-off)."""
    if isinstance(ws, AimcLinearState):
        g = ws.stack_shape[-1]
        y = aimc_apply_stacked(ws, x, exe.aimc, key, biases=biases,
                               activations=activations).astype(exe.cdtype)
        return tuple(y[i] for i in range(g))
    g = len(ws)
    if isinstance(activations, str):
        activations = (activations,) * g
    if biases is None:
        biases = (None,) * g
    keys = jax.random.split(key, g) if key is not None else (None,) * g
    return tuple(linear(x, w, exe, k_, bias=b, activation=a)
                 for w, k_, b, a in zip(ws, keys, biases, activations))


# ---------------------------------------------------------------------------
# Norms / embeddings / positional encodings
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Rotary embedding. x: [B, S, H, D] (D even), positions: [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs      # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention: chunked double-scan online softmax, GQA-aware.
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_chunk(q, k, v, q_pos, kv_pos, carry, scale, causal, window, kv_valid):
    """One (q-chunk x kv-chunk) online-softmax update.

    q: [B, G*Hkv, qc, D] grouped-query layout; k/v: [B, Hkv, kc, D].
    carry = (m [B,Hq,qc], l [B,Hq,qc], acc [B,Hq,qc,D]).
    """
    m, l, acc = carry
    b, hq, qc, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, qc, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale                 # [B,Hkv,G,qc,kc]
    mask = (kv_pos[None, :] < kv_valid)                           # pad mask
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    mask = jnp.broadcast_to(mask, (qc, k.shape[2]))
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    s = s.reshape(b, hq, qc, -1)

    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # explicit re-mask: a fully-masked chunk would otherwise yield
    # exp(NEG_INF - NEG_INF) = 1 and corrupt the accumulation
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask.reshape(1, 1, qc, -1), p, 0.0)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhqk,bhkd->bhqd", p.reshape(b, hkv, g * qc, -1),
                    v.astype(jnp.float32)).reshape(b, hq, qc, d)
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    q_chunk=1024, kv_chunk=1024, out_dtype=None):
    """q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].

    O(Sq/qc * Skv/kc) chunk pairs; memory O(qc*kc). The inner body is
    checkpointed so the backward pass recomputes scores (flash-style).
    """
    b, sq0, hq, d = q.shape
    _, skv0, hkv, _ = k.shape
    qc = min(q_chunk, sq0)
    kc = min(kv_chunk, skv0)
    # pad ragged sequence lengths up to a whole number of chunks; padded KV
    # positions are masked out, padded Q rows are sliced off at the end
    sq = -(-sq0 // qc) * qc
    skv = -(-skv0 // kc) * kc
    if sq != sq0:
        q = jnp.pad(q, ((0, 0), (0, sq - sq0), (0, 0), (0, 0)))
    if skv != skv0:
        k = jnp.pad(k, ((0, 0), (0, skv - skv0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv - skv0), (0, 0), (0, 0)))
    scale = 1.0 / (d ** 0.5)
    out_dtype = out_dtype or q.dtype

    qh = jnp.moveaxis(q, 2, 1)                    # [B, Hq, Sq, D]
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    q_blocks = qh.reshape(b, hq, sq // qc, qc, d).transpose(2, 0, 1, 3, 4)
    k_blocks = kh.reshape(b, hkv, skv // kc, kc, d).transpose(2, 0, 1, 3, 4)
    v_blocks = vh.reshape(b, hkv, skv // kc, kc, d).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def kv_body(carry, xs):
        kb, vb, j = xs
        q_blk, qi = carry[3], carry[4]
        q_pos = q_offset + qi * qc + jnp.arange(qc)
        kv_pos = j * kc + jnp.arange(kc)
        m, l, acc = _attn_chunk(q_blk, kb, vb, q_pos, kv_pos, carry[:3],
                                scale, causal, window, skv0)
        return (m, l, acc, q_blk, qi), None

    def q_body(_, xs):
        q_blk, qi = xs
        init = (jnp.full((b, hq, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, hq, qc), jnp.float32),
                jnp.zeros((b, hq, qc, d), jnp.float32),
                q_blk, qi)
        (m, l, acc, _, _), _ = jax.lax.scan(
            kv_body, init, (k_blocks, v_blocks, jnp.arange(skv // kc)))
        o = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, o.astype(out_dtype)

    _, o_blocks = jax.lax.scan(q_body, None,
                               (q_blocks, jnp.arange(sq // qc)))
    o = o_blocks.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)
    return jnp.moveaxis(o, 1, 2)[:, :sq0]


def decode_attention(q, k_cache, v_cache, kv_len=None, window=None):
    """Single-token attention against a KV cache (flash-decoding layout).

    q: [B, 1, Hq, D]; caches: [B, Skv, Hkv, D]; kv_len: [B] valid lengths.
    The cache's sequence axis is sharded over `model`; q stays REPLICATED
    over `model` (each shard computes partial attention over its sequence
    chunk) and the softmax/PV reductions psum only [B, H, G]-sized partials.
    The einsums contract directly against the [B, S, H, D] cache layout with
    ``preferred_element_type=f32`` — no transposed or f32-upcast copy of the
    cache is ever materialized (measured 20x HBM-traffic reduction on
    qwen15-110b decode_32k; EXPERIMENTS.md §Perf).
    """
    b, _, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qg = shard_act(q.reshape(b, hkv, g, d))        # batch->dp, heads replicated
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(skv)
    if kv_len is not None:
        mask = pos[None] < kv_len[:, None]                        # [B, Skv]
        if window is not None:
            mask &= pos[None] > (kv_len[:, None] - 1 - window)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down, exe: Execution, key=None):
    k1, k2, k3 = _split3(key)
    g = shard_act(linear(x, w_gate, exe, k1), model_dim=x.ndim - 1)
    u = shard_act(linear(x, w_up, exe, k2), model_dim=x.ndim - 1)
    return linear(jax.nn.silu(g) * u, w_down, exe, k3)


def gelu_mlp(x, w_in, b_in, w_out, b_out, exe: Execution, key=None):
    k1, k2 = (None, None) if key is None else tuple(jax.random.split(key))
    h = jax.nn.gelu(linear(x, w_in, exe, k1, b_in))
    return linear(h, w_out, exe, k2, b_out)


def _split3(key):
    if key is None:
        return None, None, None
    return tuple(jax.random.split(key, 3))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, k, n, dtype=jnp.float32):
    return (jax.random.normal(key, (k, n), dtype) * (2.0 / (k + n)) ** 0.5)


def embed_init(key, v, d, dtype=jnp.float32):
    return jax.random.normal(key, (v, d), dtype) * 0.02
