"""xLSTM (xlstm-350m): alternating mLSTM and sLSTM blocks.

  * mLSTM — matrix-memory LSTM with exponential gating. Training/prefill use
    the CHUNKWISE-PARALLEL form (intra-chunk quadratic einsums + O(1)
    inter-chunk state scan, the TPU-friendly equivalent of the paper's
    recurrent math); decode uses the O(1) per-step recurrence. The two forms
    are algebraically identical (stabilized log-domain gating).
  * sLSTM — scalar-memory LSTM with exponential gating and block-diagonal
    recurrent connections; inherently sequential -> lax.scan over time.

This is the direct descendant of the ALPINE paper's LSTM exploration: the
gate PRE-projections (W_z/i/f/o, q/k/v) are stationary matrices mapped onto
AIMC crossbars side by side — one queue feeds all gates (paper §VIII-D) —
while the recurrences themselves are element-wise and stay digital (the
sLSTM block-diagonal recurrent weights r_zifo are excluded by the default
`MappingPlan` for the same reason). With an installed `AimcProgram` the gate
projections decode apply-only — programmed once per session.
O(1) decode state is why this arch runs the long_500k cell.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcLinearState, stack_states
from repro.models.layers import (Execution, dense_init, embed_init, linear,
                                 linear_stack, recurrent_prefill, rmsnorm)


@dataclasses.dataclass(frozen=True)
class XlstmConfig:
    name: str
    n_layers: int = 24              # alternating mLSTM, sLSTM
    d_model: int = 1024
    n_heads: int = 4
    vocab: int = 50304
    proj_factor_m: int = 2          # mLSTM inner width multiplier
    ff_factor_s: float = 4 / 3      # sLSTM block FFN multiplier
    chunk: int = 512                # mLSTM chunkwise-parallel chunk length
    norm_eps: float = 1e-6

    @property
    def n_pairs(self):
        return self.n_layers // 2

    @property
    def d_inner(self):
        return self.proj_factor_m * self.d_model

    @property
    def hd_m(self):
        return self.d_inner // self.n_heads

    @property
    def hd_s(self):
        return self.d_model // self.n_heads

    @property
    def d_ff_s(self):
        return int(self.ff_factor_s * self.d_model)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key, cfg: XlstmConfig, dtype=jnp.float32) -> dict:
    n, d, di, h = cfg.n_pairs, cfg.d_model, cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 16)

    def stack(rng, k_, n_):
        return jax.vmap(lambda r: dense_init(r, k_, n_, dtype))(
            jax.random.split(rng, n))

    mlstm = {
        "ln": jnp.ones((n, d), dtype),
        "w_up": stack(ks[0], d, di),
        "w_gate": stack(ks[1], d, di),
        "w_q": stack(ks[2], di, di),
        "w_k": stack(ks[3], di, di),
        "w_v": stack(ks[4], di, di),
        "w_if": stack(ks[5], di, 2 * h),
        "b_if": jnp.concatenate([jnp.zeros((n, h), dtype),
                                 jnp.full((n, h), 3.0, dtype)], -1),
        "gn": jnp.ones((n, di), dtype),
        "w_down": stack(ks[6], di, d),
    }
    slstm = {
        "ln": jnp.ones((n, d), dtype),
        "w_zifo": stack(ks[7], d, 4 * d),
        "r_zifo": jax.random.normal(ks[8], (n, h, cfg.hd_s, 4 * cfg.hd_s),
                                    dtype) * 0.02,
        "b_zifo": jnp.zeros((n, 4 * d), dtype),
        "gn": jnp.ones((n, d), dtype),
        "ln2": jnp.ones((n, d), dtype),
        "w_ff_gate": stack(ks[9], d, cfg.d_ff_s),
        "w_ff_up": stack(ks[10], d, cfg.d_ff_s),
        "w_ff_down": stack(ks[11], cfg.d_ff_s, d),
    }
    return {
        "embed": embed_init(ks[12], cfg.vocab, d, dtype),
        "final_norm": jnp.ones((d,), dtype),
        "pairs": {"mlstm": mlstm, "slstm": slstm},
        "unembed": dense_init(ks[13], d, cfg.vocab, dtype),
    }


def fuse_gate_stacks(params):
    """Post-`install()` rewrite: collapse programmed same-shape gate
    projections into `[G, ...]` stacks so each group runs as ONE gate-fused
    multi-MVM kernel launch (kernel v2) instead of G separate calls:

      mLSTM  w_up + w_gate        -> w_ug   (shared input hn)
             w_q + w_k + w_v      -> w_qkv  (shared input up)
      sLSTM  w_ff_gate + w_ff_up  -> w_ff_gu

    Gates stack at axis=1 (inside the layer-scan dim), so `lax.scan`'s
    per-layer slice exposes the `[G, ...]` stack the fused kernel consumes.
    No-op for groups that are not all programmed states (digital or
    partially-mapped trees pass through unchanged); outputs are bit-equal
    to the unfused path (noise off)."""
    def fuse(tree, groups):
        tree = dict(tree)
        for stacked_name, names in groups:
            leaves = [tree.get(nm) for nm in names]
            if not all(isinstance(lf, AimcLinearState) for lf in leaves):
                continue
            if len({(lf.k, lf.n, lf.w_q.shape) for lf in leaves}) != 1:
                continue
            tree[stacked_name] = stack_states([tree.pop(nm) for nm in names],
                                              axis=1)
        return tree

    pairs = dict(params["pairs"])
    pairs["mlstm"] = fuse(pairs["mlstm"], [("w_ug", ("w_up", "w_gate")),
                                           ("w_qkv", ("w_q", "w_k", "w_v"))])
    pairs["slstm"] = fuse(pairs["slstm"],
                          [("w_ff_gu", ("w_ff_gate", "w_ff_up"))])
    return dict(params, pairs=pairs)


def _groupnorm(x, scale, n_heads, eps=1e-6):
    """Per-head groupnorm over the trailing dim. x: [..., H*dh]."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], n_heads, shp[-1] // n_heads).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(shp)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM: chunkwise-parallel (train/prefill) + step (decode)
# ---------------------------------------------------------------------------

def _mlstm_chunkwise(q, k, v, li, lf, cfg, state=None):
    """q,k,v: [B,S,H,dh] (already scaled); li/lf: [B,S,H] log input/forget
    gates. Returns (h [B,S,H,dh], final state (C, n, m))."""
    b, s, h, dh = q.shape
    c = min(cfg.chunk, s)
    if s % c:
        raise ValueError(f"seq {s} not divisible by mLSTM chunk {c}")
    nc = s // c
    # [nc, B, H, c, ...] chunked, head-major layouts
    qc = q.reshape(b, nc, c, h, dh).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b, nc, c, h, dh).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, c, h, dh).transpose(1, 0, 3, 2, 4)
    lic = li.reshape(b, nc, c, h).transpose(1, 0, 3, 2)
    lfc = lf.reshape(b, nc, c, h).transpose(1, 0, 3, 2)

    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))

    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, xs):
        C, n, m = carry
        qi, ki, vi, lii, lfi = xs                     # [B,H,c,dh], [B,H,c]
        bcum = jnp.cumsum(lfi, axis=-1)               # inclusive cumsum [B,H,c]
        # intra-chunk log decays D_ij = b_i - b_j + li_j (j <= i)
        d_ij = bcum[..., :, None] - bcum[..., None, :] + lii[..., None, :]
        d_ij = jnp.where(causal[None, None], d_ij, -1e30)
        m_local = jnp.max(d_ij, axis=-1)              # [B,H,c]
        d_state = bcum + m[..., None]                 # decay from carry state
        m_i = jnp.maximum(m_local, d_state)
        p_ij = jnp.exp(d_ij - m_i[..., None])
        scores = jnp.einsum("bhid,bhjd->bhij", qi, ki)            # scaled q
        num = jnp.einsum("bhij,bhjd->bhid", p_ij * scores, vi)
        den = jnp.einsum("bhij->bhi", p_ij * scores)
        w_state = jnp.exp(d_state - m_i)              # [B,H,c]
        num = num + w_state[..., None] * jnp.einsum("bhid,bhde->bhie", qi, C)
        den = den + w_state * jnp.einsum("bhid,bhd->bhi", qi, n)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # ---- state update to chunk end -------------------------------------
        g = bcum[..., -1]                             # [B,H]
        m_new = jnp.maximum(g + m, jnp.max(g[..., None] - bcum + lii, axis=-1))
        w_old = jnp.exp(g + m - m_new)                # [B,H]
        w_in = jnp.exp(g[..., None] - bcum + lii - m_new[..., None])  # [B,H,c]
        C_new = w_old[..., None, None] * C + \
            jnp.einsum("bhj,bhjd,bhje->bhde", w_in, ki, vi)
        n_new = w_old[..., None] * n + \
            jnp.einsum("bhj,bhjd->bhd", w_in, ki)
        return (C_new, n_new, m_new), hout

    (C, n, m), hs = jax.lax.scan(chunk_step, state, (qc, kc, vc, lic, lfc))
    hout = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dh)
    return hout, (C, n, m)


def _mlstm_step(q, k, v, li, lf, state):
    """Single-step recurrence. q,k,v: [B,H,dh]; li/lf: [B,H]."""
    C, n, m = state
    m_new = jnp.maximum(lf + m, li)
    f_ = jnp.exp(lf + m - m_new)
    i_ = jnp.exp(li - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k, v)
    n = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C, n, m_new)


def _mlstm_qkvif(hn, p, cfg, exe, keys):
    b, s, _ = hn.shape
    h_, dh = cfg.n_heads, cfg.hd_m
    if "w_ug" in p:        # gate-fused stack (fuse_gate_stacks)
        up, gate = linear_stack(hn, p["w_ug"], exe, keys[0])
        gate = jax.nn.silu(gate)
    else:
        up = linear(hn, p["w_up"], exe, keys[0])
        gate = jax.nn.silu(linear(hn, p["w_gate"], exe, keys[1]))
    if "w_qkv" in p:
        q, k, v = linear_stack(up, p["w_qkv"], exe, keys[2])
    else:
        q = linear(up, p["w_q"], exe, keys[2])
        k = linear(up, p["w_k"], exe, keys[3])
        v = linear(up, p["w_v"], exe, keys[4])
    q = q.reshape(b, s, h_, dh) / (dh ** 0.5)
    k = k.reshape(b, s, h_, dh)
    v = v.reshape(b, s, h_, dh)
    if_ = (linear(up, p["w_if"], exe, keys[5]) + p["b_if"]).astype(jnp.float32)
    li = if_[..., :h_]
    lf = jax.nn.log_sigmoid(if_[..., h_:])
    return q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), \
        li, lf, gate


def mlstm_block(h, p, cfg, exe, key, state=None):
    keys = list(jax.random.split(key, 8)) if key is not None else [None] * 8
    hn = rmsnorm(h, p["ln"], cfg.norm_eps)
    q, k, v, li, lf, gate = _mlstm_qkvif(hn, p, cfg, exe, keys)
    if state is None:
        ho, new_state = _mlstm_chunkwise(q, k, v, li, lf, cfg)
    else:
        # recurrent states compute in f32 regardless of cache storage dtype
        state = jax.tree.map(lambda x: x.astype(jnp.float32), state)
        ho, new_state = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                    li[:, 0], lf[:, 0], state)
        ho = ho[:, None]
    b, s = h.shape[:2]
    ho = _groupnorm(ho.reshape(b, s, -1).astype(exe.cdtype), p["gn"],
                    cfg.n_heads, cfg.norm_eps)
    out = linear(ho * gate, p["w_down"], exe, keys[6])
    return h + out, new_state


# ---------------------------------------------------------------------------
# sLSTM: sequential scan
# ---------------------------------------------------------------------------

def _slstm_seq(zifo, r, hd, n_heads, state):
    """zifo: [B,S,4d] input-side pre-activations; r: [H, dh, 4dh] recurrent
    weights. state: (c, n, h, m) each [B, d]. Returns ([B,S,d], state)."""
    b, s, d4 = zifo.shape
    d = d4 // 4

    def step(carry, x_t):
        c, n, h, m = carry
        hh = h.reshape(b, n_heads, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, r).reshape(b, 4 * d)
        pre = x_t + rec
        zt = jnp.tanh(pre[:, :d])
        it = pre[:, d:2 * d]
        ft = pre[:, 2 * d:3 * d]
        ot = jax.nn.sigmoid(pre[:, 3 * d:])
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c_new = f_ * c + i_ * zt
        n_new = f_ * n + i_
        h_new = ot * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(zifo, 1, 0))
    return jnp.moveaxis(hs, 0, 1), state


def slstm_block(h, p, cfg, exe, key, state=None):
    keys = list(jax.random.split(key, 8)) if key is not None else [None] * 8
    b, s, d = h.shape
    hn = rmsnorm(h, p["ln"], cfg.norm_eps)
    zifo = (linear(hn, p["w_zifo"], exe, keys[0]) +
            p["b_zifo"]).astype(jnp.float32)
    if state is None:
        state = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + \
            (jnp.full((b, d), -1e30, jnp.float32),)
    else:
        state = jax.tree.map(lambda x: x.astype(jnp.float32), state)
    hs, new_state = _slstm_seq(zifo, p["r_zifo"].astype(jnp.float32),
                               cfg.hd_s, cfg.n_heads, state)
    hs = _groupnorm(hs.astype(exe.cdtype), p["gn"], cfg.n_heads, cfg.norm_eps)
    h = h + hs
    hn2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "w_ff_gu" in p:     # gate-fused stack (fuse_gate_stacks)
        g, u = linear_stack(hn2, p["w_ff_gu"], exe, keys[1])
    else:
        g = linear(hn2, p["w_ff_gate"], exe, keys[1])
        u = linear(hn2, p["w_ff_up"], exe, keys[2])
    ff = linear(jax.nn.gelu(g) * u, p["w_ff_down"], exe, keys[3])
    return h + ff, new_state


# ---------------------------------------------------------------------------
# forward / cache / decode
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: XlstmConfig, exe: Execution = None, rng=None,
            return_hidden: bool = False):
    exe = exe or Execution()
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)
    n = cfg.n_pairs
    pair_keys = (jax.random.split(rng, n * 2).reshape(n, 2, 2)
                 if rng is not None else jnp.zeros((n, 2, 2), jnp.uint32))

    @jax.checkpoint
    def pair(h, xs):
        ps, pk = xs
        km, ks_ = (pk if rng is not None else (None, None))
        h, _ = mlstm_block(h, ps["mlstm"], cfg, exe, km)
        h, _ = slstm_block(h, ps["slstm"], cfg, exe, ks_)
        return h, None

    h, _ = jax.lax.scan(pair, h, (params["pairs"], pair_keys))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return h, 0.0
    logits = h.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return logits, 0.0


def unembed_matrix(params, cfg: XlstmConfig):
    return params["unembed"]


def init_cache(cfg: XlstmConfig, batch: int, max_seq: int = 0,
               dtype=jnp.float32, shardings=None):
    """O(1) recurrent state (matrix memory + sLSTM scalars + lengths).
    ``shardings`` (a matching tree of `NamedSharding`s) creates each leaf
    directly on its mesh placement for the sharded serving engine
    (host-side callers only; inside jit leave it None)."""
    n, h, dh, d = cfg.n_pairs, cfg.n_heads, cfg.hd_m, cfg.d_model
    cache = {
        "m_C": jnp.zeros((n, batch, h, dh, dh), dtype),
        "m_n": jnp.zeros((n, batch, h, dh), dtype),
        "m_m": jnp.full((n, batch, h), -1e30, dtype),
        "s_c": jnp.zeros((n, batch, d), dtype),
        "s_n": jnp.zeros((n, batch, d), dtype),
        "s_h": jnp.zeros((n, batch, d), dtype),
        "s_m": jnp.full((n, batch, d), -1e30, dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if shardings is not None:
        cache = jax.tree.map(jax.device_put, cache, shardings)
    return cache


def prefill(params, tokens, cfg: XlstmConfig, exe: Execution = None,
            max_seq: int | None = None, cache_dtype=jnp.float32,
            valid_len=None):
    """Prompt ingestion for serving: scan the O(1) decode recurrence over a
    (right-padded) prompt, freezing each row's state past its own
    ``valid_len``. Returns (last-valid logits [B,1,V], decode cache) — the
    recurrent counterpart of the transformer KV prefill, and what lets the
    continuous-batching engine insert an xLSTM request into a live slot."""
    exe = exe or Execution()
    cache0 = init_cache(cfg, tokens.shape[0], max_seq or tokens.shape[1],
                        cache_dtype)
    return recurrent_prefill(
        lambda cache, tok: decode_step(params, cache, tok, cfg, exe),
        cache0, tokens, cfg.vocab, valid_len)


def decode_step(params, cache, tokens, cfg: XlstmConfig, exe: Execution = None):
    exe = exe or Execution()
    h = jnp.take(params["embed"], tokens, axis=0).astype(exe.cdtype)

    cdt = cache["m_C"].dtype

    def pair(h, xs):
        ps, mC, mn, mm, sc, sn, sh, sm = xs
        h, (mC, mn, mm) = mlstm_block(h, ps["mlstm"], cfg, exe, None,
                                      (mC, mn, mm))
        h, (sc, sn, sh, sm) = slstm_block(h, ps["slstm"], cfg, exe, None,
                                          (sc, sn, sh, sm))
        # store states back at the cache dtype (bf16 by default)
        out = tuple(t.astype(cdt) for t in (mC, mn, mm, sc, sn, sh, sm))
        return h, out

    h, (mC, mn, mm, sc, sn, sh, sm) = jax.lax.scan(
        pair, h, (params["pairs"], cache["m_C"], cache["m_n"], cache["m_m"],
                  cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"]))
    new_cache = dict(cache, m_C=mC, m_n=mn, m_m=mm, s_c=sc, s_n=sn, s_h=sh,
                     s_m=sm)
    new_cache["len"] = cache["len"] + 1
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ params["unembed"].astype(jnp.float32)
    return logits, new_cache


def prefill_chunk(params, cache, tokens, cfg: XlstmConfig,
                  exe: Execution = None, span=None):
    """One bounded prefill leg from an ARBITRARY carried state.

    Unlike `prefill` (which always starts from a fresh `init_cache`), the
    engine's chunked/prefix path threads ``cache`` through — the previous
    leg's output, or a prefix-cache snapshot restored mid-prompt
    (DESIGN.md §15). ``span`` (traced scalar or [B]) freezes rows past the
    leg's valid width, exactly as `recurrent_prefill` freezes padding.
    Returns (last-valid logits [B,1,V], carried cache)."""
    exe = exe or Execution()
    b = tokens.shape[0]
    vl = (None if span is None
          else jnp.broadcast_to(jnp.asarray(span, jnp.int32), (b,)))
    return recurrent_prefill(
        lambda c, t: decode_step(params, c, t, cfg, exe),
        cache, tokens, cfg.vocab, vl)
