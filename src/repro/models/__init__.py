"""Model zoo: AIMC-capable implementations of the assigned architectures.

  transformer — dense + MoE decoder LMs (granite, llama3.2, qwen1.5, glm4,
                internvl2 backbone, arctic, olmoe)
  rglru       — RecurrentGemma (RG-LRU + local attention hybrid)
  xlstm       — sLSTM/mLSTM blocks
  encdec      — Seamless enc-dec backbone
  paper_nets  — the ALPINE paper's own MLP / LSTM / CNN-F/M/S
  layers      — shared AIMC-or-digital linear, flash attention, norms
  moe         — capacity-bucketed expert dispatch
"""
