"""Mixture-of-Experts FFN (arctic-480b: 128e top-2 + dense residual;
olmoe-1b-7b: 64e top-8).

Two capacity-bucketed dispatch strategies, both deterministic-shape and
dry-run friendly, experts sharded over the `model` mesh axis:

  * ``sort``   (default; §Perf iteration 2) — argsort tokens by expert,
    compute capacity ranks from segment starts, scatter into the [E, C, d]
    expert buffers and gather back for the combine. Dispatch costs ~zero
    FLOPs and never materializes a [T, E, C] tensor.
  * ``einsum`` (t5x/flaxformer style; the measured baseline) — one-hot
    dispatch/combine einsums. 2*T*E*C*d FLOPs per einsum: measured 45x the
    useful compute on olmoe (EXPERIMENTS.md §Perf). Kept selectable via
    REPRO_MOE_EINSUM=1 for baseline reproduction.

The router (softmax/top-k) stays digital — it is an activation-on-activation
op, outside the AIMC applicability boundary — while each expert's FFN weights
are stationary matrices and therefore AIMC-mapped (vmapped crossbar
programming per expert; see DESIGN.md §4: experts are ideal crossbar tenants,
mirroring the paper's many-small-matrices-per-tile packing).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcLinearState
from repro.models.layers import Execution, as_weight, linear, shard_act


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float, exe: Execution, key=None):
    """x: [T, d]. Expert weights: [E, d, ff] / [E, ff, d]. Returns ([T, d], aux).

    aux = load-balancing loss (Switch-style: E * sum_e f_e * p_e).
    """
    t, d = x.shape
    e = router_w.shape[1]
    cap = max(1, int(t * top_k / e * capacity_factor))

    # ---- router (digital) --------------------------------------------------
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # [T, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)              # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux (computed before capacity truncation)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)

    xd = x.astype(exe.cdtype)
    if not os.environ.get("REPRO_MOE_EINSUM"):
        y = _moe_sharded(xd, gate_idx, gate_vals, w_gate, w_up, w_down,
                         e, cap, top_k, exe, key)
        if y is not None:
            return y.astype(exe.cdtype), aux
    if os.environ.get("REPRO_MOE_EINSUM"):
        xe, combine = _dispatch_einsum(xd, gate_idx, gate_vals, e, cap, top_k,
                                       exe)
        slot_o = None
    else:
        xe, slot_o = _dispatch_sort(xd, gate_idx, e, cap, top_k)
        combine = None
    xe = shard_act(xe, model_dim=0)        # experts over `model` (EP)

    # ---- expert FFNs (AIMC-mapped when exe.mode == "aimc") -----------------
    # Each expert is its own crossbar tenant. Programmed (AimcLinearState)
    # expert stacks run apply-only under vmap; raw weights in aimc mode run
    # the per-call STE (noise-aware training). `layers.linear` dispatches.
    if isinstance(w_gate, AimcLinearState) or exe.mode == "aimc":
        use_keys = key is not None
        keys = (jax.random.split(key, e * 3).reshape(e, 3, 2) if use_keys
                else jnp.zeros((e, 3, 2), jnp.uint32))

        def one_expert(xi, wg, wu, wd, ks):
            k0, k1, k2 = ((ks[0], ks[1], ks[2]) if use_keys
                          else (None, None, None))
            g = linear(xi, wg, exe, k0)
            u = linear(xi, wu, exe, k1)
            return linear(jax.nn.silu(g) * u, wd, exe, k2)

        ye = jax.vmap(one_expert)(xe, w_gate, w_up, w_down, keys)
    else:
        g = jnp.einsum("ecd,edf->ecf", xe, as_weight(w_gate, exe.cdtype))
        u = jnp.einsum("ecd,edf->ecf", xe, as_weight(w_up, exe.cdtype))
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                        as_weight(w_down, exe.cdtype))

    if combine is not None:                # einsum combine (baseline)
        y = jnp.einsum("tec,ecd->td", combine.astype(exe.cdtype),
                       ye.astype(exe.cdtype))
    else:                                  # gather combine (sort dispatch)
        ye_flat = jnp.concatenate(
            [ye.astype(exe.cdtype).reshape(e * cap, d),
             jnp.zeros((1, d), exe.cdtype)], axis=0)
        y = jnp.einsum("tk,tkd->td", gate_vals.astype(exe.cdtype),
                       ye_flat[slot_o])
    return y.astype(exe.cdtype), aux


def _moe_sharded(xd, gate_idx, gate_vals, w_gate, w_up, w_down,
                 e, cap, top_k, exe, key):
    """Expert parallelism with explicit locality (§Perf iteration 3).

    GSPMD lowers a cross-shard scatter/gather dispatch conservatively
    (measured: per-layer all-reduces of the full [E, C, d] buffer). Instead:

      1. shard_map DISPATCH — every (data, model) device sorts ITS token
         shard into a local [E, C/ndp, d] buffer. The buffer is computed
         redundantly across the `model` axis, so the subsequent
         replicated -> E-over-model re-shard is a free local slice: the
         "all-to-all" costs zero wire.
      2. expert FFN in SPMD land — xe 2-D sharded (E -> model, C -> data);
         the einsum is fully local; FSDP all-gathers only the expert weights.
      3. shard_map COMBINE — one all-gather of ye over `model` per layer
         (each data shard already owns its tokens' capacity rows), then a
         local gather at the capacity slots.

    Returns None when the shapes don't divide the active mesh (falls back to
    the single-device paths below).
    """
    from repro.models.layers import _current_mesh
    mesh = _current_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or exe.mode == "aimc" or isinstance(w_gate, AimcLinearState)):
        return None
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != "model")
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    nmodel = mesh.shape["model"]
    t, d = xd.shape
    cap_loc = max(1, cap // ndp)
    if ndp == 1 or t % ndp or e % nmodel:
        return None

    def disp_local(x_loc, ids_loc):
        xe_loc, slot_loc = _dispatch_sort(x_loc, ids_loc, e, cap_loc, top_k)
        return xe_loc, slot_loc

    from repro.compat import shard_map
    xe, slot_o = shard_map(
        disp_local, mesh=mesh,
        in_specs=(P(dp, None), P(dp, None)),
        out_specs=(P(None, dp, None), P(dp, None)),
        check_vma=False)(xd, gate_idx)
    # replicated-over-model -> E-sharded: a local slice, no communication
    xe = jax.lax.with_sharding_constraint(xe, P("model", dp, None))

    g = jnp.einsum("ecd,edf->ecf", xe, as_weight(w_gate, exe.cdtype))
    u = jnp.einsum("ecd,edf->ecf", xe, as_weight(w_up, exe.cdtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                    as_weight(w_down, exe.cdtype))
    ye = jax.lax.with_sharding_constraint(ye, P("model", dp, None))

    def combine_local(ye_loc, slot_loc, gv_loc):
        ye_full = jax.lax.all_gather(ye_loc, "model", axis=0, tiled=True)
        ye_flat = jnp.concatenate(
            [ye_full.reshape(e * cap_loc, d),
             jnp.zeros((1, d), ye_full.dtype)], axis=0)
        return jnp.einsum("tk,tkd->td", gv_loc.astype(ye_full.dtype),
                          ye_flat[slot_loc])

    # check_vma=False: the model-axis all_gather makes the output
    # replicated over `model`, which the varying-axis checker cannot infer
    y = shard_map(
        combine_local, mesh=mesh,
        in_specs=(P("model", dp, None), P(dp, None), P(dp, None)),
        out_specs=P(dp, None), check_vma=False)(ye, slot_o, gate_vals)
    return y


def _dispatch_sort(xd, gate_idx, e, cap, top_k):
    """Sort-based capacity dispatch: ~zero FLOPs, no [T, E, C] tensor.

    Returns (xe [E, C, d], slot_o [T, k]) where slot_o indexes the flattened
    [E*C (+1 overflow)] expert buffer for the combine gather; dropped
    (over-capacity) assignments point at the zero overflow row.
    """
    t, d = xd.shape
    ids = gate_idx.reshape(-1)                                # [T*k]
    order = jnp.argsort(ids, stable=True)                     # token-major
    sorted_ids = ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e))      # segment starts
    rank = jnp.arange(t * top_k) - jnp.take(starts, sorted_ids)
    slot_s = jnp.where(rank < cap, sorted_ids * cap + rank, e * cap)
    tok_s = order // top_k
    xe_flat = jnp.zeros((e * cap + 1, d), xd.dtype).at[slot_s].set(
        xd[tok_s], mode="drop")
    xe = xe_flat[: e * cap].reshape(e, cap, d)
    inv = jnp.argsort(order)                                  # original order
    slot_o = slot_s[inv].reshape(t, top_k)
    return xe, slot_o


def _dispatch_einsum(xd, gate_idx, gate_vals, e, cap, top_k, exe):
    """One-hot dispatch/combine (t5x style) — the measured baseline."""
    t, d = xd.shape
    flat_mask = jax.nn.one_hot(gate_idx.reshape(-1), e,
                               dtype=jnp.float32)                  # [T*k, E]
    pos = jnp.cumsum(flat_mask, axis=0) - flat_mask                # arrival rank
    pos = jnp.sum(pos * flat_mask, axis=-1)                        # [T*k]
    keep = flat_mask * (pos < cap)[:, None]                        # [T*k, E]
    cap_slot = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                              dtype=jnp.float32)                   # [T*k, C]
    keep = keep.reshape(t, top_k, e)
    cap_slot = cap_slot.reshape(t, top_k, cap)
    dispatch = jnp.einsum("tke,tkc->tec", keep, cap_slot)          # [T, E, C]
    combine = jnp.einsum("tke,tkc,tk->tec", keep, cap_slot, gate_vals)
    xe = jnp.einsum("tec,td->ecd", dispatch.astype(exe.cdtype), xd)
    return xe, combine
