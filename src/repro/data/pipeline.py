"""Deterministic, restart-exact data pipeline.

Design rules for 1000-node runs (DESIGN.md §5):

  * STATELESS: batch i is a pure function of (seed, step, shard) — a restarted
    or re-sharded job regenerates exactly the token stream it would have seen,
    so checkpoint-resume is bit-exact with no pipeline state to persist.
  * SHARDED AT THE SOURCE: each data shard materializes only its slice of the
    global batch (global_batch / n_shards sequences), then `make_global_array`
    assembles a jax.Array with the right Sharding without any host gather.
  * Two backends: a synthetic corpus (zipfian token model with per-document
    structure — enough statistical texture for throughput/loss-curve work) and
    a memory-mapped token-file backend for real corpora.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    backend: str = "synthetic"     # synthetic | tokenfile
    path: str = ""                 # tokenfile backend: uint32 .bin file
    zipf_a: float = 1.2            # synthetic: zipf exponent
    doc_len_mean: int = 512


def _shard_rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def synthetic_batch(cfg: DataConfig, step: int, shard: int, n_shards: int):
    """-> tokens uint32 [local_batch, seq_len]; deterministic in (cfg, step, shard)."""
    local = cfg.global_batch // n_shards
    rng = _shard_rng(cfg, step, shard)
    # zipfian unigrams with doc boundaries (token 0 = BOS)
    z = rng.zipf(cfg.zipf_a, size=(local, cfg.seq_len)).astype(np.uint32)
    toks = np.minimum(z, cfg.vocab - 1)
    doc_starts = rng.random((local, cfg.seq_len)) < (1.0 / cfg.doc_len_mean)
    toks[doc_starts] = 0
    toks[:, 0] = 0
    return toks


def tokenfile_batch(cfg: DataConfig, step: int, shard: int, n_shards: int):
    local = cfg.global_batch // n_shards
    data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
    n_seq = len(data) // (cfg.seq_len + 1)
    rng = _shard_rng(cfg, step, shard)
    idx = rng.integers(0, n_seq, size=local)
    return np.stack([data[i * (cfg.seq_len + 1):
                          i * (cfg.seq_len + 1) + cfg.seq_len] for i in idx])


def host_batch(cfg: DataConfig, step: int, shard: int, n_shards: int):
    fn = {"synthetic": synthetic_batch, "tokenfile": tokenfile_batch}[cfg.backend]
    toks = fn(cfg, step, shard, n_shards)
    return {"tokens": toks.astype(np.int32),
            "labels": np.concatenate([toks[:, 1:], toks[:, :1]], axis=1
                                     ).astype(np.int32)}


def make_global_array(local_batches: dict, mesh, pspec) -> dict:
    """Assemble per-shard host arrays into sharded jax.Arrays (no host gather).

    In a real multi-host run each process passes only ITS shard; here (single
    host) the helper splits/distributes for API parity.
    """
    sharding = jax.sharding.NamedSharding(mesh, pspec)

    def one(x):
        return jax.make_array_from_process_local_data(sharding, x)

    return jax.tree.map(one, local_batches)


class DataIterator:
    """Step-indexed iterator facade (the object the train loop holds)."""

    def __init__(self, cfg: DataConfig, n_shards: int = 1, shard: int = 0,
                 start_step: int = 0):
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard = shard
        self.step = start_step

    def __iter__(self):
        return self

    def __next__(self):
        b = host_batch(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}      # the ONLY pipeline state — by design

    def restore(self, state: dict):
        self.step = int(state["step"])
