"""Deterministic sharded data pipeline (restart-exact; see pipeline.py)."""
from repro.data.pipeline import DataConfig, DataIterator, host_batch, make_global_array
