"""Atomic sharded checkpointing with elastic re-shard on restore."""
from repro.checkpoint.checkpoint import (latest_step, restore, restore_latest,
                                         save, save_async)
