"""Sharded, atomic, resumable checkpointing.

Fault-tolerance contract (DESIGN.md §5):

  * ATOMIC — writes go to ``step_N.tmp/`` and are renamed to ``step_N/`` only
    after every array and the manifest have fsynced; a crash mid-write can
    never corrupt the latest-valid pointer.
  * SELF-DESCRIBING — ``manifest.json`` records the pytree structure, shapes,
    dtypes and the mesh shape the run used.
  * RESHARD-ON-RESTORE — arrays are stored as full (host-assembled) buffers
    per leaf; ``restore`` re-shards them onto WHATEVER mesh the restarted job
    brings up (elastic rescaling: lose a pod, restore 2x16x16 -> 16x16, keep
    training). On a real fleet the np.save backend is swapped for a
    distributed object store; the atomicity/manifest/reshard logic is the
    part that matters and is what we test.
  * ASYNC — ``save_async`` snapshots device arrays then writes on a worker
    thread so the train loop is blocked only for the device->host copy.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import ml_dtypes
import numpy as np

import jax

# np.save cannot serialize ml_dtypes custom dtypes; store them as a same-width
# integer view and record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}
_VIEW_BACK = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
              "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Blocking atomic save. Returns the final checkpoint directory."""
    names, leaves, _ = _flatten_with_names(tree)
    final = os.path.join(path, f"step_{step:08d}")
    # unique tmp dir: a concurrent save_async of the same step must not race
    tmp = f"{final}.{os.getpid()}.{threading.get_ident()}.tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name in _VIEW_AS:
            arr = arr.view(_VIEW_AS[dtype_name])
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"name": name, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    try:
        os.rename(tmp, final)
    except OSError:
        # a concurrent writer won the rename for this step; theirs is valid
        shutil.rmtree(tmp, ignore_errors=True)
    _gc(path, keep=3)
    return final


def save_async(path: str, step: int, tree, extra: dict | None = None):
    """Snapshot to host, then write on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(path, step, host_tree, extra),
                         daemon=True)
    t.start()
    return t


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(path, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(path: str, step: int, target_tree, mesh=None, pspecs=None):
    """Restore into the structure of ``target_tree``; optionally re-shard.

    ``pspecs``: pytree of PartitionSpec matching target_tree (for elastic
    restore onto a different mesh). Returns (tree, extra).
    """
    ckpt = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(target_tree)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    for name, leaf in zip(names, leaves):
        e = by_name[name]
        arr = np.load(os.path.join(ckpt, e["file"]))
        if e["dtype"] in _VIEW_BACK:
            arr = arr.view(_VIEW_BACK[e["dtype"]])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"ckpt {arr.shape} vs target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if mesh is not None and pspecs is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)), tree, pspecs)
    return tree, manifest["extra"]


def restore_latest(path: str, target_tree, mesh=None, pspecs=None):
    step = latest_step(path)
    if step is None:
        return None, None, None
    tree, extra = restore(path, step, target_tree, mesh, pspecs)
    return step, tree, extra


def _gc(path: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)
