"""The AIMC tile model — programming, inference and noise-aware training.

This is the paper's contribution as a composable JAX module. A dense weight
matrix is *programmed* (CM_INITIALIZE) onto one or more crossbar row blocks
(`program_linear`), after which activations flow through the fused
DAC -> crossbar -> ADC pipeline (`aimc_apply` = CM_QUEUE/CM_PROCESS/CM_DEQUEUE).

Two usage modes, matching the paper and its cited training methodology:

  * inference           — program once (with programming noise + drift folded
    in), then apply many times; optional per-call read noise.
  * noise-aware training — `aimc_linear_ste`: the forward pass re-programs on
    the fly with a fresh noise draw (noise injection, [16]) and runs the full
    quantized pipeline; the backward pass is a straight-through estimator
    (gradients flow as if y = x @ W). This makes the AIMC path a drop-in,
    differentiable replacement for any linear layer in the model zoo.

Everything is a pytree / pure function: shardable under pjit, scannable under
lax.scan, and checkpoint-friendly.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import noise as noise_lib
from repro.core.quant import QMAX, adc_step_lsb, sym_scale
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class AimcConfig:
    """Static configuration of the simulated AIMC tile + execution choices.

    Hashable/frozen so it can be a jit static argument."""

    tile_rows: int = 512           # M word lines (crossbar inputs)
    tile_cols: int = 512           # N bit lines (crossbar outputs)
    adc_alpha: float = 1.0         # ADC full-scale factor (quant.adc_step_lsb)
    input_scale: float = 0.0       # 0.0 = dynamic (max-abs); >0 = fixed scale
    noise: noise_lib.NoiseModel = noise_lib.DISABLED
    impl: str = "ref"              # ref | pallas_interpret | pallas_tpu
    out_dtype: str = "float32"
    # kernel v2: apply bias + activation inside the kernel's last row-block
    # step (False = exact unfused fallback, same math as separate ops).
    fuse_epilogue: bool = True
    # read-noise generator: "counter" (cprng, oracle-bit-identical; the CI
    # path) or "hw" (pltpu PRNG, compiled TPU only).
    noise_source: str = "counter"

    @property
    def adc_step(self) -> float:
        return adc_step_lsb(self.tile_rows, self.adc_alpha)


# A programmed linear layer: conductance codes + effective scales.
#
# Registered as a pytree with STATIC (k, n) metadata so programmed states can
# live inside parameter trees: `lax.scan` slices a stacked [L, ...] state to
# the per-layer state, `vmap` maps over expert stacks, and `isinstance`
# dispatch in `models.layers.linear` still works on the traced container.
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("w_q", "s_w"), meta_fields=("k", "n"))
@dataclasses.dataclass(frozen=True)
class AimcLinearState:
    w_q: jnp.ndarray   # int8 [..., KB, M, Np] (leading dims = layer/expert stack)
    s_w: jnp.ndarray   # f32  [..., KB, Np] (drift gain/compensation folded in)
    k: int             # logical in_features
    n: int             # logical out_features

    @property
    def stack_shape(self) -> tuple[int, ...]:
        """Leading stack dims (empty for a single programmed matrix)."""
        return tuple(self.w_q.shape[:-3])

    @property
    def instances(self) -> int:
        out = 1
        for d in self.stack_shape:
            out *= d
        return out

    def with_gain(self, gain) -> "AimcLinearState":
        """Conductance drift applied as DATA: scale the effective per-column
        output scale, leaving the stored codes — and the pytree structure —
        untouched. Aged states therefore install into a parameter tree with
        an identical treedef/shape, so refreshing drift mid-serve never
        triggers a recompile."""
        return AimcLinearState(w_q=self.w_q,
                               s_w=self.s_w * jnp.float32(gain),
                               k=self.k, n=self.n)


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def program_linear(w: jnp.ndarray, cfg: AimcConfig, key: jax.Array | None = None) -> AimcLinearState:
    """CM_INITIALIZE: quantize + (noisily) program a [K, N] weight matrix.

    Row blocks of `tile_rows` are independent physical-tile spans; each gets a
    per-column conductance scale. Programming noise perturbs the stored codes;
    drift and its digital compensation fold into the effective output scale.
    """
    k, n = w.shape
    m = cfg.tile_rows
    kb = _pad_to(k, m) // m
    np_ = _pad_to(n, 128)  # lane alignment for the TPU kernel
    w_pad = jnp.zeros((kb * m, np_), w.dtype).at[:k, :n].set(w)
    w_blocks = w_pad.reshape(kb, m, np_).astype(jnp.float32)

    s_w = sym_scale(w_blocks, axis=1).reshape(kb, np_)              # per (block, col)
    codes = w_blocks / s_w[:, None, :]
    if cfg.noise.enabled and key is not None:
        codes = codes + noise_lib.programming_noise(key, codes, cfg.noise)
    w_q = jnp.clip(jnp.round(codes), -QMAX, QMAX).astype(jnp.int8)

    gain = cfg.noise.drift_gain() * cfg.noise.compensation_gain()
    return AimcLinearState(w_q=w_q, s_w=s_w * gain, k=k, n=n)


def program_stacked(w: jnp.ndarray, cfg: AimcConfig,
                    key: jax.Array | None = None) -> AimcLinearState:
    """CM_INITIALIZE for a stacked weight: [..., K, N] -> stacked state.

    Leading dims (layer stacks scanned by `lax.scan`, expert stacks consumed
    by `vmap`) are programmed independently — each instance is a separate
    crossbar tenant with its own programming-noise draw."""
    lead = w.shape[:-2]
    if not lead:
        return program_linear(w, cfg, key)
    flat = w.reshape((-1,) + w.shape[-2:])
    if key is None:
        st = jax.vmap(lambda wi: program_linear(wi, cfg, None))(flat)
    else:
        keys = jax.random.split(key, flat.shape[0])
        st = jax.vmap(lambda wi, ki: program_linear(wi, cfg, ki))(flat, keys)
    return AimcLinearState(
        w_q=st.w_q.reshape(lead + st.w_q.shape[1:]),
        s_w=st.s_w.reshape(lead + st.s_w.shape[1:]),
        k=st.k, n=st.n)


def _flatten_pad_input(x: jnp.ndarray, state: AimcLinearState, cfg: AimcConfig):
    """Shared CM_QUEUE front end: flatten leading dims, pad K to whole row
    blocks, compute the DAC scale. Returns (xf [B, KB*M], s_x, lead dims)."""
    *lead, k = x.shape
    if k != state.k:
        raise ValueError(f"in_features mismatch: {k} != {state.k}")
    kb, m, np_ = state.w_q.shape[-3:]
    b = 1
    for d in lead:
        b *= d
    xf = x.reshape(b, k).astype(jnp.float32)
    if k != kb * m:
        xf = jnp.pad(xf, ((0, 0), (0, kb * m - k)))
    if cfg.input_scale > 0.0:
        s_x = jnp.full((1, 1), cfg.input_scale, jnp.float32)
    else:
        s_x = sym_scale(xf).reshape(1, 1)
    return xf, s_x, lead


def _noise_args(cfg: AimcConfig, key: jax.Array | None, active_rows: int):
    """(seed, sigma) for the in-kernel PRNG — (None, 0.0) compiles noise out."""
    if cfg.noise.enabled and key is not None and cfg.noise.sigma_read > 0.0:
        return (noise_lib.derive_read_seed(key),
                noise_lib.read_sigma_lsb(active_rows, cfg.noise))
    return None, 0.0


def _pad_bias(bias: jnp.ndarray | None, n: int, np_: int):
    if bias is None:
        return None
    bias = jnp.asarray(bias).reshape(-1).astype(jnp.float32)
    if bias.shape[0] != n:
        raise ValueError(f"bias has {bias.shape[0]} features, layer has {n}")
    return jnp.pad(bias, (0, np_ - n)) if np_ != n else bias


def aimc_apply(state: AimcLinearState, x: jnp.ndarray, cfg: AimcConfig,
               key: jax.Array | None = None, *,
               bias: jnp.ndarray | None = None,
               activation: str = "none") -> jnp.ndarray:
    """CM_QUEUE + CM_PROCESS + CM_DEQUEUE on a programmed layer.

    x: [..., K] -> [..., N]. Leading dims are flattened for the kernel.
    Read noise (when enabled) is drawn *inside* the kernel from a scalar
    seed derived off `key` — no noise tensor is ever allocated. `bias` /
    `activation` form the epilogue: fused into the kernel's last row-block
    step when `cfg.fuse_epilogue`, applied as identical f32 ops after the
    kernel otherwise.
    """
    kb, m, np_ = state.w_q.shape
    xf, s_x, lead = _flatten_pad_input(x, state, cfg)
    seed, sigma = _noise_args(cfg, key, m)
    fuse = cfg.fuse_epilogue
    y = kernel_ops.aimc_matmul_v2(
        xf, state.w_q, state.s_w, s_x, seed,
        _pad_bias(bias, state.n, np_) if fuse else None,
        adc_step=cfg.adc_step, sigma=sigma,
        activation=activation if fuse else "none",
        impl=cfg.impl, noise_source=cfg.noise_source,
    )
    y = y[:, : state.n]
    if not fuse:
        if bias is not None:
            y = y + jnp.asarray(bias).reshape(1, -1).astype(jnp.float32)
        y = kernel_ops.EPILOGUE_FNS[activation](y)
    y = y.astype(jnp.dtype(cfg.out_dtype))
    return y.reshape(*lead, state.n)


def stack_states(states, axis: int = 0) -> AimcLinearState:
    """Stack same-shape programmed states into one `[G, ...]` gate stack.

    The stacked state is the storage format of the gate-fused multi-MVM —
    build it ONCE at programming/install time (it copies the conductance
    codes); stacking per call would re-stream the weights the fused kernel
    exists to keep stationary. `axis` places the gate dim inside existing
    stack dims: layer-scanned `[L, ...]` states stack at axis=1 so
    `lax.scan`'s per-layer slice exposes the `[G, ...]` gate stack."""
    sts = list(states)
    if len(sts) < 2:
        raise ValueError("a gate stack needs at least two states")
    first = sts[0]
    if not 0 <= axis <= len(first.stack_shape):
        raise ValueError(f"axis {axis} outside stack dims "
                         f"{first.stack_shape}")
    for st in sts[1:]:
        if (st.k, st.n) != (first.k, first.n) or st.w_q.shape != first.w_q.shape:
            raise ValueError(
                f"gate stack shape mismatch: {st.w_q.shape} ({st.k},{st.n}) "
                f"vs {first.w_q.shape} ({first.k},{first.n})")
    return AimcLinearState(
        w_q=jnp.stack([st.w_q for st in sts], axis=axis),
        s_w=jnp.stack([st.s_w for st in sts], axis=axis),
        k=first.k, n=first.n)


def aimc_apply_stacked(stack: AimcLinearState, x: jnp.ndarray, cfg: AimcConfig,
                       key: jax.Array | None = None, *,
                       biases: jnp.ndarray | None = None,
                       activations="none") -> jnp.ndarray:
    """Gate-fused multi-MVM on a `[G, ...]`-stacked programmed state.

    x: [..., K] -> [G, ..., N]: ONE weight-stationary kernel launch computes
    every gate, sharing the input block and its DAC quantization.
    `activations` is one epilogue name or a per-gate tuple; `biases` is
    `[G, N]`. Gate g draws noise under `cprng.stack_seed`, so (noise off)
    the outputs are bit-equal to per-gate `aimc_apply` calls.
    """
    if len(stack.stack_shape) != 1:
        raise ValueError(
            f"aimc_apply_stacked needs one leading gate dim, got stack shape "
            f"{stack.stack_shape}")
    g_ = stack.stack_shape[0]
    kb, m, np_ = stack.w_q.shape[-3:]
    xf, s_x, lead = _flatten_pad_input(x, stack, cfg)
    seed, sigma = _noise_args(cfg, key, m)
    if isinstance(activations, str):
        activations = (activations,) * g_
    activations = tuple(activations)
    fuse = cfg.fuse_epilogue
    if biases is not None:
        biases = jnp.asarray(biases).reshape(g_, -1).astype(jnp.float32)
        if biases.shape[1] != stack.n:
            raise ValueError(f"biases have {biases.shape[1]} features, "
                             f"layer has {stack.n}")
    bias_arg = None
    if fuse and biases is not None:
        bias_arg = (jnp.pad(biases, ((0, 0), (0, np_ - stack.n)))
                    if np_ != stack.n else biases)
    y = kernel_ops.aimc_matmul_stacked(
        xf, stack.w_q, stack.s_w, s_x, seed, bias_arg,
        adc_step=cfg.adc_step, sigma=sigma,
        activations=activations if fuse else "none",
        impl=cfg.impl, noise_source=cfg.noise_source,
    )
    y = y[:, :, : stack.n]                                    # [G, B, N]
    if not fuse:
        if biases is not None:
            y = y + biases[:, None, :]
        y = jnp.stack([kernel_ops.EPILOGUE_FNS[a](y[g])
                       for g, a in enumerate(activations)])
    y = y.astype(jnp.dtype(cfg.out_dtype))
    return y.reshape(g_, *lead, stack.n)


# ---------------------------------------------------------------------------
# Noise-aware training: straight-through estimator.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def aimc_linear_ste(x: jnp.ndarray, w: jnp.ndarray, key: jax.Array, cfg: AimcConfig):
    """Differentiable AIMC linear: y = AIMC(x, W) fwd, y = x @ W bwd.

    The forward pass programs W on the fly with a fresh programming-noise draw
    and applies per-call read noise — i.e. noise-injection training [16] — so
    the learned weights become robust to the analog non-idealities.
    """
    return _aimc_fwd_value(x, w, key, cfg)


def _aimc_fwd_value(x, w, key, cfg):
    kp, kr = (jax.random.split(key) if key is not None else (None, None))
    state = program_linear(w, cfg, kp)
    return aimc_apply(state, x, cfg, kr)


def _aimc_fwd(x, w, key, cfg):
    return _aimc_fwd_value(x, w, key, cfg), (x, w)


def _aimc_bwd(cfg, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    dx = gf @ w.T.astype(jnp.float32)
    xl = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    gl = gf.reshape(-1, g.shape[-1])
    dw = xl.T @ gl
    return dx.astype(x.dtype).reshape(x.shape), dw.astype(w.dtype), None


aimc_linear_ste.defvjp(_aimc_fwd, _aimc_bwd)


def aimc_linear(x, w, cfg: AimcConfig, key: jax.Array | None = None,
                state: AimcLinearState | None = None):
    """Low-level front door (models route through `models.layers.linear`,
    which also accepts an `AimcLinearState` directly in place of `w` — the
    program-once/apply-many path built by `core.program.program_model`).

    * training / on-the-fly:     aimc_linear(x, w, cfg, key)        [STE]
    * pre-programmed inference:  aimc_linear(x, None, cfg, key, state)
    * cfg is None or technique off -> caller should use a plain matmul.
    """
    if state is not None:
        return aimc_apply(state, x, cfg, key)
    if isinstance(w, AimcLinearState):
        return aimc_apply(w, x, cfg, key)
    return aimc_linear_ste(x, w, key, cfg)
