"""Cost-model-driven analog/digital auto-placement (DESIGN.md §16).

`MappingPlan` declares WHICH projections go to crossbars; until now the
declaration was hand-written. This module SEARCHES it: given a model's
parameter tree and a crossbar budget, every candidate layer is priced both
ways through the calibrated cost model (`costmodel.evaluate`, the model
`core.schedule` matches at ratio 1.000), and the placer picks the analog
set that minimizes predicted per-vector latency under the capacity
constraint — the heterogeneous-placement search of arXiv 2201.01089 /
2405.14978 on our exact accounting.

The search is a greedy density order with an EXACT feasibility oracle:

  * `layer_costs`     — per mapped layer: t_digital (SIMD gemv + weight
    streaming), t_analog (CM_QUEUE/PROCESS/DEQUEUE through the shared
    `aimc_mvm_time`), and the tiles the layer packs alone.
  * `plan_placement`  — candidates with positive savings, sorted by
    savings-per-tile (density) descending; prefix m is feasible iff the
    RUNNING MAX of packed-context maxima over prefixes 1..m fits the
    budget, where packing is `tile.pack_contexts` — a bit-exact simulation
    of `ProgramBuilder`'s least-loaded shelf packer over the tree-walk
    programming order. The running-max rule makes the chosen prefix length
    monotone in the budget BY CONSTRUCTION (more budget never worsens the
    predicted latency), and the chosen split dominates both all-digital
    and the longest all-analog prefix that fits — the properties
    tests/test_placement_props.py pins.
  * capacity overflow — positive-savings layers the budget cannot hold
    resident become a `RotationPlan`: a HOT prefix stays programmed while
    the leftovers rotate through the freed headroom in greedy groups, one
    rotation state per group (hot + group). The serving engine swaps
    states at decode-chunk boundaries (`ServeEngine._placement_tick`),
    billing each swap's incoming group as CM_INITIALIZE per `SwapEvent` —
    reconciled exactly by `reconcile_swaps`, the `reconcile_recal` idiom.
  * `PlacementRoofline` — the predicted-vs-measured calibration law
    (`OverlapRoofline` idiom): measured per-layer digital apply wallclock
    fits an affine function of the modeled time; the bench gates the fit's
    residuals (benchmarks/bench_placement.py).

Everything here runs at setup time (plain Python over static shapes —
never inside jit); the output is a `MappingPlan` + optional `RotationPlan`
that `program_model` / `ServeEngine` consume.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.aimc import AimcConfig
from repro.core.costmodel import (CALIB, HIGH_POWER, Workload,
                                  analog_mvm_stage, digital_mvm_stage,
                                  evaluate)
from repro.core.program import MappingPlan, iter_mapped_leaves
from repro.core.tile import pack_contexts


# ---------------------------------------------------------------------------
# Per-layer pricing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerCost:
    """One mapped layer priced both ways (one token vector)."""

    path: str
    k: int
    n: int
    instances: int
    fold_index: int        # programming-key index (iter_mapped_leaves order)
    t_digital: float       # modeled seconds/vector on the CPU
    t_analog: float        # modeled seconds/vector on the crossbar
    tiles_alone: int       # tiles this layer packs into an empty context

    @property
    def savings(self) -> float:
        return self.t_digital - self.t_analog

    @property
    def density(self) -> float:
        """Savings per tile the layer would claim standalone — the greedy
        order's key (capacity is the scarce resource)."""
        return self.savings / max(self.tiles_alone, 1)

    @property
    def item(self) -> tuple[str, int, int, int]:
        """The `tile.pack_contexts` row for this layer."""
        return (self.path, self.k, self.n, self.instances)


def _one_layer_time(stage, cfg: AimcConfig, sys, p, coupling: str) -> float:
    w = Workload(name="layer", phases=((stage,),), pipelined=False,
                 coupling=coupling, tile_rows=cfg.tile_rows)
    return evaluate(w, sys, p).time_s


def layer_costs(params, plan: MappingPlan | None, cfg: AimcConfig,
                sys=HIGH_POWER, p=CALIB,
                coupling: str = "tight") -> tuple[LayerCost, ...]:
    """Price every plan-selected layer both ways, in tree-walk order.

    Each side is evaluated as its own one-stage workload, so per-layer
    times SUM exactly to `evaluate()` on the combined `split_workload` —
    the consistency the bench gates at ratio 1.000."""
    out = []
    for path, w, idx in iter_mapped_leaves(params, plan):
        k, n = int(w.shape[-2]), int(w.shape[-1])
        instances = 1
        for d in w.shape[:-2]:
            instances *= int(d)
        t_d = _one_layer_time(digital_mvm_stage(k, n, instances),
                              cfg, sys, p, coupling)
        t_a = _one_layer_time(analog_mvm_stage(k, n, instances),
                              cfg, sys, p, coupling)
        tiles = sum(pack_contexts([(path, k, n, instances)], 1,
                                  cfg.tile_rows, cfg.tile_cols))
        out.append(LayerCost(path=path, k=k, n=n, instances=instances,
                             fold_index=idx, t_digital=t_d, t_analog=t_a,
                             tiles_alone=tiles))
    return tuple(out)


# ---------------------------------------------------------------------------
# Rotation plan (capacity overflow)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RotationPlan:
    """Time-multiplexed placement for a model exceeding the tile budget.

    ``hot`` layers stay programmed in every state; each ``groups[i]`` is a
    cold-layer set resident only in rotation state ``i`` (hot + group).
    ``digital`` lists positive-savings layers that cannot fit even alone
    alongside nothing — permanently digital. Every state's packing fits
    ``tiles_per_context`` by construction (verified again by
    `launch.serve --placement-verify`)."""

    hot: tuple[str, ...]
    groups: tuple[tuple[str, ...], ...]
    digital: tuple[str, ...]
    n_contexts: int
    tiles_per_context: int
    swap_every: int = 1

    def __post_init__(self):
        if self.swap_every < 1:
            raise ValueError("swap_every must be >= 1")

    @property
    def all_names(self) -> tuple[str, ...]:
        """Every layer that is analog in at least one state — the ONE
        uncapped program the engine serves from (`install_subset` carves
        the per-state trees, so a layer's programmed state is identical in
        every rotation state that carries it)."""
        return self.hot + tuple(n for g in self.groups for n in g)

    @property
    def n_states(self) -> int:
        return max(1, len(self.groups))

    def states(self) -> tuple[tuple[str, ...], ...]:
        """Per rotation state, the analog-resident layer names."""
        if not self.groups:
            return (self.hot,)
        return tuple(self.hot + g for g in self.groups)

    def incoming(self, state: int) -> tuple[str, ...]:
        """Matrices reprogrammed when switching INTO ``state`` — the
        CM_INITIALIZE bill of one swap."""
        if not self.groups:
            return ()
        return self.groups[state % len(self.groups)]

    def plan(self) -> MappingPlan:
        """The UNCAPPED MappingPlan for the backing program over
        `all_names` (states together exceed the budget on purpose; the
        per-state packing is what must fit)."""
        return MappingPlan.for_names(self.all_names,
                                     n_contexts=self.n_contexts)

    def summary(self) -> str:
        return (f"RotationPlan: {len(self.hot)} hot + "
                f"{sum(len(g) for g in self.groups)} rotating in "
                f"{len(self.groups)} group(s) (+{len(self.digital)} "
                f"permanently digital), cap {self.tiles_per_context} "
                f"tiles x {self.n_contexts} context(s), swap every "
                f"{self.swap_every} chunk(s)")


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    """One rotation swap, as charged to the serve report."""

    t: float                    # serve-clock instant
    chunk: int                  # lifetime chunk counter at the swap
    state: int                  # rotation state switched INTO
    incoming: tuple[str, ...]   # matrices reprogrammed onto the shared tiles
    initialize: int             # CM_INITIALIZE device writes charged
    wall_s: float               # host wall spent swapping


def reconcile_swaps(program, report) -> bool:
    """The swap books must close exactly: every event's CM_INITIALIZE bill
    equals `reprogram_counts` recomputed from the program's shapes for the
    incoming group, and the report's total equals the per-event sum —
    `runtime.health.reconcile_recal`'s discipline for rotation."""
    events = getattr(report, "swap_events", [])
    for ev in events:
        if ev.initialize != program.reprogram_counts(ev.incoming).initialize:
            return False
    return report.swap_initialize == sum(ev.initialize for ev in events)


# ---------------------------------------------------------------------------
# The placer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementResult:
    """The chosen split plus everything the tests/benches gate on."""

    costs: tuple[LayerCost, ...]          # every candidate, tree-walk order
    analog: tuple[str, ...]               # resident analog paths
    digital: tuple[str, ...]              # paths served digitally
    plan: MappingPlan                     # capped plan selecting `analog`
    n_contexts: int
    tiles_per_context: int | None
    predicted_s: float                    # chosen split, seconds/vector
    predicted_digital_s: float            # all-digital baseline
    predicted_analog_fit_s: float         # longest all-analog prefix that fits
    overflow: bool                        # positive-savings layers left out
    rotation: "RotationPlan | None" = None

    def predicted_for(self, analog_paths) -> float:
        """Predicted seconds/vector for an arbitrary analog subset — the
        per-layer sum the bench cross-checks against `evaluate()` on the
        matching `split_workload`."""
        analog_paths = set(analog_paths)
        return sum(c.t_analog if c.path in analog_paths else c.t_digital
                   for c in self.costs)

    def summary(self) -> str:
        cap = (f"{self.tiles_per_context} tiles/context"
               if self.tiles_per_context is not None else "uncapped")
        line = (f"auto-placement: {len(self.analog)}/{len(self.costs)} "
                f"layers analog under {cap} x {self.n_contexts}; predicted "
                f"{self.predicted_s * 1e6:.1f}us/vector (all-digital "
                f"{self.predicted_digital_s * 1e6:.1f}us, "
                f"{self.predicted_digital_s / max(self.predicted_s, 1e-12):.2f}x)")
        if self.rotation is not None:
            line += f"; {self.rotation.summary()}"
        return line


def _packmax(costs, chosen, n_contexts: int, cfg: AimcConfig) -> int:
    """Max per-context tile count of programming ``chosen`` — packed in
    TREE-WALK order (``costs`` order), exactly as `program_model` will."""
    chosen = set(chosen)
    items = [c.item for c in costs if c.path in chosen]
    per = pack_contexts(items, n_contexts, cfg.tile_rows, cfg.tile_cols)
    return max(per) if per else 0


def _feasible_prefix_len(costs, order, budget: int, n_contexts: int,
                         cfg: AimcConfig) -> int:
    """Longest m such that the RUNNING MAX of packmax over prefixes
    1..m fits ``budget``. The running max is nondecreasing in m, so
    feasible prefix lengths are downward-closed and monotone in the
    budget — the monotonicity theorem the property tests pin."""
    h = 0
    m = 0
    for j in range(1, len(order) + 1):
        h = max(h, _packmax(costs, {c.path for c in order[:j]},
                            n_contexts, cfg))
        if h > budget:
            break
        m = j
    return m


def plan_placement(params, plan: MappingPlan | None, cfg: AimcConfig, *,
                   tiles_per_context: int | None, n_contexts: int = 1,
                   sys=HIGH_POWER, p=CALIB, coupling: str = "tight",
                   swap_every: int = 1) -> PlacementResult:
    """Search the analog/digital split under a crossbar budget.

    ``plan`` scopes the CANDIDATE set (which leaves may map at all —
    default `MappingPlan` patterns); the search then decides, per
    candidate, where it actually runs. ``tiles_per_context=None`` is an
    uncapped pool: everything with positive predicted savings goes analog.

    Overflow: when positive-savings candidates do not all fit resident, the
    result carries a `RotationPlan` — the resident prefix is shrunk until
    every rotatable leftover fits alongside it (swap headroom), leftovers
    are grouped greedily (each group + hot fits the cap), and serving
    time-multiplexes the groups, paying CM_INITIALIZE per swap."""
    base_plan = dataclasses.replace(
        plan or MappingPlan(), n_contexts=n_contexts, tiles_per_context=None)
    costs = layer_costs(params, base_plan, cfg, sys, p, coupling)
    order = sorted(costs, key=lambda c: (-c.density, c.path))
    candidates = [c for c in order if c.savings > 0]

    if tiles_per_context is None:
        m_res = len(candidates)
        m_all = len(order)
    else:
        m_res = _feasible_prefix_len(costs, candidates, tiles_per_context,
                                     n_contexts, cfg)
        m_all = _feasible_prefix_len(costs, order, tiles_per_context,
                                     n_contexts, cfg)

    resident = candidates[:m_res]
    resident_set = {c.path for c in resident}
    analog = tuple(c.path for c in costs if c.path in resident_set)
    digital = tuple(c.path for c in costs if c.path not in resident_set)
    leftovers = candidates[m_res:]

    def predicted(chosen):
        chosen = set(chosen)
        return sum(c.t_analog if c.path in chosen else c.t_digital
                   for c in costs)

    predicted_s = predicted(resident_set)
    predicted_digital = predicted(())
    predicted_fit = predicted({c.path for c in order[:m_all]})

    rotation = None
    if leftovers and tiles_per_context is not None:
        rotation = _build_rotation(costs, candidates, m_res,
                                   tiles_per_context, n_contexts, cfg,
                                   swap_every)

    result_plan = MappingPlan.for_names(
        analog, n_contexts=n_contexts, tiles_per_context=tiles_per_context)
    return PlacementResult(
        costs=costs, analog=analog, digital=digital, plan=result_plan,
        n_contexts=n_contexts, tiles_per_context=tiles_per_context,
        predicted_s=predicted_s, predicted_digital_s=predicted_digital,
        predicted_analog_fit_s=predicted_fit,
        overflow=bool(leftovers), rotation=rotation)


def _build_rotation(costs, candidates, m_res: int,
                    budget: int, n_contexts: int, cfg: AimcConfig,
                    swap_every: int) -> RotationPlan:
    """Shrink the hot prefix for swap headroom, then group the rest.

    A candidate that does not fit even alone in an empty pool can never
    rotate in — it stays permanently digital. The hot prefix backs off
    from the resident choice until EVERY rotatable non-hot candidate fits
    beside it; candidates dropped from the prefix while shrinking re-enter
    the rotation pool (they still have positive savings), keeping their
    density rank. At m=0 the pool is exactly the fits-alone set, so the
    condition holds and the loop terminates. Groups then fill greedily in
    density order, each group + hot packing within the cap."""
    def fits_alone(g) -> bool:
        return _packmax(costs, {g.path}, n_contexts, cfg) <= budget

    m = m_res
    while True:
        hot_set = {c.path for c in candidates[:m]}
        pool = [g for g in candidates[m:] if fits_alone(g)]
        if all(_packmax(costs, hot_set | {g.path}, n_contexts, cfg)
               <= budget for g in pool):
            break
        m -= 1
    hot = tuple(c.path for c in costs if c.path in hot_set)
    permanent = tuple(g.path for g in candidates[m:] if not fits_alone(g))

    groups: list[tuple[str, ...]] = []
    cur: list[str] = []
    for g in pool:
        if _packmax(costs, hot_set | set(cur) | {g.path},
                    n_contexts, cfg) <= budget:
            cur.append(g.path)
        else:
            groups.append(tuple(cur))
            cur = [g.path]
    if cur:
        groups.append(tuple(cur))

    return RotationPlan(hot=hot, groups=tuple(groups), digital=permanent,
                        n_contexts=n_contexts, tiles_per_context=budget,
                        swap_every=swap_every)


# ---------------------------------------------------------------------------
# Predicted-vs-measured calibration (the OverlapRoofline idiom)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementRoofline:
    """Affine calibration between modeled and measured per-layer time:

        T_measured(layer) = t_fixed_s + scale * T_modeled(layer)

    The cost model prices an in-order A53-class system, not this host, so
    the absolute scale differs — but if the model RANKS layers correctly
    (what placement decisions need), measured wallclock is affine in the
    modeled time. `fit` recovers both constants by least squares over the
    per-layer (modeled, measured) pairs; `residuals` is what the bench
    gates (|predicted - measured| / measured per layer)."""

    t_fixed_s: float
    scale: float

    @classmethod
    def fit(cls, modeled, measured) -> "PlacementRoofline":
        """Least squares over the basis [1, t_modeled]. Needs >= 2 layers;
        negative constants clamp to 0 (time is not refundable)."""
        if len(modeled) != len(measured) or len(modeled) < 2:
            raise ValueError(
                f"PlacementRoofline.fit needs >= 2 (modeled, measured) "
                f"pairs, got {len(modeled)}/{len(measured)}")
        a_mat = np.array([[1.0, t] for t in modeled])
        y = np.array(list(measured))
        (fixed, scale), *_ = np.linalg.lstsq(a_mat, y, rcond=None)
        return cls(t_fixed_s=max(float(fixed), 0.0),
                   scale=max(float(scale), 0.0))

    def predict_s(self, modeled: float) -> float:
        return self.t_fixed_s + self.scale * modeled

    def residuals(self, modeled, measured):
        """Per-layer relative |predicted - measured| / measured."""
        return [abs(self.predict_s(tm) - tw) / tw
                for tm, tw in zip(modeled, measured)]
