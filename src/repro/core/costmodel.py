"""Analytical full-system performance/energy model (the gem5-X role).

The paper characterizes whole applications — accelerated MVMs *plus* input
load, queue/dequeue, activation functions, core-to-core communication, cache
working-set effects — on two calibrated system models (paper Table I). This
module reimplements that characterization analytically:

  * `SystemConfig`   — Table I-(A)/(B): clocks, cache sizes, pJ/cycle figures.
  * `AimcTileSpec`   — Table I-(C): 100 ns CM_PROCESS, 4 GB/s tile SRAM I/O,
    12.8 TOp/s/W at 256x256 (re-scaled for tile size: crossbar + converters),
    power upscaling 5.3x / 2x to the 28 nm core node.
  * `CalibratedParams` — effective-throughput constants playing the role gem5's
    microarchitecture played. Four of them are *calibrated* against the paper's
    own headline results (see benchmarks/calibration notes in EXPERIMENTS.md);
    the rest are textbook in-order-A53 figures.
  * `evaluate()`     — timing + energy for a `Workload` (per-core stages of
    MVM / element-wise / load / store / comm ops), digital or AIMC-mapped,
    tight- or loose-coupled.

Execution-model notes derived from the paper's measurements:

  * CM_QUEUE/CM_DEQUEUE are *instruction-issue bound*, not 4 GB/s-bound: 4
    bytes move per instruction, and each custom instruction performs a
    CPU->tile transaction costing tens of cycles on the in-order pipeline.
    This is why "analog queue" is ~40% of the MLP run time (paper Fig. 8)
    even though 1 KB at 4 GB/s would take only 0.26 us, and why the paper
    stresses that queue/dequeue bandwidth is THE critical parameter (§VII-B).
  * The MLP/LSTM cases process a single inference stream with a sequential
    cross-core dependency chain (mutex hand-off), so multi-core mappings pay
    the full communication latency per inference (paper: MLP case 3/4 are
    20%/30% *slower* than single-core). `pipelined=False` sums stages.
  * The CNN applies fine-grained (position-level) pipelining across cores
    (paper §IX-A), so its per-inference time is the max stage time.
    `pipelined=True` takes the max.
  * The MinorCPU is in-order: compute, tile-I/O and memory-stall components
    add up within a stage (no overlap).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

from repro.core import isa

# ---------------------------------------------------------------------------
# Table I — system configurations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    freq_hz: float
    n_cores: int
    l1_bytes: int
    llc_bytes: int
    pj_idle: float           # per cycle
    pj_wfm: float            # per cycle (wait-for-memory)
    pj_active: float         # per cycle
    mem_io_power_w: float
    llc_leak_mw_per_256kb: float
    llc_read_pj_byte: float
    llc_write_pj_byte: float
    dram_pj_access: float    # per 64B access
    aimc_power_scale: float  # 14nm -> 28nm upscale (paper §VI-B)


HIGH_POWER = SystemConfig(
    name="high-power", freq_hz=2.3e9, n_cores=8,
    l1_bytes=64 * 1024, llc_bytes=1024 * 1024,
    pj_idle=126.03, pj_wfm=638.99, pj_active=845.39,
    mem_io_power_w=5.82, llc_leak_mw_per_256kb=874.08,
    llc_read_pj_byte=5.60, llc_write_pj_byte=5.02,
    dram_pj_access=120.0, aimc_power_scale=5.3,
)

LOW_POWER = SystemConfig(
    name="low-power", freq_hz=0.8e9, n_cores=8,
    l1_bytes=32 * 1024, llc_bytes=512 * 1024,
    pj_idle=10.72, pj_wfm=46.04, pj_active=60.92,
    mem_io_power_w=3.03, llc_leak_mw_per_256kb=271.62,
    llc_read_pj_byte=1.81, llc_write_pj_byte=1.63,
    dram_pj_access=120.0, aimc_power_scale=2.0,
)


@dataclasses.dataclass(frozen=True)
class AimcTileSpec:
    latency_s: float = 100e-9          # CM_PROCESS
    io_bw: float = 4e9                 # tile SRAM queue/dequeue, bytes/s
    tops_per_w_256: float = 12.8       # MVM efficiency at 256x256
    converter_energy_frac: float = 0.5 # share of tile energy in DAC/ADC

    def mvm_energy_j(self, k: int, n: int, scale: float) -> float:
        """Energy of one CM_PROCESS on a k x n tile region (paper: efficiency
        re-calculated for tile size: crossbar ~ k*n, converters ~ k + n)."""
        e_256 = (2 * 256 * 256) / (self.tops_per_w_256 * 1e12)
        e_xbar = e_256 * (1 - self.converter_energy_frac) * (k * n) / (256 * 256)
        e_conv = e_256 * self.converter_energy_frac * (k + n) / (256 + 256)
        return (e_xbar + e_conv) * scale


AIMC_TILE = AimcTileSpec()


def _default_elem_cycles():
    return {
        "relu": 1.0, "add": 1.0, "mul": 1.0, "copy": 0.5,
        "sigmoid": 33.0, "tanh": 33.0, "softmax": 40.0, "exp": 20.0,
        "maxpool": 3.0, "lrn": 10.0,
    }


@dataclasses.dataclass(frozen=True)
class CalibratedParams:
    """Microarchitectural effective-throughput constants.

    CALIBRATED against the paper's own results (provenance in EXPERIMENTS.md
    §Paper-calibration): `simd_macs_per_cycle`, `conv_macs_per_cycle`,
    `cm_queue_cycles`, `load_cycles_per_byte`, `loose_word_cycles`.
    All others are standard in-order Cortex-A53-class figures.
    """

    # dense/gemv int8 SIMD efficiency (NEON peak 16/cyc; Eigen gemv on an
    # in-order core achieves ~6 effective).
    simd_macs_per_cycle: float = 6.0
    # direct convolution efficiency (batch-1 edge inference: index arithmetic
    # + strided loads dominate; calibrated to the paper's CNN-S 20.5x).
    conv_macs_per_cycle: float = 0.44
    # custom-instruction issue cost: one CPU->tile transaction each.
    cm_queue_cycles: float = 90.0
    cm_dequeue_cycles: float = 45.0
    # input marshalling: load + int8 pack into argument registers.
    load_cycles_per_byte: float = 34.0
    store_cycles_per_byte: float = 8.0
    elem_cycles: dict = dataclasses.field(default_factory=_default_elem_cycles)
    llc_bytes_per_cycle: float = 8.0       # L1<->LLC fill path
    dram_bw_eff: float = 2.6e9             # 16-bit DDR4-2400, effective
    sync_s: float = 6.0e-6                 # mutex + futex wake per hand-off
    comm_cycles_per_byte: float = 12.0     # remote-line read + repack
    loose_word_cycles: float = 240.0       # extra I/O-bus cost per 32b word


CALIB = CalibratedParams()


# ---------------------------------------------------------------------------
# Workload IR
# ---------------------------------------------------------------------------

OpKind = Literal["mvm", "elemwise", "load", "store", "comm"]


@dataclasses.dataclass(frozen=True)
class Op:
    kind: OpKind
    # mvm
    k: int = 0
    n: int = 0
    count: int = 1            # e.g. conv output positions re-using the kernel
    aimc: bool = False
    conv: bool = False        # direct-conv (vs gemv) digital efficiency class
    # fused epilogue (aimc mvm only): activation applied inside the
    # CM_DEQUEUE loop instead of as a separate elemwise pass (kernel v2's
    # fused-epilogue contract in cost-model terms). "" = none.
    epilogue: str = ""
    # elemwise
    fn: str = "relu"
    elems: int = 0
    # load/store/comm
    bytes: int = 0


@dataclasses.dataclass(frozen=True)
class Stage:
    """Work mapped to one CPU core (plus its private AIMC tile, if any)."""
    ops: tuple[Op, ...]
    weights_bytes: int = 0    # digital weights this stage streams per inference
    act_bytes: int = 0        # activations this stage touches per inference


@dataclasses.dataclass(frozen=True)
class Workload:
    """``phases`` is a tuple of phases; each phase is a tuple of stages that
    run in PARALLEL on different cores (e.g. the two column-halves of an MLP
    layer in case 4). Phases execute sequentially for single-stream inference
    (MLP/LSTM: per-inference time = sum over phases of max-in-phase), unless
    ``pipelined`` (CNN fine-grained pipelining: max over every stage)."""

    name: str
    phases: tuple[tuple[Stage, ...], ...]
    pipelined: bool = False
    coupling: Literal["tight", "loose"] = "tight"
    tile_rows: int = 1024     # AIMC crossbar word lines (per-case, paper Fig. 6/9)

    @property
    def stages(self) -> tuple[Stage, ...]:
        return tuple(s for phase in self.phases for s in phase)


@dataclasses.dataclass(frozen=True)
class Result:
    time_s: float             # per inference
    energy_j: float           # per inference
    llc_mpi: float            # LLC-misses-per-instruction proxy
    breakdown: dict           # sub-ROI time shares (paper Fig. 8 / Fig. 11 style)
    stage_times: tuple
    dram_bytes: float = 0.0   # DRAM traffic per inference (memory intensity)


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------


def aimc_mvm_time(counts: isa.CmCounts, sys: SystemConfig,
                  p: CalibratedParams = CALIB,
                  coupling: str = "tight") -> tuple[float, float, float]:
    """(t_queue, t_process, t_dequeue) for a CM_* instruction ledger.

    THE shared accounting: `evaluate()` prices every AIMC mvm op through
    this function, and `core.schedule` prices its per-core `CoreLedger`s
    through the same one — so a scheduled multi-core mapping and the
    analytical `Workload` of the same case can never drift apart. Queue and
    dequeue are each the max of the bandwidth view (tile SRAM I/O, Table I-C)
    and the instruction-issue view (custom-instruction cost per 32-bit word,
    the paper's actual bottleneck — §VII-B); loose coupling adds the I/O-bus
    transaction cost per word on top.
    """
    f = sys.freq_hz
    t_q = max(counts.queue_bytes / AIMC_TILE.io_bw,
              counts.queue * p.cm_queue_cycles / f)
    t_d = max(counts.dequeue_bytes / AIMC_TILE.io_bw,
              counts.dequeue * p.cm_dequeue_cycles / f)
    if coupling == "loose":
        t_q += counts.queue * p.loose_word_cycles / f
        t_d += counts.dequeue * p.loose_word_cycles / f
    t_p = counts.process * AIMC_TILE.latency_s
    return t_q, t_p, t_d


def fused_epilogue_time(elems: int, fn: str, dequeue_count: int,
                        sys: SystemConfig, p: CalibratedParams = CALIB) -> float:
    """Visible time of an activation folded into the CM_DEQUEUE loop.

    An unfused epilogue is a separate elemwise pass (a plain `Op(elemwise)`).
    Fused, the ALU work interleaves with the dequeue's CPU->tile
    transactions: the in-order core can hide up to `cm_dequeue_cycles` of
    arithmetic behind each transaction's latency, so only the excess shows.
    Cheap epilogues (relu at 1 cycle/elem, 4 elems/word vs a 45-cycle
    transaction) vanish entirely; transcendentals (sigmoid/tanh at 33
    cycles/elem) overflow the bubble and pay the remainder. THE shared
    accounting — `evaluate()` and `core.schedule.shard_time` both price
    fused epilogues through this one function.
    """
    cycles = elems * p.elem_cycles[fn]
    hidden = dequeue_count * p.cm_dequeue_cycles
    return max(0.0, cycles - hidden) / sys.freq_hz


def _stage_time(stage: Stage, sys: SystemConfig, p: CalibratedParams,
                coupling: str, tile_rows: int):
    """Returns (time_s, breakdown, aimc_energy_j, stall_s, instr_count)."""
    f = sys.freq_hz
    t_total = 0.0
    e_aimc = 0.0
    instrs = 0.0
    bd = {"mvm_digital": 0.0, "analog_queue": 0.0, "analog_process": 0.0,
          "analog_dequeue": 0.0, "digital_ops": 0.0, "input_load": 0.0,
          "output_store": 0.0, "comm": 0.0, "mem_stall": 0.0}

    for op in stage.ops:
        if op.kind == "mvm" and not op.aimc:
            eff = p.conv_macs_per_cycle if op.conv else p.simd_macs_per_cycle
            t = op.count * (op.k * op.n) / (eff * f)
            bd["mvm_digital"] += t
            instrs += op.count * op.k * op.n / 16
            t_total += t
        elif op.kind == "mvm" and op.aimc:
            counts = isa.mvm_counts(op.k, op.n, tile_rows)
            t_q, t_p, t_d = aimc_mvm_time(counts, sys, p, coupling)
            t_q, t_d, t_p = t_q * op.count, t_d * op.count, t_p * op.count
            if op.epilogue:
                t_d += fused_epilogue_time(op.count * op.n, op.epilogue,
                                           op.count * counts.dequeue, sys, p)
            bd["analog_queue"] += t_q
            bd["analog_dequeue"] += t_d
            bd["analog_process"] += t_p
            instrs += op.count * (counts.queue + counts.dequeue)
            e_aimc += op.count * AIMC_TILE.mvm_energy_j(
                min(op.k, tile_rows) * counts.process, op.n,
                sys.aimc_power_scale)
            t_total += t_q + t_d + t_p
        elif op.kind == "elemwise":
            t = op.elems * p.elem_cycles[op.fn] / f
            bd["digital_ops"] += t
            instrs += op.elems * p.elem_cycles[op.fn]
            t_total += t
        elif op.kind == "load":
            t = op.bytes * p.load_cycles_per_byte / f
            bd["input_load"] += t
            instrs += op.bytes * 1.5
            t_total += t
        elif op.kind == "store":
            t = op.bytes * p.store_cycles_per_byte / f
            bd["output_store"] += t
            instrs += op.bytes * 1.5
            t_total += t
        elif op.kind == "comm":
            t = p.sync_s + op.bytes * p.comm_cycles_per_byte / f
            bd["comm"] += t
            t_total += t

    # Working-set memory stalls: digital weights that exceed the cache levels
    # are re-streamed every inference (paper §VII-E working-set analysis).
    ws = stage.weights_bytes + stage.act_bytes
    stall = 0.0
    if stage.weights_bytes > 0:
        if ws > sys.llc_bytes:
            spill = min(1.0, (ws - sys.llc_bytes) / max(ws, 1))
            stall += stage.weights_bytes * spill / p.dram_bw_eff
            stall += stage.weights_bytes * (1 - spill) / (p.llc_bytes_per_cycle * f)
        elif ws > sys.l1_bytes:
            stall += stage.weights_bytes / (p.llc_bytes_per_cycle * f)
    bd["mem_stall"] = stall
    t_total += stall

    return t_total, bd, e_aimc, stall, instrs


def evaluate(w: Workload, sys: SystemConfig, p: CalibratedParams = CALIB) -> Result:
    per_stage = [_stage_time(s, sys, p, w.coupling, w.tile_rows) for s in w.stages]
    times = [t for (t, *_rest) in per_stage]
    if w.pipelined and len(times) > 1:
        t_inf = max(times)
    else:
        t_inf, i = 0.0, 0
        for phase in w.phases:
            t_inf += max(times[i: i + len(phase)]) if phase else 0.0
            i += len(phase)

    bd_total: dict[str, float] = {}
    for (_t, bd, _e, _stall, _i) in per_stage:
        for key, v in bd.items():
            bd_total[key] = bd_total.get(key, 0.0) + v

    # ---- energy -------------------------------------------------------------
    f = sys.freq_hz
    e = 0.0
    dram_bytes = 0.0
    llc_traffic = 0.0
    total_instrs = 0.0
    for (t_stage, _bd, e_aimc, stall, instrs) in per_stage:
        busy = max(0.0, t_stage - stall)
        e += busy * f * sys.pj_active * 1e-12
        e += stall * f * sys.pj_wfm * 1e-12
        e += max(0.0, t_inf - t_stage) * f * sys.pj_idle * 1e-12
        e += e_aimc
        total_instrs += instrs
    idle_cores = max(0, sys.n_cores - len(per_stage))
    e += idle_cores * t_inf * f * sys.pj_idle * 1e-12

    for s in w.stages:
        ws = s.weights_bytes + s.act_bytes
        if s.weights_bytes and ws > sys.llc_bytes:
            spill = min(1.0, (ws - sys.llc_bytes) / max(ws, 1))
            dram_bytes += s.weights_bytes * spill
            # digital direct conv re-streams its kernel weights once per
            # output ROW (weights far exceed L1); LLC-spilled fractions of
            # that traffic hit DRAM — the cache-thrashing the paper's
            # memory-intensity metric captures (§IX-B).
            for op in s.ops:
                if op.kind == "mvm" and op.conv and not op.aimc:
                    rows = max(int(math.sqrt(op.count)) - 1, 0)
                    dram_bytes += op.k * op.n * rows * spill
        llc_traffic += s.weights_bytes + 2 * s.act_bytes

    e += (dram_bytes / 64.0) * sys.dram_pj_access * 1e-12
    e += llc_traffic * sys.llc_read_pj_byte * 1e-12
    e += sys.mem_io_power_w * t_inf
    e += (sys.llc_leak_mw_per_256kb * 1e-3) * (sys.llc_bytes / (256 * 1024)) * t_inf

    mpi = (dram_bytes / 64.0) / max(total_instrs, 1.0)
    return Result(time_s=t_inf, energy_j=e, llc_mpi=mpi,
                  breakdown=bd_total, stage_times=tuple(times),
                  dram_bytes=dram_bytes)


def speedup(digital: Result, analog: Result) -> tuple[float, float]:
    """(perf gain, energy gain) of analog over digital — the paper's headline."""
    return digital.time_s / analog.time_s, digital.energy_j / analog.energy_j


# ---------------------------------------------------------------------------
# Per-layer stage builders (core.placement's pricing substrate)
# ---------------------------------------------------------------------------

def digital_mvm_stage(k: int, n: int, count: int = 1,
                      conv: bool = False) -> Stage:
    """One layer's digital MVM as a single-op stage: SIMD gemv time plus
    the working-set stall of streaming its float32 weights every
    inference. ``count`` is the instance multiplicity (stacked layers /
    experts), each firing once per token vector."""
    return Stage(ops=(Op(kind="mvm", k=k, n=n, count=count, conv=conv),),
                 weights_bytes=count * k * n * 4)


def analog_mvm_stage(k: int, n: int, count: int = 1,
                     epilogue: str = "") -> Stage:
    """One layer's AIMC MVM as a single-op stage: queue/process/dequeue
    traffic priced through `aimc_mvm_time` — weights are stationary on the
    crossbar, so no working-set bytes."""
    return Stage(ops=(Op(kind="mvm", k=k, n=n, count=count, aimc=True,
                         epilogue=epilogue),))


def split_workload(name: str, layers, analog, tile_rows: int = 1024,
                   coupling: str = "tight") -> Workload:
    """A sequential Workload for a mixed analog/digital layer split.

    ``layers`` is ``(path, k, n, instances)`` per layer in execution order;
    ``analog`` the set of paths mapped to crossbars. Each layer becomes its
    OWN one-stage phase, so `evaluate()`'s sequential law (sum over phases
    of max-in-phase) degenerates to the exact per-layer sum — the identity
    `core.placement` relies on: the placer's per-layer time sums equal the
    full-model evaluation at ratio 1.000 by construction (gated in
    benchmarks/bench_placement.py)."""
    analog = set(analog)
    phases = []
    for path, k, n, instances in layers:
        stage = (analog_mvm_stage(k, n, instances) if path in analog
                 else digital_mvm_stage(k, n, instances))
        phases.append((stage,))
    return Workload(name=name, phases=tuple(phases), pipelined=False,
                    coupling=coupling, tile_rows=tile_rows)
