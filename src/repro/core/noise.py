"""PCM non-ideality models (paper §III-C).

Three effects, each independently switchable and fully deterministic given a
PRNG key (so training/eval/checkpoint-restart are bit-exact):

  * programming noise  — write error when a conductance target is programmed
    (CM_INITIALIZE). Gaussian in conductance (= int8 code) units with a
    level-dependent sigma: sigma(w) = sigma_prog_min + (sigma_prog_max -
    sigma_prog_min) * |w|/127, following the level dependence measured in
    Joshi et al. (Nat. Comm. 2020) / Nandakumar et al. (IEDM 2020).
  * read noise         — instantaneous 1/f + thermal noise on each analog MVM
    (CM_PROCESS). Modelled as additive Gaussian on the bit-line accumulation
    with std sigma_read * 127 * sqrt(M_active_rows) LSBs.
  * conductance drift  — G(t) = G(t0) * (t/t0)^(-nu). A deterministic,
    multiplicative decay (nu ~ 0.05 for doped-Ge2Sb2Te5 PCM) plus optional
    digital drift compensation (a single scalar gain (t/t0)^{+nu} applied to
    the ADC output — "global drift compensation" in the PCM literature).

All sigmas are expressed as fractions of the full-scale code (127), so they are
directly comparable to the 8-bit precision they perturb.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.quant import QMAX


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """PCM non-ideality parameters. Defaults follow the PCM literature the

    paper builds on ([13], [16], [30], [31])."""

    enabled: bool = True
    # programming (write) noise, fraction of full scale, level-dependent.
    sigma_prog_min: float = 0.010
    sigma_prog_max: float = 0.025
    # per-MVM read noise, fraction of full scale per sqrt(active row).
    sigma_read: float = 0.005
    # conductance drift exponent and elapsed/reference time ratio.
    drift_nu: float = 0.05
    drift_t_ratio: float = 1.0  # t/t0; 1.0 = freshly programmed (no drift)
    drift_compensate: bool = True
    # time-dependent drift: reference time t0 (seconds after programming at
    # which the decay clock starts) and per-core exponent spread (fraction of
    # drift_nu; cores in a cluster do not drift identically).
    drift_t0: float = 1.0
    drift_core_spread: float = 0.0

    def drift_gain(self) -> float:
        if self.drift_t_ratio <= 1.0:
            return 1.0
        return float(self.drift_t_ratio ** (-self.drift_nu))

    def compensation_gain(self) -> float:
        return 1.0 / self.drift_gain() if self.drift_compensate else 1.0

    def compensation_gain_at(self, t_since_program: float,
                             nu: float | None = None) -> float:
        """Digital dequant-scale correction for a program of age
        ``t_since_program`` — the inverse of the NOMINAL power law.

        Global drift compensation in the PCM literature is a single scalar
        (t/t0)^{+nu} folded into the ADC dequant scale; the compensator
        knows only the nominal exponent, NOT each core's actual one, so
        with `drift_core_spread > 0` the cancellation is approximate (the
        residual is exactly what the health probes measure). Static
        `compensation_gain` is the t-ratio snapshot of this law; serving
        uses this age-based form between recals (satellite: the static
        gain never tracked program age)."""
        if not (self.enabled and self.drift_compensate):
            return 1.0
        g = self.drift_gain_at(t_since_program, nu)
        return 1.0 / g if g > 0.0 else 1.0

    def drift_gain_at(self, t_since_program: float, nu: float | None = None) -> float:
        """G(t)/G(t0) for a program of age `t_since_program` seconds.

        The power law G(t) = G(t0) * (t/t0)^(-nu) with t0 = `drift_t0`;
        ages at or below t0 (including a negative clock skew) are "fresh"
        and decay-free. `nu` overrides the global exponent — pass
        `per_core_nu(core)` to model per-core variation."""
        if not self.enabled:
            return 1.0
        nu = self.drift_nu if nu is None else nu
        ratio = t_since_program / self.drift_t0
        if ratio <= 1.0 or nu == 0.0:
            return 1.0
        return float(ratio ** (-nu))

    def per_core_nu(self, core: int, seed: int = 0) -> float:
        """Deterministic per-core drift exponent: nu * (1 + spread * u),
        u in [-1, 1) hashed from (seed, core). spread=0 -> the global nu."""
        if self.drift_core_spread == 0.0:
            return self.drift_nu
        u = 2.0 * unit_hash(seed, core) - 1.0
        return self.drift_nu * (1.0 + self.drift_core_spread * u)


DISABLED = NoiseModel(enabled=False)


def drift_only(nu: float = 0.05, t0: float = 1.0,
               core_spread: float = 0.0,
               compensate: bool = False) -> NoiseModel:
    """A NoiseModel that drifts with program age but is otherwise ideal.

    Programming/read noise are zeroed and compensation defaults off, so a
    serving stack built on this model stays bit-deterministic: the ONLY
    time-varying effect is the multiplicative power-law decay
    `drift_gain_at`. This is the model the drift-aware serve loop
    (runtime.health) evolves online. ``compensate=True`` turns on the
    age-based dequant correction (`compensation_gain_at`) — still
    deterministic; with ``core_spread == 0`` it cancels the decay
    exactly."""
    return NoiseModel(enabled=True, sigma_prog_min=0.0, sigma_prog_max=0.0,
                      sigma_read=0.0, drift_nu=nu, drift_t_ratio=1.0,
                      drift_compensate=compensate, drift_t0=t0,
                      drift_core_spread=core_spread)


_MASK64 = (1 << 64) - 1


def unit_hash(*ints: int) -> float:
    """Deterministic hash of integers to [0, 1) — splitmix64 finalizer.

    Pure python (no PRNG state, no jax), so per-core variation and backoff
    jitter are reproducible across processes and platforms."""
    h = 0x9E3779B97F4A7C15
    for v in ints:
        h = (h ^ (int(v) & _MASK64)) & _MASK64
        h = (h + 0x9E3779B97F4A7C15) & _MASK64
        h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & _MASK64
        h = h ^ (h >> 31)
    return h / float(1 << 64)


def programming_noise(key: jax.Array, w_codes: jnp.ndarray, nm: NoiseModel) -> jnp.ndarray:
    """Additive write-error on integer conductance codes (float, caller rounds)."""
    if not nm.enabled:
        return jnp.zeros_like(w_codes, dtype=jnp.float32)
    level = jnp.abs(w_codes.astype(jnp.float32)) / QMAX
    sigma = (nm.sigma_prog_min + (nm.sigma_prog_max - nm.sigma_prog_min) * level) * QMAX
    return sigma * jax.random.normal(key, w_codes.shape, dtype=jnp.float32)


def read_noise(key: jax.Array, shape, active_rows: int, nm: NoiseModel) -> jnp.ndarray:
    """Additive bit-line noise (int32-accumulator LSB units) for one CM_PROCESS.

    Bulk-array form (jax.random). The execution path no longer materializes
    this tensor: kernel v2 draws the same-distribution noise in-kernel from
    a scalar seed (`derive_read_seed` + `read_sigma_lsb`); this function
    remains for the noise-model unit tests and off-path analysis."""
    if not nm.enabled or nm.sigma_read == 0.0:
        return jnp.zeros(shape, dtype=jnp.float32)
    sigma = read_sigma_lsb(active_rows, nm)
    return sigma * jax.random.normal(key, shape, dtype=jnp.float32)


def read_sigma_lsb(active_rows: int, nm: NoiseModel) -> float:
    """Read-noise std in accumulator LSBs for an `active_rows`-row tile —
    the STATIC scale kernel v2 bakes into the compiled kernel (0.0 compiles
    the noise code out)."""
    if not nm.enabled:
        return 0.0
    return float(nm.sigma_read * QMAX * (active_rows ** 0.5))


def derive_read_seed(key: jax.Array) -> jnp.ndarray:
    """Collapse a JAX PRNG key to the scalar uint32 seed kernel v2 prefetches.

    One `jax.random.bits` draw — deterministic per key, so programs/tests
    that fold or split keys per call/layer/shard get decorrelated streams
    exactly as they did with materialized `jax.random.normal` noise. The
    per-element expansion from this scalar is `kernels.cprng` (counter mode)
    or the TPU hardware PRNG (`noise_source="hw"`)."""
    return jax.random.bits(key, dtype=jnp.uint32)


def apply_drift(w_analog: jnp.ndarray, nm: NoiseModel) -> jnp.ndarray:
    """Deterministic conductance decay applied to programmed (noisy) codes."""
    return w_analog * nm.drift_gain()
