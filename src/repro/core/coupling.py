"""Tight vs loose AIMC coupling as executable JAX (paper §IV-A, §VII-B).

The paper's distinction — custom-instruction access to a private tile vs
memory-mapped I/O-bus transactions — maps onto TPU as a *fusion* distinction:

  * tight  — ONE fused kernel (or one fused jit region): DAC quantization,
    crossbar MAC, read noise, ADC and digital accumulation share VMEM; no
    analog-domain intermediate touches HBM.
  * loose  — every pipeline stage is materialized to HBM before the next
    starts (`optimization_barrier` between stages), mirroring each value
    crossing the I/O bus: x -> x_q -> per-block int32 accumulations ->
    ADC codes -> dequantized output.

`benchmarks/bench_coupling.py` lowers both and compares HBM bytes from
`cost_analysis()` — the TPU version of the paper's 3.1x tight-vs-loose gap —
while the analytical model covers the paper's own ARM-side numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aimc import AimcConfig, AimcLinearState
from repro.core.quant import adc_quantize, quantize, sym_scale
from repro.kernels import ops as kernel_ops


def tight_forward(state: AimcLinearState, x: jnp.ndarray, cfg: AimcConfig) -> jnp.ndarray:
    """Fused execution (the default production path): kernel v2, no noise
    operand (noise, when enabled, is drawn in-kernel from a scalar seed)."""
    kb, m, np_ = state.w_q.shape
    xf = x.astype(jnp.float32)
    if xf.shape[1] != kb * m:
        xf = jnp.pad(xf, ((0, 0), (0, kb * m - xf.shape[1])))
    s_x = sym_scale(xf).reshape(1, 1)
    y = kernel_ops.aimc_matmul_v2(xf, state.w_q, state.s_w, s_x,
                                  adc_step=cfg.adc_step, impl=cfg.impl)
    return y[:, : state.n]


def loose_forward(state: AimcLinearState, x: jnp.ndarray, cfg: AimcConfig) -> jnp.ndarray:
    """Staged execution with an HBM round-trip between every stage."""
    barrier = jax.lax.optimization_barrier
    kb, m, np_ = state.w_q.shape
    b = x.shape[0]
    xf = x.astype(jnp.float32)
    if xf.shape[1] != kb * m:
        xf = jnp.pad(xf, ((0, 0), (0, kb * m - xf.shape[1])))

    # stage 1: DAC quantization (CPU -> bus -> tile input memory)
    s_x = sym_scale(xf).reshape(1, 1)
    x_q = barrier(quantize(xf.reshape(b, kb, m), s_x.reshape(())))
    # stage 2: crossbar MAC per row block (tile-internal, result over the bus)
    acc = barrier(jnp.einsum("bkm,kmn->kbn", x_q.astype(jnp.int32),
                             state.w_q.astype(jnp.int32)).astype(jnp.float32))
    # stage 3: ADC quantization (tile output memory -> bus)
    codes = barrier(adc_quantize(acc, jnp.float32(cfg.adc_step)))
    # stage 4: digital dequant + row-block accumulation (CPU side)
    contrib = codes.astype(jnp.float32) * state.s_w[:, None, :]
    y = jnp.sum(contrib, axis=0) * (jnp.float32(cfg.adc_step) * s_x.reshape(()))
    return y[:, : state.n]


# ---------------------------------------------------------------------------
# HBM traffic accounting (the quantitative tight-vs-loose gap on TPU)
# ---------------------------------------------------------------------------

def hbm_noise_bytes(state: AimcLinearState, batch: int, *,
                    noise_streamed: bool = False) -> int:
    """HBM bytes the noise path costs per call: the v1 `[KB, B, Np]` f32
    operand when streamed, the 4-byte scalar-prefetched seed under kernel
    v2's in-kernel PRNG."""
    kb, m, np_ = state.w_q.shape[-3:]
    return kb * batch * np_ * 4 if noise_streamed else 4


def hbm_epilogue_bytes(state: AimcLinearState, batch: int, *,
                       epilogue_fused: bool = True) -> int:
    """HBM bytes of the layer epilogue (bias + activation): zero when fused
    into the kernel's last row-block step (kernel v2), one full read + write
    of the f32 output when it runs as a separate XLA op."""
    np_ = state.w_q.shape[-1]
    return 0 if epilogue_fused else 2 * batch * np_ * 4


def hbm_bytes_tight(state: AimcLinearState, batch: int,
                    block_b: int = 128, block_n: int = 512, *,
                    noise_streamed: bool = False,
                    epilogue_fused: bool = True) -> int:
    """HBM bytes of ONE fused-kernel call, from the BlockSpecs of
    kernels/aimc_mvm.py.

    Grid (B/bb, Np/bn, KB), row blocks innermost: the f32 output block is
    revisited consecutively (stays in VMEM), the x block re-streams once per
    column tile, the int8 weight panel once per batch tile. No analog-domain
    intermediate (x_q, bit-line accumulations, ADC codes) ever leaves VMEM —
    that is the kernel-fusion translation of the paper's tight coupling.

    Defaults model kernel v2: no noise operand (a 4-byte seed instead of the
    v1 `[KB, B, Np]` f32 stream) and the epilogue fused into the last grid
    step. `noise_streamed=True` / `epilogue_fused=False` reproduce the v1
    accounting for before/after tables.
    """
    kb, m, np_ = state.w_q.shape
    bb, bn = min(block_b, batch), min(block_n, np_)
    x = batch * kb * m * 4 * (np_ // bn)          # x f32, per column tile
    w = kb * m * np_ * 1 * (batch // bb or 1)     # int8 weights, per batch tile
    out = batch * np_ * 4                         # written once (VMEM-resident)
    scales = kb * np_ * 4 + 4
    return (x + w + out + scales
            + hbm_noise_bytes(state, batch, noise_streamed=noise_streamed)
            + hbm_epilogue_bytes(state, batch, epilogue_fused=epilogue_fused))


def hbm_bytes_loose(state: AimcLinearState, batch: int,
                    block_b: int = 128, block_n: int = 512) -> int:
    """HBM bytes of the staged execution: every pipeline stage materializes
    its result (x_q int8, bit-line int32 accumulations, ADC int32 codes) to
    HBM and the next stage reads it back — the TPU mirror of each value
    crossing the paper's I/O bus. Staging implies the v1 noise stream and an
    unfused epilogue."""
    kb, m, np_ = state.w_q.shape
    base = hbm_bytes_tight(state, batch, block_b, block_n,
                           noise_streamed=True, epilogue_fused=False)
    x_q = batch * kb * m * 1
    acc = kb * batch * np_ * 4
    codes = kb * batch * np_ * 4
    # write + read-back for each staged intermediate
    return base + 2 * (x_q + acc + codes)
