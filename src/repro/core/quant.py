"""DAC/ADC quantization math for AIMC crossbar simulation.

This module is the single source of truth for the fixed-point arithmetic of the
simulated AIMC tile (paper §III-B):

  * DAC: signed 8-bit input quantization. The input scaling factor is either
    computed per-call ("dynamic", max-abs) or fixed ("static") as the paper
    recommends ("preferably fixed to avoid dynamic scaling").
  * Crossbar: int8 x int8 -> int32 exact MAC (the analog dot product, modelled
    noiselessly here; noise lives in `core.noise`).
  * ADC: signed 8-bit output quantization with a per-tile output step sized to
    the statistical (not worst-case) bit-line range, `adc_alpha * sqrt(M) * 127`
    accumulator LSBs for an M-row tile.

All functions are pure jnp and are safe to call inside Pallas kernel bodies,
so the Pallas kernel (`kernels/aimc_mvm.py`) and the oracle (`kernels/ref.py`)
share literally the same arithmetic.
"""

from __future__ import annotations

import jax.numpy as jnp

# Signed 8-bit converters (paper: "The resolution of DACs and ADCs are signed
# 8-bits"). We use the symmetric range [-127, 127] so that a weight and its
# negation program to exactly opposite conductance pairs.
QMAX = 127
QMIN = -127


def sym_scale(x: jnp.ndarray, axis=None, eps: float = 1e-12) -> jnp.ndarray:
    """Symmetric max-abs quantization scale so x/scale fits in [-127, 127]."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps) / QMAX


def quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Round-to-nearest signed-8-bit quantization (returns int8)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return q.astype(dtype) * scale.astype(dtype)


def adc_step_lsb(tile_rows: int, adc_alpha: float) -> float:
    """ADC quantization step, in int32-accumulator LSBs.

    The bit line of an M-row tile accumulates up to M*127*127 LSBs worst case,
    but activations concentrate, so real designs size the ADC full scale to the
    statistical range ~ sqrt(M) * 127 * 127 (cf. HERMES [13]). With an 8-bit
    ADC (127 positive codes) the step is alpha * sqrt(M) * 127 LSBs.
    """
    return float(max(1.0, adc_alpha * (tile_rows ** 0.5) * QMAX))


def quantize_weight_int8(w: jnp.ndarray):
    """Per-output-channel symmetric int8 quantization of a [..., K, N] weight.

    Returns {"q": int8 codes, "s": f32 scales [..., 1, N]} — the paper's
    number format for serving (`Execution.serve_int8`), consumed by
    `models.layers.as_weight`."""
    s = sym_scale(w.astype(jnp.float32), axis=-2)          # [..., 1, N]
    return {"q": quantize(w.astype(jnp.float32), s), "s": s}


def quantize_params_int8(params, quantizable: set[str], skip=("embed",)):
    """Tree-wide int8 packing of the projection matrices named in
    `quantizable` (see launch.shardings name sets); other leaves cast to
    bf16. Mirrors launch.steps._serve_params_shape."""
    import jax

    def conv(path, leaf):
        name = ""
        for k in reversed(path):
            if hasattr(k, "key"):
                name = str(k.key)
                break
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        if name in quantizable and name not in skip and leaf.ndim >= 2:
            return quantize_weight_int8(leaf)
        return leaf.astype(jnp.bfloat16)

    return jax.tree_util.tree_map_with_path(conv, params)


def adc_quantize(acc: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """Quantize an int32 (or float) bit-line accumulation to signed 8-bit codes.

    Returns int32 codes in [-127, 127] (int32 so downstream digital accumulation
    of multiple row-block tiles does not overflow).
    """
    q = jnp.round(acc.astype(jnp.float32) / step)
    return jnp.clip(q, QMIN, QMAX).astype(jnp.int32)
