"""ALPINE core: the paper's contribution as composable JAX modules.

  aimc      — tile programming / inference / noise-aware training (STE)
  quant     — DAC/ADC fixed-point math (shared by kernel and oracle)
  noise     — PCM non-idealities (programming / read / drift)
  tile      — crossbar tile allocation (AIMClib mapMatrix)
  aimclib   — programmer-facing queue/process/dequeue API
  isa       — CM_* instruction accounting
  costmodel — gem5-X-equivalent analytical performance/energy model
  workloads — the paper's MLP/LSTM/CNN cases as cost-model IR
  coupling  — tight (fused) vs loose (HBM-staged) execution
"""

from repro.core.aimc import (AimcConfig, AimcLinearState, aimc_apply,
                             aimc_linear, aimc_linear_ste, program_linear)
from repro.core.noise import DISABLED, NoiseModel

__all__ = [
    "AimcConfig", "AimcLinearState", "aimc_apply", "aimc_linear",
    "aimc_linear_ste", "program_linear", "NoiseModel", "DISABLED",
]
