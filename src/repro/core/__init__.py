"""ALPINE core: the paper's contribution as composable JAX modules.

  aimc      — tile programming / inference / noise-aware training (STE)
  program   — program-once/apply-many model API (MappingPlan, AimcProgram)
  quant     — DAC/ADC fixed-point math (shared by kernel and oracle)
  noise     — PCM non-idealities (programming / read / drift)
  tile      — crossbar tile allocation (AIMClib mapMatrix)
  aimclib   — programmer-facing queue/process/dequeue API
  isa       — CM_* instruction accounting
  costmodel — gem5-X-equivalent analytical performance/energy model
  workloads — the paper's MLP/LSTM/CNN cases as cost-model IR
  coupling  — tight (fused) vs loose (HBM-staged) execution
"""

from repro.core.aimc import (AimcConfig, AimcLinearState, aimc_apply,
                             aimc_linear, aimc_linear_ste, program_linear,
                             program_stacked)
from repro.core.noise import DISABLED, NoiseModel
from repro.core.program import (AimcProgram, CapacityError, MappingPlan,
                                ProgramBuilder, program_model)

__all__ = [
    "AimcConfig", "AimcLinearState", "aimc_apply", "aimc_linear",
    "aimc_linear_ste", "program_linear", "program_stacked",
    "AimcProgram", "CapacityError", "MappingPlan", "ProgramBuilder",
    "program_model", "NoiseModel", "DISABLED",
]
