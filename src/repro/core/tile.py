"""Crossbar tile allocation — the JAX port of AIMClib's ``mapMatrix`` (paper §IV-C).

A physical AIMC tile is an ``M x N`` crossbar (M word lines = input rows,
N bit lines = output columns). AIMClib lets the programmer place *several*
weight matrices side by side in one crossbar at (row, col) offsets — e.g. the
four LSTM gate matrices are tiled next to each other so that a single
CM_PROCESS computes all four gate MVMs (paper §VIII-D, [37]).

This module provides:

  * ``split_matrix``      — grid-split an arbitrary (K x N_out) weight matrix
    into crossbar-sized blocks (a matrix larger than one tile spans several;
    row-direction blocks are ADC-quantized independently and accumulated
    digitally, which is the fidelity-relevant part simulated by the kernel).
  * ``TileAllocator``     — first-fit shelf packer assigning placements of many
    (possibly small) matrices into as few physical tiles as possible.
  * ``TileMap``           — the resulting placement table, with utilization and
    tile-count statistics consumed by the cost model (`core.costmodel`) and the
    benchmarks.

The allocator runs at *trace/setup time* (plain Python over static shapes), so
it never appears inside jitted code; jitted code sees only the resulting block
structure.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class Placement:
    """One rectangular weight block placed on one physical tile."""

    matrix_id: str
    tile_id: int
    row_off: int  # word-line offset within the tile
    col_off: int  # bit-line offset within the tile
    rows: int
    cols: int
    # position of this block inside its source matrix
    src_row: int
    src_col: int


@dataclasses.dataclass(frozen=True)
class TileMap:
    tile_rows: int
    tile_cols: int
    placements: tuple[Placement, ...]
    n_tiles: int

    @property
    def utilization(self) -> float:
        used = sum(p.rows * p.cols for p in self.placements)
        total = self.n_tiles * self.tile_rows * self.tile_cols
        return used / total if total else 0.0

    def devices_used(self) -> int:
        # a signed weight needs a PCM device *pair* (paper §III-B)
        return 2 * sum(p.rows * p.cols for p in self.placements)

    def blocks_for(self, matrix_id: str) -> tuple[Placement, ...]:
        return tuple(p for p in self.placements if p.matrix_id == matrix_id)


def split_matrix(rows: int, cols: int, tile_rows: int, tile_cols: int):
    """Yield (src_row, src_col, r, c) blocks of a rows x cols matrix that each
    fit within one tile. Row-direction splits imply digital accumulation."""
    for r0 in range(0, rows, tile_rows):
        for c0 in range(0, cols, tile_cols):
            yield (r0, c0, min(tile_rows, rows - r0), min(tile_cols, cols - c0))


def n_row_blocks(rows: int, tile_rows: int) -> int:
    return math.ceil(rows / tile_rows)


def n_col_blocks(cols: int, tile_cols: int) -> int:
    return math.ceil(cols / tile_cols)


class TileAllocator:
    """First-fit shelf packer for many matrices into M x N crossbars.

    Shelf packing: within a tile, blocks are placed left-to-right on "shelves"
    (horizontal bands). A new shelf opens when the current row is full; a new
    tile opens when no shelf fits. This is the same greedy policy AIMClib's
    offset-based ``mapMatrix`` encourages, and is within ~10% of optimal for
    the NN layer mixes we map (blocks are large relative to tiles).
    """

    def __init__(self, tile_rows: int, tile_cols: int):
        if tile_rows <= 0 or tile_cols <= 0:
            raise ValueError("tile dimensions must be positive")
        self.tile_rows = tile_rows
        self.tile_cols = tile_cols
        # per tile: list of shelves [row_off, shelf_height, col_cursor]
        self._tiles: list[list[list[int]]] = []
        self._placements: list[Placement] = []

    # -- internal -----------------------------------------------------------
    def _try_place_in_tile(self, tile_idx: int, r: int, c: int):
        shelves = self._tiles[tile_idx]
        # try existing shelves (first fit)
        for shelf in shelves:
            row_off, height, cursor = shelf
            if r <= height and cursor + c <= self.tile_cols:
                shelf[2] += c
                return row_off, cursor
        # open a new shelf
        used_rows = sum(s[1] for s in shelves)
        if used_rows + r <= self.tile_rows and c <= self.tile_cols:
            shelves.append([used_rows, r, c])
            return used_rows, 0
        return None

    def _place_block(self, matrix_id: str, src_row: int, src_col: int, r: int, c: int):
        for tile_idx in range(len(self._tiles)):
            pos = self._try_place_in_tile(tile_idx, r, c)
            if pos is not None:
                break
        else:
            self._tiles.append([])
            tile_idx = len(self._tiles) - 1
            pos = self._try_place_in_tile(tile_idx, r, c)
            assert pos is not None, "block exceeds tile dimensions after split"
        row_off, col_off = pos
        self._placements.append(
            Placement(matrix_id, tile_idx, row_off, col_off, r, c, src_row, src_col)
        )

    # -- public -------------------------------------------------------------
    @property
    def n_tiles(self) -> int:
        """Physical tiles opened so far (the capacity the packer consumed)."""
        return len(self._tiles)

    @property
    def placements(self) -> tuple[Placement, ...]:
        """Everything placed so far (finalize() is a snapshot of the same)."""
        return tuple(self._placements)

    def map_matrix(self, matrix_id: str, rows: int, cols: int) -> None:
        """AIMClib ``mapMatrix``: split to tile-sized blocks and pack them."""
        for (r0, c0, r, c) in split_matrix(rows, cols, self.tile_rows, self.tile_cols):
            self._place_block(matrix_id, r0, c0, r, c)

    def map_side_by_side(self, matrix_ids: Sequence[str], rows: int, cols_each: int) -> None:
        """Place several same-height matrices adjacently (the LSTM-gate trick):

        one input queue + one CM_PROCESS serves all of them, outputs read from
        consecutive column ranges (paper §VIII-D)."""
        total_cols = cols_each * len(matrix_ids)
        if rows <= self.tile_rows and total_cols <= self.tile_cols:
            # force contiguous placement on a fresh shelf set
            for i, mid in enumerate(matrix_ids):
                self._place_block(mid, 0, 0, rows, cols_each)
        else:
            for mid in matrix_ids:
                self.map_matrix(mid, rows, cols_each)

    def finalize(self) -> TileMap:
        return TileMap(
            tile_rows=self.tile_rows,
            tile_cols=self.tile_cols,
            placements=tuple(self._placements),
            n_tiles=len(self._tiles),
        )


def overlapping_placements(
        placements: Sequence[Placement]) -> list[tuple[Placement, Placement]]:
    """Pairs of placements claiming intersecting cell ranges of one physical
    tile — a packer-invariant violation. Must ALWAYS be empty; checked by
    the multi-program pool tests so co-programmed models can never silently
    share crossbar devices (each cell pair holds exactly one weight)."""
    by_tile: dict[int, list[Placement]] = {}
    for p in placements:
        by_tile.setdefault(p.tile_id, []).append(p)
    bad = []
    for group in by_tile.values():
        for i, a in enumerate(group):
            for b in group[i + 1:]:
                row_hit = (a.row_off < b.row_off + b.rows
                           and b.row_off < a.row_off + a.rows)
                col_hit = (a.col_off < b.col_off + b.cols
                           and b.col_off < a.col_off + a.cols)
                if row_hit and col_hit:
                    bad.append((a, b))
    return bad


def plan_linear(matrix_id: str, in_features: int, out_features: int,
                tile_rows: int, tile_cols: int) -> TileMap:
    """Convenience: a TileMap for a single dense weight matrix."""
    alloc = TileAllocator(tile_rows, tile_cols)
    alloc.map_matrix(matrix_id, in_features, out_features)
    return alloc.finalize()


def pack_contexts(items: Sequence[tuple[str, int, int, int]],
                  n_contexts: int, tile_rows: int,
                  tile_cols: int) -> tuple[int, ...]:
    """Per-context tile counts of packing ``items`` exactly the way
    `core.program.ProgramBuilder` would — the placer's feasibility oracle.

    ``items`` are ``(matrix_id, rows, cols, instances)`` in PROGRAMMING
    ORDER (the `iter_mapped_leaves` tree walk). The simulation reproduces
    the builder's policy bit-for-bit: each matrix goes to the least-loaded
    context (min `n_tiles`, lowest index on ties), each instance mapped as
    ``id`` / ``id[i]`` through the same first-fit shelf packer. Because the
    policies are identical (pinned by tests/test_placement.py against a
    real builder), a subset whose packed max fits `tiles_per_context` here
    is GUARANTEED to program without `CapacityError` there."""
    if n_contexts < 1:
        raise ValueError("n_contexts must be >= 1")
    allocs = [TileAllocator(tile_rows, tile_cols) for _ in range(n_contexts)]
    for mid, rows, cols, instances in items:
        ctx = min(range(n_contexts), key=lambda i: allocs[i].n_tiles)
        for i in range(instances):
            inst = mid if instances == 1 else f"{mid}[{i}]"
            allocs[ctx].map_matrix(inst, rows, cols)
    return tuple(a.n_tiles for a in allocs)
