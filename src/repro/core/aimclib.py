"""AIMClib — the programmer-facing library (paper §IV-C, Fig. 4), in JAX.

Mirrors the C library's surface:

  * ``map_matrix(name, w)``        — program a weight matrix onto crossbars at
    packed offsets (tiling handled by `core.tile.TileAllocator`).
  * ``map_gates(name, [W...])``    — place several same-height matrices side
    by side so ONE process call computes all of them (the paper's LSTM trick,
    §VIII-D: queue [h, x] once, dequeue all four gate pre-activations).
  * ``queue_vector / process / dequeue_vector`` — the instruction-level data
    flow of Fig. 4, for code that wants the explicit three-step shape.
  * ``linear(name, x)``            — the fused convenience path every model
    layer actually uses (identical math, one call).
  * int8 <-> fp32 casting, digital activation helpers, and a host "checker"
    mode — which is exactly `kernels/ref.py` (the oracle doubles as the
    paper's debug-on-host checker program).

The context also keeps per-matrix CM_* instruction counts so applications get
cost-model accounting for free.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.core.aimc import AimcConfig, AimcLinearState, aimc_apply, program_linear
from repro.core.tile import TileAllocator, TileMap


class AimcContext:
    """One context ~ the set of AIMC tiles private to a core (paper Fig. 2)."""

    def __init__(self, cfg: AimcConfig, key: jax.Array | None = None):
        self.cfg = cfg
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._alloc = TileAllocator(cfg.tile_rows, cfg.tile_cols)
        self._states: dict[str, AimcLinearState] = {}
        self._counts: dict[str, isa.CmCounts] = {}
        self._pending: dict[str, jnp.ndarray] = {}   # queued inputs per matrix

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- programming (CM_INITIALIZE) ----------------------------------------
    def map_matrix(self, name: str, w: jnp.ndarray) -> AimcLinearState:
        if name in self._states:
            raise ValueError(f"matrix {name!r} already mapped")
        k, n = w.shape
        self._alloc.map_matrix(name, k, n)
        state = program_linear(jnp.asarray(w), self.cfg, self._next_key())
        self._states[name] = state
        self._counts[name] = isa.initialize_counts(k, n)
        return state

    def map_gates(self, name: str, gates: Sequence[jnp.ndarray]) -> AimcLinearState:
        """Concatenate same-height gate matrices column-wise and map them as a
        single crossbar tenant — one queue + one process serves all gates."""
        rows = gates[0].shape[0]
        if any(g.shape[0] != rows for g in gates):
            raise ValueError("gate matrices must share in_features")
        self._alloc.map_side_by_side(
            [f"{name}.g{i}" for i in range(len(gates))], rows, gates[0].shape[1]
        )
        w = jnp.concatenate([jnp.asarray(g) for g in gates], axis=1)
        state = program_linear(w, self.cfg, self._next_key())
        self._states[name] = state
        self._counts[name] = isa.initialize_counts(*w.shape)
        return state

    # -- the Fig. 4 instruction-level flow -----------------------------------
    def queue_vector(self, name: str, x: jnp.ndarray) -> None:
        st = self._state(name)
        self._counts[name] += isa.mvm_counts(st.k, st.n, self.cfg.tile_rows)
        self._pending[name] = jnp.asarray(x)

    def process(self, name: str) -> None:
        if name not in self._pending:
            raise RuntimeError(f"CM_PROCESS before CM_QUEUE for {name!r}")

    def dequeue_vector(self, name: str) -> jnp.ndarray:
        x = self._pending.pop(name, None)
        if x is None:
            raise RuntimeError(f"CM_DEQUEUE before CM_QUEUE for {name!r}")
        return aimc_apply(self._state(name), x, self.cfg, self._next_key())

    # -- fused path -----------------------------------------------------------
    def linear(self, name: str, x: jnp.ndarray) -> jnp.ndarray:
        st = self._state(name)
        self._counts[name] += isa.mvm_counts(st.k, st.n, self.cfg.tile_rows)
        return aimc_apply(st, x, self.cfg, self._next_key())

    # -- bookkeeping ----------------------------------------------------------
    def _state(self, name: str) -> AimcLinearState:
        if name not in self._states:
            raise KeyError(f"matrix {name!r} was never mapped")
        return self._states[name]

    def tile_map(self) -> TileMap:
        return self._alloc.finalize()

    def instruction_counts(self) -> isa.CmCounts:
        total = isa.CmCounts()
        for c in self._counts.values():
            total = total + c
        return total


# -- digital helpers (run "on the CPU", paper keeps these out of the tile) ----
def relu(x):
    return jnp.maximum(x, 0)


def sigmoid(x):
    return jax.nn.sigmoid(x)


def tanh(x):
    return jnp.tanh(x)


def softmax(x, axis=-1):
    return jax.nn.softmax(x, axis=axis)


def cast_to_int8(x, scale):
    from repro.core.quant import quantize
    return quantize(x, scale)


def cast_from_int8(q, scale):
    from repro.core.quant import dequantize
    return dequantize(q, scale)
