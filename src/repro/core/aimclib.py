"""AIMClib — the programmer-facing library (paper §IV-C, Fig. 4), in JAX.

Mirrors the C library's surface:

  * ``map_matrix(name, w)``        — program a weight matrix onto crossbars at
    packed offsets (tiling handled by `core.tile.TileAllocator`).
  * ``map_gates(name, [W...])``    — place several same-height matrices side
    by side so ONE process call computes all of them (the paper's LSTM trick,
    §VIII-D: queue [h, x] once, dequeue all four gate pre-activations).
  * ``queue_vector / process / dequeue_vector`` — the instruction-level data
    flow of Fig. 4, for code that wants the explicit three-step shape.
  * ``linear(name, x)``            — the fused convenience path every model
    layer actually uses (identical math, one call).
  * int8 <-> fp32 casting, digital activation helpers, and a host "checker"
    mode — which is exactly `kernels/ref.py` (the oracle doubles as the
    paper's debug-on-host checker program).

The context is a thin dynamic shell over `core.program.ProgramBuilder`: the
same program-once registry that `program_model` builds for whole models backs
the hand-written mapMatrix workloads here, so CM_* instruction counts flow
through one accounting path — ``ctx.program()`` hands the registry (an
`AimcProgram` pytree) to serving stats and the `bench_*` cost accounting.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.core.aimc import (AimcConfig, AimcLinearState, aimc_apply,
                             aimc_apply_stacked)
from repro.core.program import AimcProgram, ProgramBuilder
from repro.core.tile import TileMap


class AimcContext:
    """One context ~ the set of AIMC tiles private to a core (paper Fig. 2)."""

    def __init__(self, cfg: AimcConfig, key: jax.Array | None = None,
                 n_contexts: int = 1, tiles_per_context: int | None = None):
        self.cfg = cfg
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._builder = ProgramBuilder(cfg, n_contexts=n_contexts,
                                       tiles_per_context=tiles_per_context)
        self._counts: dict[str, isa.CmCounts] = {}
        self._pending: dict[str, jnp.ndarray] = {}   # queued inputs per matrix

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- programming (CM_INITIALIZE) ----------------------------------------
    def map_matrix(self, name: str, w: jnp.ndarray) -> AimcLinearState:
        state = self._builder.add(name, jnp.asarray(w), self._next_key())
        self._counts[name] = isa.initialize_counts(state.k, state.n)
        return state

    def map_gates(self, name: str, gates: Sequence[jnp.ndarray]) -> AimcLinearState:
        """Concatenate same-height gate matrices column-wise and map them as a
        single crossbar tenant — one queue + one process serves all gates."""
        state = self._builder.add_gates(name, gates, self._next_key())
        self._counts[name] = isa.initialize_counts(state.k, state.n)
        return state

    def map_gate_stack(self, name: str,
                       gates: Sequence[jnp.ndarray]) -> AimcLinearState:
        """Program same-SHAPE gate matrices as a `[G, ...]` stacked tenant
        for the gate-fused multi-MVM (kernel v2): `linear_stack` runs all G
        as one weight-stationary kernel launch with a per-gate epilogue.
        Same crossbar footprint and CM_* profile as `map_gates` (queue the
        shared input once, dequeue every gate's columns)."""
        w = jnp.stack([jnp.asarray(g) for g in gates])
        state = self._builder.add(name, w, self._next_key())
        self._counts[name] = isa.initialize_counts(
            state.k, state.n).scaled(state.instances)
        return state

    # -- the Fig. 4 instruction-level flow -----------------------------------
    def queue_vector(self, name: str, x: jnp.ndarray) -> None:
        st = self._state(name)
        self._counts[name] += isa.mvm_counts(st.k, st.n, self.cfg.tile_rows)
        self._pending[name] = jnp.asarray(x)

    def process(self, name: str) -> None:
        if name not in self._pending:
            raise RuntimeError(f"CM_PROCESS before CM_QUEUE for {name!r}")

    def dequeue_vector(self, name: str) -> jnp.ndarray:
        x = self._pending.pop(name, None)
        if x is None:
            raise RuntimeError(f"CM_DEQUEUE before CM_QUEUE for {name!r}")
        return aimc_apply(self._state(name), x, self.cfg, self._next_key())

    # -- fused path -----------------------------------------------------------
    def linear(self, name: str, x: jnp.ndarray,
               bias: jnp.ndarray | None = None,
               activation: str = "none") -> jnp.ndarray:
        st = self._state(name)
        self._counts[name] += isa.mvm_counts(st.k, st.n, self.cfg.tile_rows)
        return aimc_apply(st, x, self.cfg, self._next_key(), bias=bias,
                          activation=activation)

    def linear_stack(self, name: str, x: jnp.ndarray,
                     activations="none") -> jnp.ndarray:
        """Apply a `map_gate_stack` tenant: one gate-fused kernel launch,
        `[G, ..., N]` out. Accounted as the side-by-side mapping (shared
        queue, per-gate dequeue — the §VIII-D instruction profile)."""
        st = self._state(name)
        g = st.instances
        self._counts[name] += isa.mvm_counts(st.k, g * st.n,
                                             self.cfg.tile_rows)
        return aimc_apply_stacked(st, x, self.cfg, self._next_key(),
                                  activations=activations)

    # -- bookkeeping ----------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._builder._entries

    def _state(self, name: str) -> AimcLinearState:
        try:
            return self._builder._entries[name]
        except KeyError:
            raise KeyError(f"matrix {name!r} was never mapped") from None

    def program(self) -> AimcProgram:
        """The registry built so far, as a jit-friendly `AimcProgram`."""
        return self._builder.build()

    def tile_map(self) -> TileMap:
        maps = self.program().tile_maps
        if len(maps) == 1:
            return maps[0]
        # multi-context views merge for reporting: offset tile ids per context
        placements, n_tiles = [], 0
        for tm in maps:
            for p in tm.placements:
                placements.append(
                    type(p)(p.matrix_id, p.tile_id + n_tiles, p.row_off,
                            p.col_off, p.rows, p.cols, p.src_row, p.src_col))
            n_tiles += tm.n_tiles
        return TileMap(self.cfg.tile_rows, self.cfg.tile_cols,
                       tuple(placements), n_tiles)

    def instruction_counts(self) -> isa.CmCounts:
        return isa.total(self._counts.values())


# -- digital helpers (run "on the CPU", paper keeps these out of the tile) ----
def relu(x):
    return jnp.maximum(x, 0)


def sigmoid(x):
    return jax.nn.sigmoid(x)


def tanh(x):
    return jnp.tanh(x)


def softmax(x, axis=-1):
    return jax.nn.softmax(x, axis=axis)


def cast_to_int8(x, scale):
    from repro.core.quant import quantize
    return quantize(x, scale)


def cast_from_int8(q, scale):
    from repro.core.quant import dequantize
    return dequantize(q, scale)
