"""Multi-core AIMC scheduler — the executable twin of the cost model's phases.

The paper's headline results come from *multi-core* mappings: the MLP/LSTM
explorations column-split layers across cores with mutex hand-offs between
phases (§VII-D, §VIII-D), and the CNN pipelines one conv layer per core at
position granularity (§IX-A). `core.workloads` describes those mappings
analytically; this module makes them RUN:

  * ``Shard``          — one (slice of a) programmed matrix assigned to one
    virtual core in one phase, with its dataflow edges (comm/load/store
    bytes) declared statically.
  * ``select_columns`` — exact column-split of an `AimcLinearState`. ADC
    quantization, per-column scales and row-block accumulation are all
    column-independent, so the concatenated shard outputs are bit-identical
    to the single-core apply (noise off) — the property every multi-core
    mapping in the paper relies on.
  * ``CoreSchedule``   — lowers an `AimcProgram` onto N virtual cores.
    ``apply(name, x)`` executes a matrix across all its shards (interleaved
    on one device); ``apply_sharded`` runs one shard per mesh device via
    `shard_map`. ``ledgers()`` emits per-core CM_*/comm-byte accounts, and
    ``modeled_latency()`` prices them through the SAME
    `costmodel.aimc_mvm_time` the analytical model uses — measured
    (executable) and predicted (analytical) views can be compared case by
    case (`benchmarks/bench_pipeline.py`).
  * dataflow laws      — ``sequential_latency`` (per-inference time = sum
    over phases of the slowest core, the MLP/LSTM mutex chain) and
    ``pipelined_latency`` (= slowest stage, the CNN position pipeline),
    mirroring `costmodel.evaluate`'s treatment of `Workload.pipelined`.
  * ``OverlapRoofline`` — the serving-loop latency law: T_step(k) =
    t_step_s + t_round_s/k, fitted from measured chunked-decode step
    times; predicts (and the serving bench gates) the host-overlap gain
    of the k-step scanned decode loop (DESIGN.md §13).

Builders for every paper multi-core case live at the bottom
(`mlp_schedule`, `lstm_schedule`, `cnn_schedule`) and `from_program` lowers
any `program_model` output (zoo models) using its MappingPlan contexts as
cores. `mesh_placement` / `device_ledgers` fold the virtual cores onto a
JAX mesh's model-axis devices for the sharded serving engine
(DESIGN.md §11) — placement regroups the books but never creates or loses
traffic.

Invariants (pinned by tests/test_schedule.py): column splits are EXACT
(concatenated shard outputs == single-core apply, noise off); unsplit
per-core ledgers sum to `program.mvm_counts()` while column splits
partition dequeue/initialize exactly and duplicate queue/process by the
split factor; `modeled_latency()` equals `costmodel.evaluate()` on the
matching Workload IR bit-for-bit (shared accounting).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa
from repro.core.aimc import AimcLinearState, aimc_apply
from repro.core.costmodel import (CALIB, HIGH_POWER, aimc_mvm_time,
                                  fused_epilogue_time)
from repro.core.program import AimcProgram


# ---------------------------------------------------------------------------
# Exact column splitting
# ---------------------------------------------------------------------------

def select_columns(state: AimcLinearState,
                   ranges: Sequence[tuple[int, int]]) -> AimcLinearState:
    """A new programmed state holding only the given logical column ranges.

    The slice is EXACT: per-column weight scales, ADC codes and row-block
    accumulation never mix columns, so (noise off)

        aimc_apply(select_columns(st, R), x) == aimc_apply(st, x)[..., idx(R)]

    bit for bit. Non-contiguous ranges are allowed (the LSTM case-4 gate
    slices pick one stripe out of each of the four gate blocks)."""
    for a, b in ranges:
        if not (0 <= a < b <= state.n):
            raise ValueError(f"column range [{a}, {b}) outside n={state.n}")
    idx = np.concatenate([np.arange(a, b) for a, b in ranges])
    if len(np.unique(idx)) != idx.size:
        raise ValueError("overlapping column ranges")
    n_new = int(idx.size)
    np_new = -(-n_new // 128) * 128          # keep TPU lane alignment
    w_q = jnp.asarray(state.w_q)[..., idx]
    s_w = jnp.asarray(state.s_w)[..., idx]
    pad = np_new - n_new
    if pad:
        w_q = jnp.pad(w_q, [(0, 0)] * (w_q.ndim - 1) + [(0, pad)])
        s_w = jnp.pad(s_w, [(0, 0)] * (s_w.ndim - 1) + [(0, pad)])
    return AimcLinearState(w_q=w_q, s_w=s_w, k=state.k, n=n_new)


# ---------------------------------------------------------------------------
# Shards and per-core ledgers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shard:
    """One (slice of a) programmed matrix on one virtual core.

    ``cols=None`` assigns the whole matrix; otherwise a tuple of logical
    [start, stop) column ranges. ``count`` is the number of MVMs this shard
    fires per inference (conv output positions re-using the kernel).
    ``comm_in_bytes``/``comm_events`` are the activation bytes and mutex
    hand-offs this core pays before computing (paper: sequential cross-core
    dependency chain); ``comm_out_bytes`` what it forwards.
    ``digital_cycles`` prices the stage's CPU-side element-wise tail (relu /
    cell math / softmax ...) in core cycles, so schedule-modeled latency is
    comparable to `costmodel.evaluate` on the matching `Workload`.
    ``epilogue_fn``/``epilogue_elems`` instead declare an activation FUSED
    into the shard's dequeue loop (kernel v2's fused epilogue), priced by
    the shared `costmodel.fused_epilogue_time` — cheap epilogues hide under
    the per-word transaction latency and cost nothing. ``epilogue_elems``
    is PER FIRING (scaled by count * instances like the MVM itself)."""

    name: str
    core: int
    phase: int
    cols: tuple[tuple[int, int], ...] | None = None
    count: int = 1
    comm_in_bytes: int = 0
    comm_out_bytes: int = 0
    comm_events: int = 0
    load_bytes: int = 0
    store_bytes: int = 0
    digital_cycles: float = 0.0
    epilogue_fn: str = ""
    epilogue_elems: int = 0

    def n_cols(self, state: AimcLinearState) -> int:
        if self.cols is None:
            return state.n
        return sum(b - a for a, b in self.cols)


@dataclasses.dataclass(frozen=True)
class CoreLedger:
    """Static per-core account of one inference — the same units the cost
    model prices (`isa.CmCounts` + comm/load/store bytes)."""

    core: int
    cm: isa.CmCounts
    comm_bytes: int = 0
    comm_events: int = 0
    load_bytes: int = 0
    store_bytes: int = 0

    def row(self) -> list:
        return [self.core, self.cm.queue, self.cm.process, self.cm.dequeue,
                self.comm_bytes, self.load_bytes + self.store_bytes]


# ---------------------------------------------------------------------------
# Dataflow latency laws (mirrors costmodel.evaluate's Workload.pipelined)
# ---------------------------------------------------------------------------

def sequential_latency(phase_times: Sequence[Sequence[float]]) -> float:
    """Mutex hand-off semantics (MLP/LSTM): stages inside a phase run in
    parallel on different cores, phases chain — per-inference latency is the
    sum over phases of the slowest stage in each."""
    return sum(max(ph) if len(ph) else 0.0 for ph in phase_times)


def pipelined_latency(phase_times: Sequence[Sequence[float]]) -> float:
    """Position-level pipelining (CNN): at steady state every stage works on
    a different inference — per-inference latency is the slowest stage."""
    return max((t for ph in phase_times for t in ph), default=0.0)


@dataclasses.dataclass(frozen=True)
class OverlapRoofline:
    """Calibrated host-overlap roofline for the chunked decode loop.

    The serving engine's per-token cost splits into two empirical
    constants (the SNIPPETS.md discipline: fit measured constants, then
    gate predicted-vs-measured like bench_pipeline's ratio checks):

        T_step(k) = t_step_s + t_round_s / k

    ``t_step_s`` is the irreducible per-step device time (model math plus,
    on a mesh, the model-axis reduction — it scales with neither k nor the
    host), and ``t_round_s`` is the per-HOST-ROUND overhead (dispatch,
    sync, readback, Python bookkeeping) that a k-step `lax.scan` chunk
    amortizes over k steps. `fit` recovers both by least squares from
    measured synchronous per-step times at >= 2 chunk sizes; `predict_
    step_s` / `speedup` then EXPLAIN the measured chunked-decode gain, and
    the serving bench gates |predicted - measured| (BENCH_serving.json).
    """
    t_step_s: float
    t_round_s: float

    @classmethod
    def fit(cls, step_times: dict[int, float]) -> "OverlapRoofline":
        """Least-squares fit of (t_step_s, t_round_s) over the basis
        [1, 1/k]. ``step_times``: chunk size k -> measured mean seconds
        per decode STEP (chunk wall / k) at that k. Needs >= 2 distinct
        chunk sizes; negative fitted constants clamp to 0 (wall-clock
        noise can tilt the regression, but time is not refundable)."""
        ks = sorted(step_times)
        if len(ks) < 2:
            raise ValueError(
                f"OverlapRoofline.fit needs step times at >= 2 chunk "
                f"sizes, got {ks}")
        a_mat = np.array([[1.0, 1.0 / k] for k in ks])
        y = np.array([step_times[k] for k in ks])
        (t_step, t_round), *_ = np.linalg.lstsq(a_mat, y, rcond=None)
        return cls(t_step_s=max(float(t_step), 0.0),
                   t_round_s=max(float(t_round), 0.0))

    def predict_step_s(self, k: int) -> float:
        """Predicted seconds per decode step at chunk size ``k``."""
        if k < 1:
            raise ValueError(f"chunk size must be >= 1, got {k}")
        return self.t_step_s + self.t_round_s / k

    def speedup(self, k_from: int = 1, k_to: int = 8) -> float:
        """Predicted step-time ratio T(k_from) / T(k_to) — the overlap
        gain the chunked loop should realize by moving from k_from to
        k_to host-round amortization."""
        return self.predict_step_s(k_from) / self.predict_step_s(k_to)

    def residuals(self, step_times: dict[int, float]) -> dict[int, float]:
        """k -> relative |predicted - measured| / measured, the
        calibration quality the bench gates on."""
        return {k: abs(self.predict_step_s(k) - t) / t
                for k, t in step_times.items()}


# ---------------------------------------------------------------------------
# CoreSchedule
# ---------------------------------------------------------------------------

class CoreSchedule:
    """An `AimcProgram` lowered onto N virtual cores.

    Built once at setup time (plain Python over static shapes — never inside
    jit); ``apply`` is jit-friendly and numerically equal to the single-core
    programmed path (noise off)."""

    def __init__(self, program: AimcProgram, shards: Sequence[Shard],
                 pipelined: bool = False, name: str = ""):
        self.program = program
        self.cfg = program.cfg
        self.shards = tuple(shards)
        self.pipelined = pipelined
        self.name = name
        if not self.shards:
            raise ValueError("a schedule needs at least one shard")

        self._by_name: dict[str, tuple[Shard, ...]] = {}
        for sh in self.shards:
            if sh.name not in program:
                raise KeyError(f"shard references unmapped matrix {sh.name!r}")
            self._by_name.setdefault(sh.name, ())
            self._by_name[sh.name] += (sh,)

        # pre-slice states + record the inverse column permutation per matrix
        self._states: dict[tuple[str, int], AimcLinearState] = {}
        self._inv_perm: dict[str, np.ndarray | None] = {}
        for mname, shs in self._by_name.items():
            st = program[mname]
            if len(shs) == 1 and shs[0].cols is None:
                self._inv_perm[mname] = None
                continue
            if any(sh.cols is None for sh in shs):
                raise ValueError(
                    f"matrix {mname!r}: mixing full and column-split shards")
            idx = np.concatenate(
                [np.concatenate([np.arange(a, b) for a, b in sh.cols])
                 for sh in shs])
            if not np.array_equal(np.sort(idx), np.arange(st.n)):
                raise ValueError(
                    f"matrix {mname!r}: shard columns are not a disjoint "
                    f"cover of 0..{st.n}")
            for i, sh in enumerate(shs):
                self._states[(mname, i)] = select_columns(st, sh.cols)
            self._inv_perm[mname] = np.argsort(idx)

    # -- shape stats ---------------------------------------------------------
    @property
    def n_cores(self) -> int:
        return max(sh.core for sh in self.shards) + 1

    @property
    def n_phases(self) -> int:
        return max(sh.phase for sh in self.shards) + 1

    def shards_of(self, name: str) -> tuple[Shard, ...]:
        return self._by_name[name]

    # -- execution: interleaved on one device --------------------------------
    def apply(self, name: str, x: jnp.ndarray,
              key: jax.Array | None = None) -> jnp.ndarray:
        """Run matrix `name` across all its shards and reassemble the full
        output — the executable form of the column-split mapping. With one
        full shard this IS the single-core path. Noise draws (when enabled)
        are per shard, so multi-core noise differs from single-core by
        design — each core owns physically distinct crossbar columns."""
        shs = self._by_name[name]
        if self._inv_perm[name] is None:
            return aimc_apply(self.program[name], x, self.cfg, key)
        parts = []
        for i in range(len(shs)):
            sub_key = jax.random.fold_in(key, i) if key is not None else None
            parts.append(aimc_apply(self._states[(name, i)], x, self.cfg,
                                    sub_key))
        y = jnp.concatenate(parts, axis=-1)
        return y[..., self._inv_perm[name]]

    # -- execution: one core per mesh device via shard_map --------------------
    def apply_sharded(self, name: str, x: jnp.ndarray, mesh,
                      axis: str = "model") -> jnp.ndarray:
        """`apply`, but with the per-core column shards distributed along a
        mesh axis: each device holds (a group of) cores' conductance codes
        and computes only its slice; slices concatenate on the way out. The
        input is replicated — every core queues the full activation vector,
        exactly the paper's case-4 dataflow."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        shs = self._by_name[name]
        if self._inv_perm[name] is None:
            raise ValueError(f"matrix {name!r} has a single full shard; "
                             "use apply() (nothing to distribute)")
        states = [self._states[(name, i)] for i in range(len(shs))]
        k, n = states[0].k, states[0].n
        if any(st.n != n or st.w_q.shape != states[0].w_q.shape
               for st in states):
            raise ValueError("apply_sharded needs equal-size column shards")
        n_dev = mesh.shape[axis]
        if len(states) % n_dev:
            raise ValueError(f"{len(states)} shards not divisible over "
                             f"{n_dev} devices on axis {axis!r}")
        w_q = jnp.stack([st.w_q for st in states])
        s_w = jnp.stack([st.s_w for st in states])
        cfg = self.cfg

        def shard_fn(wq_l, sw_l, x_l):
            def one(wq_i, sw_i):
                st = AimcLinearState(w_q=wq_i, s_w=sw_i, k=k, n=n)
                return aimc_apply(st, x_l, cfg)

            return jax.vmap(one)(wq_l, sw_l)

        parts = shard_map(shard_fn, mesh, in_specs=(P(axis), P(axis), P()),
                          out_specs=P(axis), check_vma=False)(w_q, s_w, x)
        y = jnp.concatenate(list(parts), axis=-1)
        return y[..., self._inv_perm[name]]

    # -- static accounting (the cost model's units) ---------------------------
    def ledgers(self) -> tuple[CoreLedger, ...]:
        """Per-core CM_*/comm-byte accounts for ONE inference.

        Column-split cores each queue the FULL input vector (the paper's
        case-4 semantics: every core feeds its private tile), so summed
        queue/process counts exceed the single-core program's by the split
        factor while dequeue/initialize partition exactly — `ledger_totals`
        vs `program.mvm_counts()` quantifies the multi-core queue tax."""
        acc = {c: [isa.CmCounts(), 0, 0, 0, 0] for c in range(self.n_cores)}
        for sh in self.shards:
            st = self.program[sh.name]
            cm = isa.mvm_counts(st.k, sh.n_cols(st), self.cfg.tile_rows)
            a = acc[sh.core]
            a[0] = a[0] + cm.scaled(sh.count * st.instances)
            a[1] += sh.comm_in_bytes + sh.comm_out_bytes
            a[2] += sh.comm_events
            a[3] += sh.load_bytes
            a[4] += sh.store_bytes
        return tuple(CoreLedger(c, *acc[c]) for c in sorted(acc))

    def ledger_totals(self) -> isa.CmCounts:
        return isa.total(led.cm for led in self.ledgers())

    # -- predicted latency through the shared cost-model accounting -----------
    def shard_time(self, sh: Shard, sys=HIGH_POWER, p=CALIB,
                   coupling: str = "tight") -> float:
        """Modeled busy time of one shard — CM_* traffic priced by
        `costmodel.aimc_mvm_time` (the same function `evaluate()` uses) plus
        its comm/load/store edges."""
        st = self.program[sh.name]
        cm = isa.mvm_counts(st.k, sh.n_cols(st), self.cfg.tile_rows)
        t_q, t_p, t_d = aimc_mvm_time(cm, sys, p, coupling)
        reps = sh.count * st.instances
        t = (t_q + t_p + t_d) * reps
        if sh.epilogue_fn:
            # epilogue_elems is per firing; elems and the hiding dequeue
            # budget scale together (mirrors costmodel._stage_time's
            # op.count scaling)
            t += fused_epilogue_time(
                sh.epilogue_elems * reps, sh.epilogue_fn,
                cm.dequeue * reps, sys, p)
        f = sys.freq_hz
        t += sh.comm_events * p.sync_s
        t += (sh.comm_in_bytes + sh.comm_out_bytes) * p.comm_cycles_per_byte / f
        t += sh.load_bytes * p.load_cycles_per_byte / f
        t += sh.store_bytes * p.store_cycles_per_byte / f
        t += sh.digital_cycles / f
        return t

    def phase_times(self, sys=HIGH_POWER, p=CALIB,
                    coupling: str = "tight") -> tuple[tuple[float, ...], ...]:
        """Per phase, the modeled busy time of each active core."""
        per: dict[tuple[int, int], float] = {}
        for sh in self.shards:
            key = (sh.phase, sh.core)
            per[key] = per.get(key, 0.0) + self.shard_time(sh, sys, p, coupling)
        out = []
        for ph in range(self.n_phases):
            out.append(tuple(t for (p_, _c), t in sorted(per.items())
                             if p_ == ph))
        return tuple(out)

    def modeled_latency(self, sys=HIGH_POWER, p=CALIB,
                        coupling: str = "tight") -> float:
        """Per-inference latency under this schedule's dataflow law."""
        times = self.phase_times(sys, p, coupling)
        law = pipelined_latency if self.pipelined else sequential_latency
        return law(times)

    # -- mesh placement (sharded serving, DESIGN.md §11) -----------------------
    def mesh_placement(self, mesh, axis: str = "model",
                       dead: Sequence[int] = ()) -> dict[int, int]:
        """virtual core -> device slot along mesh ``axis`` (round-robin).

        The placement rule the sharded serving engine uses: cores fold onto
        the model-parallel devices in index order, so an N-core schedule on
        a D-device axis puts core c on device ``c % D``. With D >= N every
        core owns a device (the paper's one-core-per-unit regime); with
        D < N devices time-share cores exactly as a single device
        time-shares every core today — the ledgers are placement-invariant
        either way. A mesh without ``axis`` is a single device slot.

        ``dead`` lists device slots lost mid-serve (the chaos/fault path):
        cores fold round-robin over the SURVIVING slots only, preserving
        round-robin order — the drain-and-remap rule `runtime.health` pairs
        with tile reprogramming. Killing every slot raises."""
        n_dev = mesh.shape[axis] if axis in mesh.axis_names else 1
        alive = [d for d in range(n_dev) if d not in set(dead)]
        if not alive:
            raise ValueError(f"mesh_placement: all {n_dev} device slot(s) "
                             f"on axis {axis!r} are dead")
        return {c: alive[c % len(alive)] for c in range(self.n_cores)}

    def device_ledgers(self, mesh, axis: str = "model",
                       dead: Sequence[int] = ()) -> dict[int, CoreLedger]:
        """device slot -> per-inference ledger summed over the cores placed
        there (`mesh_placement`). The ``core`` field of each returned
        `CoreLedger` is the DEVICE slot; summed over devices the books equal
        `ledger_totals()` — placement never creates or loses traffic (with
        or without ``dead`` slots excluded)."""
        place = self.mesh_placement(mesh, axis, dead=dead)
        acc: dict[int, list] = {}
        for led in self.ledgers():
            d = place[led.core]
            if d not in acc:
                acc[d] = [isa.CmCounts(), 0, 0, 0, 0]
            a = acc[d]
            a[0] = a[0] + led.cm
            a[1] += led.comm_bytes
            a[2] += led.comm_events
            a[3] += led.load_bytes
            a[4] += led.store_bytes
        return {d: CoreLedger(d, *acc[d]) for d in sorted(acc)}

    def summary(self) -> str:
        law = "pipelined" if self.pipelined else "sequential"
        return (f"CoreSchedule[{self.name or 'anon'}]: {len(self.shards)} "
                f"shards of {len(self._by_name)} matrices on "
                f"{self.n_cores} core(s), {self.n_phases} phase(s), {law}; "
                f"modeled {self.modeled_latency() * 1e6:.1f}us/inf")

    def __repr__(self) -> str:
        return f"<{self.summary()}>"

    # -- lowering a whole-model program ---------------------------------------
    @classmethod
    def from_program(cls, program: AimcProgram,
                     pipelined: bool = False) -> "CoreSchedule":
        """Lower a `program_model` output onto its MappingPlan contexts: each
        context is a virtual core, each mapped matrix a phase in registry
        order, with an int8 activation hand-off (k bytes + one mutex) charged
        whenever consecutive matrices sit on different cores."""
        shards = []
        prev_core = None
        for i, name in enumerate(program.names):
            st = program[name]
            core = program.contexts[i]
            hand_off = prev_core is not None and core != prev_core
            shards.append(Shard(
                name=name, core=core, phase=i,
                comm_in_bytes=st.k if hand_off else 0,
                comm_events=1 if hand_off else 0))
            prev_core = core
        return cls(program, shards, pipelined=pipelined, name="from_program")


# ---------------------------------------------------------------------------
# Pipelined stream execution (position-level pipelining, measured view)
# ---------------------------------------------------------------------------

def pipeline_run(stage_fns: Sequence[Callable], inputs: Sequence):
    """Push a stream of inputs through chained stages, measuring per-stage
    wallclock. Pipelining changes TIMING, not values — outputs are identical
    to sequential execution; the per-stage times feed the two latency laws
    (measured pipelined latency ~ max stage, sequential ~ sum)."""
    times = [0.0] * len(stage_fns)
    outs = []
    for x in inputs:
        for i, fn in enumerate(stage_fns):
            t0 = time.perf_counter()
            x = fn(x)
            jax.block_until_ready(x)
            times[i] += time.perf_counter() - t0
        outs.append(x)
    n = max(len(inputs), 1)
    return outs, tuple(t / n for t in times)


# ---------------------------------------------------------------------------
# Paper-case schedule builders (workloads.py's analytical twins, executable)
# ---------------------------------------------------------------------------

def mlp_schedule(program: AimcProgram, cores: int = 1,
                 p=CALIB, fuse_epilogue: bool = False) -> CoreSchedule:
    """The paper's MLP analog mappings (Fig. 6) over entries fc1/fc2.

    cores=1 -> case 1 (both layers one core); cores=2 -> case 3 (layer per
    core, mutex hand-off); cores=4 -> case 4 (each layer column-split over
    two cores, all-to-all half hand-offs). Comm edges and digital relu
    cycles mirror `workloads.mlp_workloads` op for op, so
    `modeled_latency()` tracks `costmodel.evaluate` on the same case.
    ``fuse_epilogue`` folds each layer's relu into its dequeue loop (kernel
    v2) instead of a separate digital pass — the matching workloads carry
    `Op(..., epilogue="relu")`."""
    n_in, n1 = program["fc1"].k, program["fc1"].n
    n2 = program["fc2"].n
    relu = p.elem_cycles["relu"]

    def tail(elems):
        """Per-shard relu epilogue: fused into the dequeue or digital."""
        if fuse_epilogue:
            return {"epilogue_fn": "relu", "epilogue_elems": elems}
        return {"digital_cycles": elems * relu}

    if cores == 1:
        shards = [Shard("fc1", 0, 0, load_bytes=n_in, **tail(n1)),
                  Shard("fc2", 0, 1, store_bytes=n2, **tail(n2))]
    elif cores == 2:
        shards = [Shard("fc1", 0, 0, load_bytes=n_in, **tail(n1)),
                  Shard("fc2", 1, 1, comm_in_bytes=n1, comm_events=1,
                        store_bytes=n2, **tail(n2))]
    elif cores == 4:
        h1, h2 = n1 // 2, n2 // 2
        shards = [
            Shard("fc1", 0, 0, cols=((0, h1),), load_bytes=n_in, **tail(h1)),
            Shard("fc1", 1, 0, cols=((h1, n1),), comm_in_bytes=n_in,
                  comm_events=1, **tail(n1 - h1)),
            Shard("fc2", 2, 1, cols=((0, h2),), comm_in_bytes=n1,
                  comm_events=2, store_bytes=h2, **tail(h2)),
            Shard("fc2", 3, 1, cols=((h2, n2),), comm_in_bytes=n1,
                  comm_events=2, store_bytes=n2 - h2, **tail(n2 - h2)),
        ]
    else:
        raise ValueError(f"MLP mappings exist for 1/2/4 cores, not {cores}")
    suffix = "_fused" if fuse_epilogue else ""
    return CoreSchedule(program, shards, name=f"mlp_{cores}c{suffix}")


def _lstm_cell_cycles(nh: int, frac: float = 1.0, p=CALIB) -> float:
    """Digital cycles of the nine linear-complexity cell ops (§VIII-D),
    matching `workloads._lstm_cell_elemwise`."""
    m = int(nh * frac)
    ec = p.elem_cycles
    return (3 * m * ec["sigmoid"] + m * ec["tanh"] + 2 * m * ec["mul"]
            + m * ec["add"] + m * ec["tanh"] + m * ec["mul"])


def lstm_schedule(program: AimcProgram, cores: int, nh: int,
                  x_dim: int = 50, y_dim: int = 50,
                  p=CALIB) -> CoreSchedule:
    """The paper's LSTM analog mappings (Table II-B) over entries
    cell ([h,x] -> 4 gates side by side) and dense.

    cores=1 -> case 1/2 (everything one core); cores=2 -> case 3 (cell core
    + dense core); cores=5 -> case 4 (cell gate-sliced over four cores —
    each takes one column stripe of EVERY gate, exchanges h stripes
    all-to-all for the recurrence — plus a dense core)."""
    soft = p.elem_cycles["softmax"] * y_dim
    if cores == 1:
        shards = [Shard("cell", 0, 0, load_bytes=x_dim,
                        digital_cycles=_lstm_cell_cycles(nh, p=p)),
                  Shard("dense", 0, 1, store_bytes=y_dim,
                        digital_cycles=soft)]
    elif cores == 2:
        shards = [Shard("cell", 0, 0, load_bytes=x_dim,
                        digital_cycles=_lstm_cell_cycles(nh, p=p)),
                  Shard("dense", 1, 1, comm_in_bytes=nh, comm_events=1,
                        store_bytes=y_dim, digital_cycles=soft)]
    elif cores == 5:
        q = 4
        if nh % q:
            raise ValueError(f"gate slicing needs nh % {q} == 0, got {nh}")
        sl = nh // q
        shards = [
            Shard("cell", j, 0,
                  cols=tuple((g * nh + j * sl, g * nh + (j + 1) * sl)
                             for g in range(4)),
                  load_bytes=x_dim,
                  comm_in_bytes=(q - 1) * sl,       # h stripes from peers
                  comm_out_bytes=sl,                # own h stripe broadcast
                  comm_events=q,                    # q-1 in + 1 out
                  digital_cycles=_lstm_cell_cycles(nh, 1 / q, p=p))
            for j in range(q)
        ]
        shards.append(Shard("dense", q, 1, comm_in_bytes=nh, comm_events=1,
                            store_bytes=y_dim, digital_cycles=soft))
    else:
        raise ValueError(f"LSTM mappings exist for 1/2/5 cores, not {cores}")
    return CoreSchedule(program, shards, name=f"lstm_{cores}c")


def cnn_schedule(program: AimcProgram, convs: Sequence[tuple],
                 img: int = 224, p=CALIB) -> CoreSchedule:
    """The paper's pipelined CNN mapping (§IX-A): conv layer i on core i as
    pipeline stage i, feature maps handed core-to-core. ``convs`` is the
    `models.paper_nets.CNN_SPECS` row: (cin, k, cout, stride, pad, lrn,
    pool) per layer; output-position counts derive from `img`. The dense
    head stays digital (paper §IX-A) and is not part of this schedule."""
    shards = []
    ec = p.elem_cycles
    hw, c_prev = img, convs[0][0]
    for i, (cin, k, cout, stride, pad, lrn, pool) in enumerate(convs):
        out_hw = (hw + 2 * pad - k) // stride + 1
        in_bytes = hw * hw * c_prev
        elems = out_hw * out_hw * cout
        cycles = elems * ec["relu"]
        if lrn:
            cycles += elems * ec["lrn"]
        if pool > 1:
            cycles += elems * ec["maxpool"]
        shards.append(Shard(
            f"conv{i}", core=i, phase=i, count=out_hw * out_hw,
            load_bytes=in_bytes if i == 0 else 0,
            comm_in_bytes=0 if i == 0 else in_bytes,
            comm_events=0 if i == 0 else 1,
            digital_cycles=cycles))
        hw, c_prev = out_hw // pool, cout
    return CoreSchedule(program, shards, pipelined=True,
                        name=f"cnn_{len(convs)}stage")
