"""Paper workloads as cost-model IR (paper §VII-IX: Figs. 6, 9, 12).

Builders return ``{case_name: Workload}`` dicts covering every digital and
AIMC-mapped case of the three exploration studies, plus the loose-coupling
variant of §VII-B. The executable-JAX twins of these networks live in
``models/paper_nets.py``; this module is the timing/energy view.

Phase structure: stages inside one phase run on different cores in parallel
(column-split layers); phases chain sequentially per inference. The CNN uses
fine-grained position-level pipelining instead (``pipelined=True``).
"""

from __future__ import annotations

from repro.core.costmodel import Op, Stage, Workload

INT8 = 1  # bytes per weight/activation element (paper uses int8_t end-to-end)


# ---------------------------------------------------------------------------
# Exploration one: MLP (1024, 1024), ReLU (paper Fig. 6)
# ---------------------------------------------------------------------------

def mlp_workloads(n: int = 1024) -> dict[str, Workload]:
    w_bytes = 2 * n * n * INT8
    act = 3 * n * INT8
    half = n // 2

    def digital(cores: int) -> Workload:
        if cores == 1:
            ops = (Op("load", bytes=n),
                   Op("mvm", k=n, n=n), Op("elemwise", fn="relu", elems=n),
                   Op("mvm", k=n, n=n), Op("elemwise", fn="relu", elems=n),
                   Op("store", bytes=n))
            phases = ((Stage(ops, weights_bytes=w_bytes, act_bytes=act),),)
        elif cores == 2:
            phases = (
                (Stage((Op("load", bytes=n), Op("mvm", k=n, n=n),
                        Op("elemwise", fn="relu", elems=n)),
                       weights_bytes=n * n, act_bytes=2 * n),),
                (Stage((Op("comm", bytes=n), Op("mvm", k=n, n=n),
                        Op("elemwise", fn="relu", elems=n), Op("store", bytes=n)),
                       weights_bytes=n * n, act_bytes=2 * n),),
            )
        else:  # 4 cores: each layer column-split across two cores
            l1 = tuple(
                Stage((Op("load", bytes=n) if i == 0 else Op("comm", bytes=n),
                       Op("mvm", k=n, n=half),
                       Op("elemwise", fn="relu", elems=half)),
                      weights_bytes=n * half, act_bytes=2 * n)
                for i in range(2))
            l2 = tuple(
                Stage((Op("comm", bytes=half), Op("comm", bytes=half),
                       Op("mvm", k=n, n=half),
                       Op("elemwise", fn="relu", elems=half),
                       Op("store", bytes=half)),
                      weights_bytes=n * half, act_bytes=2 * n)
                for _ in range(2))
            phases = (l1, l2)
        return Workload(f"mlp_dig_{cores}c", phases)

    def analog(case: int) -> Workload:
        if case in (1, 2):
            # single core, both layers in one tile; case 2 halves the word
            # lines so each MVM needs two CM_PROCESS activations (paper §VII-B)
            tile_rows = n if case == 1 else n // 2
            ops = (Op("load", bytes=n),
                   Op("mvm", k=n, n=n, aimc=True),
                   Op("elemwise", fn="relu", elems=n),
                   Op("mvm", k=n, n=n, aimc=True),
                   Op("elemwise", fn="relu", elems=n),
                   Op("store", bytes=n))
            return Workload(f"mlp_ana_case{case}", ((Stage(ops, act_bytes=act),),),
                            tile_rows=tile_rows)
        if case == 3:  # one layer per core, mutex hand-off between them
            phases = (
                (Stage((Op("load", bytes=n), Op("mvm", k=n, n=n, aimc=True),
                        Op("elemwise", fn="relu", elems=n))),),
                (Stage((Op("comm", bytes=n), Op("mvm", k=n, n=n, aimc=True),
                        Op("elemwise", fn="relu", elems=n), Op("store", bytes=n))),),
            )
            return Workload("mlp_ana_case3", phases, tile_rows=n)
        # case 4: each layer split over two cores; second layer consumes both
        # halves from both producers (two comms + mutexes per consumer).
        l1 = tuple(
            Stage((Op("load", bytes=n) if i == 0 else Op("comm", bytes=n),
                   Op("mvm", k=n, n=half, aimc=True),
                   Op("elemwise", fn="relu", elems=half)))
            for i in range(2))
        l2 = tuple(
            Stage((Op("comm", bytes=half), Op("comm", bytes=half),
                   Op("mvm", k=n, n=half, aimc=True),
                   Op("elemwise", fn="relu", elems=half),
                   Op("store", bytes=half)))
            for _ in range(2))
        return Workload("mlp_ana_case4", (l1, l2), tile_rows=n)

    def analog_fused(case: int) -> Workload:
        """Kernel-v2 fused-epilogue twins of cases 1/3: each relu rides its
        layer's dequeue loop (`Op(..., epilogue="relu")`) instead of running
        as a separate elemwise pass — matches
        `schedule.mlp_schedule(..., fuse_epilogue=True)` op for op."""
        if case == 1:
            ops = (Op("load", bytes=n),
                   Op("mvm", k=n, n=n, aimc=True, epilogue="relu"),
                   Op("mvm", k=n, n=n, aimc=True, epilogue="relu"),
                   Op("store", bytes=n))
            return Workload("mlp_ana_case1_fused", ((Stage(ops, act_bytes=act),),),
                            tile_rows=n)
        phases = (
            (Stage((Op("load", bytes=n),
                    Op("mvm", k=n, n=n, aimc=True, epilogue="relu"))),),
            (Stage((Op("comm", bytes=n),
                    Op("mvm", k=n, n=n, aimc=True, epilogue="relu"),
                    Op("store", bytes=n))),),
        )
        return Workload("mlp_ana_case3_fused", phases, tile_rows=n)

    out = {f"dig_{c}c": digital(c) for c in (1, 2, 4)}
    out |= {f"ana_case{i}": analog(i) for i in (1, 2, 3, 4)}
    out |= {f"ana_case{i}_fused": analog_fused(i) for i in (1, 3)}
    # §VII-B loosely-coupled variant: case-1 mapping over the I/O bus.
    loose = analog(1)
    out["ana_loose"] = Workload("mlp_ana_loose", loose.phases,
                                coupling="loose", tile_rows=n)
    return out


# ---------------------------------------------------------------------------
# Exploration two: LSTM, PTB character model (paper Fig. 9, Table II)
# ---------------------------------------------------------------------------

def _lstm_cell_elemwise(nh: int, frac: float = 1.0) -> tuple[Op, ...]:
    """The nine linear-complexity cell ops (paper §VIII-D): 3 sigmoid gates,
    tanh(g), c = f*c + i*g, tanh(c), h = o*tanh(c)."""
    m = int(nh * frac)
    return (Op("elemwise", fn="sigmoid", elems=3 * m),
            Op("elemwise", fn="tanh", elems=m),
            Op("elemwise", fn="mul", elems=2 * m),
            Op("elemwise", fn="add", elems=m),
            Op("elemwise", fn="tanh", elems=m),
            Op("elemwise", fn="mul", elems=m))


def lstm_workloads(nh: int, x: int = 50, y: int = 50) -> dict[str, Workload]:
    kin = nh + x                      # concatenated [h, x]
    cell_w = 4 * kin * nh * INT8
    dense_w = nh * y * INT8
    act = (kin + nh + y) * INT8
    q = 4                             # cell slices in the quin-core cases

    def digital(cores: int) -> Workload:
        cell_ops = (Op("load", bytes=x), Op("mvm", k=kin, n=4 * nh),
                    *_lstm_cell_elemwise(nh))
        dense_ops = (Op("mvm", k=nh, n=y),
                     Op("elemwise", fn="softmax", elems=y), Op("store", bytes=y))
        if cores == 1:
            return Workload(f"lstm{nh}_dig_1c",
                            ((Stage(cell_ops + dense_ops,
                                    weights_bytes=cell_w + dense_w,
                                    act_bytes=act),),))
        if cores == 2:
            return Workload(f"lstm{nh}_dig_2c", (
                (Stage(cell_ops, weights_bytes=cell_w, act_bytes=act),),
                (Stage((Op("comm", bytes=nh),) + dense_ops,
                       weights_bytes=dense_w, act_bytes=act),)))
        slices = tuple(
            Stage((Op("load", bytes=x),
                   *(Op("comm", bytes=nh // q) for _ in range(q - 1)),  # h feedback
                   Op("mvm", k=kin, n=4 * nh // q),
                   *_lstm_cell_elemwise(nh, 1 / q), Op("comm", bytes=nh // q)),
                  weights_bytes=cell_w // q, act_bytes=act)
            for _ in range(q))
        dense = Stage((Op("comm", bytes=nh),) + dense_ops,
                      weights_bytes=dense_w, act_bytes=act)
        return Workload(f"lstm{nh}_dig_5c", (slices, (dense,)))

    def analog(case: int) -> Workload:
        # paper Table II-(B): case 1 packs cell+dense in one big tile, case 2
        # uses a snugger tile, case 3 splits layers across two cores, case 4
        # gate-slices the cell across four cores + a dense core.
        tile_rows = {1: 2 * kin, 2: kin + 50, 3: kin + 50, 4: kin + 50}[case]
        cell_mvm = Op("mvm", k=kin, n=4 * nh, aimc=True)
        dense_mvm = Op("mvm", k=nh, n=y, aimc=True)
        soft = (Op("elemwise", fn="softmax", elems=y), Op("store", bytes=y))
        if case in (1, 2):
            ops = (Op("load", bytes=x), cell_mvm, *_lstm_cell_elemwise(nh),
                   dense_mvm, *soft)
            return Workload(f"lstm{nh}_ana_case{case}",
                            ((Stage(ops, act_bytes=act),),), tile_rows=tile_rows)
        if case == 3:
            return Workload(f"lstm{nh}_ana_case3", (
                (Stage((Op("load", bytes=x), cell_mvm,
                        *_lstm_cell_elemwise(nh))),),
                (Stage((Op("comm", bytes=nh), dense_mvm, *soft)),)),
                tile_rows=tile_rows)
        # case 4: each cell core queues the full [h, x], dequeues its gate
        # slice; h slices are exchanged all-to-all for the recurrence.
        slices = tuple(
            Stage((Op("load", bytes=x),
                   *(Op("comm", bytes=nh // q) for _ in range(q - 1)),  # h feedback
                   Op("mvm", k=kin, n=4 * nh // q, aimc=True),
                   *_lstm_cell_elemwise(nh, 1 / q), Op("comm", bytes=nh // q)))
            for _ in range(q))
        dense = Stage((Op("comm", bytes=nh), dense_mvm, *soft))
        return Workload(f"lstm{nh}_ana_case4", (slices, (dense,)),
                        tile_rows=tile_rows)

    out = {f"dig_{c}c": digital(c) for c in (1, 2, 5)}
    out |= {f"ana_case{i}": analog(i) for i in (1, 2, 3, 4)}
    return out


# ---------------------------------------------------------------------------
# Exploration three: CNN-F/M/S (paper Fig. 12, Chatfield et al. [42])
# ---------------------------------------------------------------------------

# (cin, ksize, cout, out_hw, lrn, pool_out_hw) per conv layer; dense dims.
_CNN_SPECS = {
    "F": dict(convs=[(3, 11, 64, 54, True, 27), (64, 5, 256, 27, True, 13),
                     (256, 3, 256, 13, False, 13), (256, 3, 256, 13, False, 13),
                     (256, 3, 256, 13, False, 6)],
              dense=[(6 * 6 * 256, 4096), (4096, 4096), (4096, 1000)]),
    "M": dict(convs=[(3, 7, 96, 109, True, 54), (96, 5, 256, 52, True, 26),
                     (256, 3, 512, 26, False, 26), (512, 3, 512, 26, False, 26),
                     (512, 3, 512, 26, False, 13)],
              dense=[(13 * 13 * 512, 4096), (4096, 4096), (4096, 1000)]),
    "S": dict(convs=[(3, 7, 96, 109, True, 36), (96, 5, 256, 34, True, 17),
                     (256, 3, 512, 17, False, 17), (512, 3, 512, 17, False, 17),
                     (512, 3, 512, 17, False, 5)],
              dense=[(5 * 5 * 512, 4096), (4096, 4096), (4096, 1000)]),
}


def cnn_workloads(variant: str) -> dict[str, Workload]:
    spec = _CNN_SPECS[variant]

    def build(aimc: bool) -> Workload:
        stages = []
        prev_hw, prev_c = 224, 3
        for i, (cin, k, cout, hw, lrn, pool_hw) in enumerate(spec["convs"]):
            kdim = k * k * cin
            ops = []
            if i == 0:
                ops.append(Op("load", bytes=224 * 224 * 3))
            else:
                ops.append(Op("comm", bytes=prev_hw * prev_hw * prev_c))
            ops.append(Op("mvm", k=kdim, n=cout, count=hw * hw,
                          aimc=aimc, conv=True))
            ops.append(Op("elemwise", fn="relu", elems=hw * hw * cout))
            if lrn:
                ops.append(Op("elemwise", fn="lrn", elems=hw * hw * cout))
            if pool_hw != hw:
                ops.append(Op("elemwise", fn="maxpool", elems=hw * hw * cout))
            stages.append(Stage(
                tuple(ops),
                weights_bytes=0 if aimc else kdim * cout * INT8,
                act_bytes=(prev_hw * prev_hw * prev_c + hw * hw * cout) * INT8))
            prev_hw, prev_c = pool_hw, cout
        # dense layers: digital in BOTH mappings (paper §IX-A)
        for j, (kin, nout) in enumerate(spec["dense"]):
            ops = [Op("comm", bytes=kin if j == 0 else 0),
                   Op("mvm", k=kin, n=nout),
                   Op("elemwise", fn="softmax" if j == 2 else "relu", elems=nout)]
            if j == 2:
                ops.append(Op("store", bytes=nout))
            stages.append(Stage(tuple(ops), weights_bytes=kin * nout * INT8,
                                act_bytes=(kin + nout) * INT8))
        name = f"cnn{variant}_{'ana' if aimc else 'dig'}"
        phases = tuple((s,) for s in stages)
        return Workload(name, phases, pipelined=True, tile_rows=1024)

    return {"dig": build(False), "ana": build(True)}
