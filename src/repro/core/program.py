"""Program-once / apply-many: the model-level AIMC programming API.

The paper's deployment model (§IV-B, Fig. 4) is weights-stationary: matrices
are programmed onto crossbars ONCE (CM_INITIALIZE, outside the inference
region of interest); inference afterwards is pure queue/process/dequeue
traffic. This module makes that split first-class for whole models:

  * ``MappingPlan``   — declares WHICH projections map to crossbars (name /
    path patterns, per-layer predicate, minimum size) and WHERE (round-robin
    over ``n_contexts`` cores, capacity-checked against `tile.TileAllocator`).
  * ``program_model(params, plan, cfg, key)`` — walks a parameter pytree,
    programs every selected weight (stacked layer/expert dims included) and
    returns an ``AimcProgram``.
  * ``AimcProgram``   — a jit-friendly, shardable pytree registry mapping
    param-tree paths -> `AimcLinearState`. ``program.install(params)``
    substitutes the programmed states into the parameter tree, after which
    every ``models.layers.linear`` call transparently runs the apply-only
    path (CM_QUEUE/PROCESS/DEQUEUE) — no re-programming on the hot path.
    The program also carries the static CM_* accounting: CM_INITIALIZE totals
    (paid once) and per-forward MVM instruction counts, consumed by
    ``launch.serve`` stats and the benchmarks.
  * ``ProgramBuilder`` — the incremental surface underneath both
    ``program_model`` and ``aimclib.AimcContext`` (one builder context per
    core, paper Fig. 2).

Training is unchanged: without an installed program, ``Execution(mode="aimc")``
keeps the on-the-fly STE path (noise-aware training).

Public surface: `MappingPlan`, `program_model`, `AimcProgram`
(`install`, `install_shape`, `initialize_counts`, `mvm_counts`, placement
stats), `ProgramBuilder`, `TilePool` (one shared crossbar budget several
co-programmed models draw from — the multi-tenant server's capacity
authority), `CapacityError`.

Invariants (pinned by tests/test_program.py): programming + apply
reproduces the seed's `aimc_linear_ste` bit-for-bit under the same keys;
CM_* counts are pure functions of mapped shapes (no instrumentation inside
jit); `install` replaces ONLY plan-selected leaves and is idempotent over
already-installed trees; an `AimcProgram` crosses jit boundaries, shards
and donates like any parameter tree (all bookkeeping is static aux data).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import isa
from repro.core import noise as noise_lib
from repro.core.aimc import (AimcConfig, AimcLinearState, program_linear,
                             program_stacked)
from repro.core.tile import TileAllocator, TileMap


class CapacityError(RuntimeError):
    """A MappingPlan asked for more crossbar tiles than a context provides."""


# ---------------------------------------------------------------------------
# TilePool — one shared crossbar budget, many co-programmed models
# ---------------------------------------------------------------------------

class TilePool:
    """A shared multi-context crossbar budget several programs draw from.

    One accelerator pool, many co-resident models (the multi-tenant server,
    DESIGN.md §12): every ``program_model(..., pool=..., label=...)`` call
    packs its matrices into THE SAME per-context `TileAllocator`s, so
    capacity is checked against the sum of everything programmed so far —
    two models that fit individually but not together raise `CapacityError`
    instead of silently overlapping crossbar tiles. Matrix ids are
    label-prefixed (``label/path``) so the shared placement table stays
    unambiguous; re-using a label for a second program raises.

    A failed co-program leaves its partial placements charged to the pool
    (shelf packing has no rollback); treat `CapacityError` during server
    bring-up as fatal and rebuild the pool.
    """

    def __init__(self, cfg: AimcConfig, n_contexts: int = 1,
                 tiles_per_context: int | None = None):
        if n_contexts < 1:
            raise ValueError("n_contexts must be >= 1")
        self.cfg = cfg
        self.tiles_per_context = tiles_per_context
        self.allocators = [TileAllocator(cfg.tile_rows, cfg.tile_cols)
                           for _ in range(n_contexts)]
        self.labels: list[str] = []            # programs resident, in order

    @property
    def n_contexts(self) -> int:
        return len(self.allocators)

    @property
    def n_tiles(self) -> int:
        """Physical tiles opened across all contexts so far."""
        return sum(a.n_tiles for a in self.allocators)

    @property
    def capacity_tiles(self) -> int | None:
        return (None if self.tiles_per_context is None
                else self.tiles_per_context * self.n_contexts)

    @property
    def utilization(self) -> float:
        """Used crossbar cells / capacity cells (opened tiles if uncapped)."""
        used = sum(p.rows * p.cols for a in self.allocators
                   for p in a.placements)
        tiles = self.capacity_tiles or self.n_tiles
        total = tiles * self.cfg.tile_rows * self.cfg.tile_cols
        return used / total if total else 0.0

    def placements(self):
        """Every placement across the pool (for overlap/ownership audits)."""
        return tuple(p for a in self.allocators for p in a.placements)

    def claim(self, label: str):
        if label in self.labels:
            raise ValueError(f"program label {label!r} already resident in "
                             f"the pool (labels: {self.labels})")
        self.labels.append(label)

    def summary(self) -> str:
        cap = (f"/{self.capacity_tiles}" if self.capacity_tiles is not None
               else "")
        return (f"TilePool: {len(self.labels)} program(s) "
                f"({', '.join(self.labels) or 'none'}) on {self.n_tiles}"
                f"{cap} tiles across {self.n_contexts} context(s), "
                f"utilization {self.utilization:.0%}")


# ---------------------------------------------------------------------------
# MappingPlan — which projections go to crossbars, and where
# ---------------------------------------------------------------------------

# Stationary-projection naming convention across the model zoo. Everything a
# model routes through `layers.linear` matches one of these; embeddings, the
# vocab matmul, norms/biases/gains, depthwise conv kernels and the sLSTM
# recurrent block-diagonals stay digital (DESIGN.md §4 applicability
# boundary). The MoE router is excluded explicitly: it is tiny and feeds
# top-k control flow, which the paper keeps on the CPU.
DEFAULT_INCLUDE = (r"w[qkvo]", r"w_\w+", r"we_\w+", r"wd_\w+", r"c[qkvo]")
DEFAULT_EXCLUDE = (r"router", r"embed", r"unembed", r"conv_\w+", r"lam",
                   r"r_zifo", r"b_\w+")


@dataclasses.dataclass(frozen=True)
class MappingPlan:
    """Declarative crossbar mapping policy (hashable; jit-static friendly).

    ``include``/``exclude`` are regex patterns, full-matched against the leaf
    name (last pytree key) — or against the whole ``/``-joined path when the
    pattern contains a ``/``. ``predicate``, when given, has the final word:
    it receives ``(path, shape)`` for every pattern-selected leaf and can veto
    per layer/projection. ``n_contexts`` spreads matrices over several
    per-core tile sets (paper Fig. 2, multi-context placement), least-loaded
    first; ``tiles_per_context`` bounds each context's capacity.
    """

    include: tuple[str, ...] = DEFAULT_INCLUDE
    exclude: tuple[str, ...] = DEFAULT_EXCLUDE
    predicate: Callable[[str, tuple[int, ...]], bool] | None = None
    min_features: int = 1          # skip matrices with K or N below this
    n_contexts: int = 1
    tiles_per_context: int | None = None

    def __post_init__(self):
        if self.n_contexts < 1:
            raise ValueError("n_contexts must be >= 1")

    def _matches(self, patterns: tuple[str, ...], path: str, name: str) -> bool:
        for pat in patterns:
            target = path if "/" in pat else name
            if re.fullmatch(pat, target):
                return True
        return False

    @staticmethod
    def for_names(names, *, n_contexts: int = 1,
                  tiles_per_context: int | None = None) -> "MappingPlan":
        """A plan selecting EXACTLY the given tree paths — the form
        `core.placement` emits once the search has chosen the analog set.

        Each path becomes a fully-escaped include pattern matched against
        the whole ``/``-joined path (slash-free top-level paths get an
        optional-slash prefix so `selects` still full-path-matches them);
        the exclude list is empty, so membership is literal."""
        pats = tuple(re.escape(p) if "/" in p else "/?" + re.escape(p)
                     for p in names)
        return MappingPlan(include=pats, exclude=(), n_contexts=n_contexts,
                           tiles_per_context=tiles_per_context)

    def selects(self, path: str, shape: tuple[int, ...]) -> bool:
        """Should the float leaf at `path` (full stacked shape) be mapped?"""
        if len(shape) < 2:
            return False
        name = path.rsplit("/", 1)[-1]
        if not self._matches(self.include, path, name):
            return False
        if self._matches(self.exclude, path, name):
            return False
        k, n = shape[-2], shape[-1]
        if min(k, n) < self.min_features:
            return False
        if self.predicate is not None and not self.predicate(path, shape):
            return False
        return True


# ---------------------------------------------------------------------------
# ProgramBuilder — incremental programming + tile allocation
# ---------------------------------------------------------------------------

class ProgramBuilder:
    """Programs matrices one by one, packing tiles per context.

    Runs at setup time (plain Python over static shapes) — never inside jit.
    Placement is least-loaded-context first; `tiles_per_context` turns the
    allocator into a hard capacity check.

    With ``pool`` (a `TilePool`), the builder packs into the pool's SHARED
    allocators instead of fresh ones — capacity is then checked against
    everything already resident (co-programming, DESIGN.md §12). Allocator
    matrix ids are prefixed ``label/`` so the shared placement table keeps
    per-program ownership; the built program's `tile_maps` carry only this
    program's placements (pool-level stats live on the pool).
    """

    def __init__(self, cfg: AimcConfig, n_contexts: int = 1,
                 tiles_per_context: int | None = None,
                 pool: TilePool | None = None, label: str = ""):
        self.cfg = cfg
        self.pool = pool
        self.label = label
        if pool is not None:
            if (pool.cfg.tile_rows, pool.cfg.tile_cols) != (cfg.tile_rows,
                                                            cfg.tile_cols):
                raise ValueError(
                    f"pool tiles {pool.cfg.tile_rows}x{pool.cfg.tile_cols} "
                    f"!= program tiles {cfg.tile_rows}x{cfg.tile_cols}")
            pool.claim(label or f"program{len(pool.labels)}")
            self.label = self.label or pool.labels[-1]
            self.tiles_per_context = pool.tiles_per_context
            self._allocs = pool.allocators
        else:
            self.tiles_per_context = tiles_per_context
            self._allocs = [TileAllocator(cfg.tile_rows, cfg.tile_cols)
                            for _ in range(n_contexts)]
        self._entries: dict[str, AimcLinearState] = {}
        self._context_of: dict[str, int] = {}

    # -- placement ----------------------------------------------------------
    def _matrix_id(self, name: str) -> str:
        """The allocator-facing id: label-prefixed when pooled, so two
        co-programmed models never collide in the shared placement table."""
        return f"{self.label}/{name}" if self.pool is not None else name

    def _pick_context(self) -> int:
        return min(range(len(self._allocs)),
                   key=lambda i: self._allocs[i].n_tiles)

    def _place(self, name: str, desc: str, place) -> int:
        """One placement path for every tenant kind: pick the least-loaded
        context, run `place(alloc)` against its allocator, capacity-check,
        record. Keeps matrix and gate placement policy identical."""
        ctx = self._pick_context()
        alloc = self._allocs[ctx]
        place(alloc)
        if (self.tiles_per_context is not None
                and alloc.n_tiles > self.tiles_per_context):
            resident = (f" (co-resident programs: "
                        f"{', '.join(self.pool.labels)})"
                        if self.pool is not None and self.pool.labels
                        else "")
            raise CapacityError(
                f"mapping {desc} overflows context {ctx}: "
                f"{alloc.n_tiles} tiles > cap {self.tiles_per_context}"
                + resident)
        self._context_of[name] = ctx
        return ctx

    def _allocate(self, name: str, k: int, n: int, instances: int) -> int:
        mid = self._matrix_id(name)

        def place(alloc):
            for i in range(instances):
                inst = mid if instances == 1 else f"{mid}[{i}]"
                alloc.map_matrix(inst, k, n)

        return self._place(name, f"{name!r} ({instances}x[{k}x{n}])", place)

    # -- programming (CM_INITIALIZE) ----------------------------------------
    def add(self, name: str, w: jnp.ndarray,
            key: jax.Array | None = None) -> AimcLinearState:
        """Program one (possibly stacked [..., K, N]) weight matrix."""
        if name in self._entries:
            raise ValueError(f"matrix {name!r} already mapped")
        w = jnp.asarray(w)
        if w.ndim < 2:
            raise ValueError(f"matrix {name!r} must be at least 2-D")
        instances = 1
        for d in w.shape[:-2]:
            instances *= d
        self._allocate(name, w.shape[-2], w.shape[-1], instances)
        state = program_stacked(w, self.cfg, key)
        self._entries[name] = state
        return state

    def add_gates(self, name: str, gates: Sequence[jnp.ndarray],
                  key: jax.Array | None = None) -> AimcLinearState:
        """Place same-height gate matrices side by side — one queue + one
        CM_PROCESS serves all of them (the paper's LSTM trick, §VIII-D)."""
        if name in self._entries:
            raise ValueError(f"matrix {name!r} already mapped")
        rows = gates[0].shape[0]
        if any(g.shape[0] != rows for g in gates):
            raise ValueError("gate matrices must share in_features")
        mid = self._matrix_id(name)
        self._place(
            name, f"gates {name!r} ({len(gates)}x[{rows}x{gates[0].shape[1]}])",
            lambda alloc: alloc.map_side_by_side(
                [f"{mid}.g{i}" for i in range(len(gates))],
                rows, gates[0].shape[1]))
        w = jnp.concatenate([jnp.asarray(g) for g in gates], axis=1)
        state = program_linear(w, self.cfg, key)
        self._entries[name] = state
        return state

    # -- finalize -----------------------------------------------------------
    def _own_tile_maps(self) -> tuple[TileMap, ...]:
        """Per-context tile maps restricted to THIS program's placements.

        Pooled builders share allocators with co-resident programs, so a
        raw ``finalize()`` would claim foreign placements; filter by the
        label prefix and count only the tiles this program touches."""
        prefix = f"{self.label}/"
        maps = []
        for alloc in self._allocs:
            own = tuple(p for p in alloc.placements
                        if p.matrix_id.startswith(prefix))
            maps.append(TileMap(
                tile_rows=self.cfg.tile_rows, tile_cols=self.cfg.tile_cols,
                placements=own,
                n_tiles=len({p.tile_id for p in own})))
        return tuple(maps)

    def build(self) -> "AimcProgram":
        names = tuple(sorted(self._entries))
        tile_maps = (self._own_tile_maps() if self.pool is not None
                     else tuple(a.finalize() for a in self._allocs))
        return AimcProgram(
            states=tuple(self._entries[n] for n in names),
            names=names,
            cfg=self.cfg,
            contexts=tuple(self._context_of[n] for n in names),
            tile_maps=tile_maps,
        )


# ---------------------------------------------------------------------------
# AimcProgram — the registry pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class AimcProgram:
    """Path -> programmed-state registry; pytree (states are the children).

    Shardable/donatable like any parameter tree; all bookkeeping (names,
    contexts, tile maps, the programming config) is static aux data, so a
    program can cross a jit boundary or be closed over by one.
    """

    def __init__(self, states: tuple[AimcLinearState, ...],
                 names: tuple[str, ...], cfg: AimcConfig,
                 contexts: tuple[int, ...], tile_maps: tuple[TileMap, ...],
                 t_programmed: tuple[float, ...] | None = None):
        self.states = tuple(states)
        self.names = tuple(names)
        self.cfg = cfg
        self.contexts = tuple(contexts)
        self.tile_maps = tuple(tile_maps)
        # program-age clock: per-matrix programming instant on the SERVE
        # clock (seconds). Fresh builds are all-zero; hot reprogramming
        # stamps the recal instant, which restarts that matrix's drift law.
        self.t_programmed = (tuple(0.0 for _ in self.names)
                             if t_programmed is None else tuple(t_programmed))
        if len(self.t_programmed) != len(self.names):
            raise ValueError("t_programmed must have one entry per matrix")

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return self.states, (self.names, self.cfg, self.contexts,
                             self.tile_maps, self.t_programmed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, cfg, contexts, tile_maps, t_programmed = aux
        return cls(tuple(children), names, cfg, contexts, tile_maps,
                   t_programmed)

    # -- mapping ------------------------------------------------------------
    @property
    def entries(self) -> dict[str, AimcLinearState]:
        return dict(zip(self.names, self.states))

    def __contains__(self, path: str) -> bool:
        return path in self.names

    def __getitem__(self, path: str) -> AimcLinearState:
        try:
            return self.states[self.names.index(path)]
        except ValueError:
            raise KeyError(f"matrix {path!r} was never mapped") from None

    def __len__(self) -> int:
        return len(self.names)

    def install(self, params):
        """Substitute programmed states into a parameter tree.

        Mapped leaves are replaced by their `AimcLinearState`; everything
        else passes through untouched. The result is what serving code feeds
        the model: `layers.linear` dispatches on the state type, so every
        zoo model runs apply-only AIMC with zero model-code changes."""
        entries = self.entries
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=_is_quantized_leaf)
        leaves = [entries.get(_path_key(path), leaf) for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def install_shape(self, params_shape):
        """`install` over a ShapeDtypeStruct tree (for lowering/dry-runs)."""
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self)
        entries = dict(zip(self.names, abstract.states))
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params_shape, is_leaf=_is_quantized_leaf)
        leaves = [entries.get(_path_key(path), leaf) for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def install_subset(self, params, names):
        """`install`, restricted to ``names``: only those matrices' states
        replace their raw leaves; every other mapped weight STAYS digital.

        This is the rotation substrate (core.placement, DESIGN.md §16): one
        uncapped program holds every layer that ever goes analog, and each
        time-multiplexed rotation state is an `install_subset` over its
        resident hot + cold-group names — same keyspace, same states, so a
        layer computes identically in every state that carries it. Unknown
        names raise (a silently-skipped name would serve digital while the
        swap books bill analog reprogramming)."""
        names = set(names)
        unknown = names - set(self.names)
        if unknown:
            raise KeyError(f"install_subset: unmapped matrices "
                           f"{sorted(unknown)}")
        entries = {n: st for n, st in zip(self.names, self.states)
                   if n in names}
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=_is_quantized_leaf)
        leaves = [entries.get(_path_key(path), leaf) for path, leaf in flat]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def install_updates(self, params, entries: dict[str, AimcLinearState]):
        """Substitute REFRESHED states into an already-installed tree.

        ``install`` is a no-op over installed trees (the state's children
        flatten to sub-paths that match nothing); this is the companion that
        replaces whole `AimcLinearState` nodes by their original path — the
        mechanism behind online drift refresh and hot reprogramming. Every
        update has the same shapes/treedef as what it replaces, so jitted
        closures over the result never recompile. An entry whose path does
        not exist in the tree raises — a silently dropped update would mean
        serving stale states while the books charge for fresh ones."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=_is_installed_or_quantized_leaf)
        unused = set(entries)
        leaves = []
        for path, leaf in flat:
            pkey = _path_key(path)
            if isinstance(leaf, AimcLinearState) and pkey in entries:
                leaves.append(entries[pkey])
                unused.discard(pkey)
            else:
                leaves.append(leaf)
        if unused:
            raise KeyError(f"install_updates: no installed state at "
                           f"{sorted(unused)}")
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # -- program-age clock + drift views (runtime.health's substrate) -------
    def ages(self, t_now: float) -> dict[str, float]:
        """Seconds since each matrix was (re)programmed, on the serve clock."""
        return {n: t_now - t0 for n, t0 in zip(self.names, self.t_programmed)}

    def drift_gains(self, t_now: float,
                    nm: noise_lib.NoiseModel | None = None,
                    seed: int = 0) -> dict[str, float]:
        """Per-matrix conductance decay gain at serve time ``t_now``.

        The power law runs per matrix off its own program age, with the
        exponent drawn per CORE (`NoiseModel.per_core_nu`) — physically, the
        matrices of one context share a crossbar's material batch."""
        nm = self.cfg.noise if nm is None else nm
        out = {}
        for name, t0, ctx in zip(self.names, self.t_programmed, self.contexts):
            out[name] = nm.drift_gain_at(t_now - t0, nm.per_core_nu(ctx, seed))
        return out

    def aged_entries(self, t_now: float,
                     nm: noise_lib.NoiseModel | None = None,
                     seed: int = 0) -> dict[str, AimcLinearState]:
        """Drift-decayed views of the programmed states at ``t_now``.

        Decay scales the effective output scale (`with_gain`) — codes and
        pytree structure are untouched, so the result feeds straight into
        `install_updates`. Empty when nothing has drifted."""
        gains = self.drift_gains(t_now, nm, seed)
        if all(g == 1.0 for g in gains.values()):
            return {}
        return {n: st.with_gain(gains[n])
                for n, st in zip(self.names, self.states)}

    def reprogrammed(self, entries: dict[str, AimcLinearState],
                     t_now: float) -> "AimcProgram":
        """Hot reprogram: swap in freshly-programmed states for ``entries``
        and stamp their program age to ``t_now`` (their drift law restarts).
        The CM_INITIALIZE cost is the caller's to charge — see
        `runtime.health.Recalibrator`, which never swaps silently."""
        unknown = set(entries) - set(self.names)
        if unknown:
            raise KeyError(f"reprogrammed: unmapped matrices {sorted(unknown)}")
        states = tuple(entries.get(n, st)
                       for n, st in zip(self.names, self.states))
        ages = tuple(t_now if n in entries else t0
                     for n, t0 in zip(self.names, self.t_programmed))
        return AimcProgram(states, self.names, self.cfg, self.contexts,
                           self.tile_maps, t_programmed=ages)

    def remap_context(self, dead: int) -> "AimcProgram":
        """Survivor placement after losing context (core) ``dead``.

        Every matrix resident on the dead context is re-packed onto the
        least-loaded SURVIVING context on fresh spare tiles (appended after
        the survivor's existing tiles — the dead crossbars are retired, not
        reused), and the dead context's tile map empties. States are
        unchanged: the caller must reprogram the moved matrices
        (`reprogrammed`) since their conductances live on new physical
        tiles. MVM counts are shape-only, so `mvm_counts()` — and therefore
        ledger reconciliation — is invariant under the remap."""
        n_ctx = len(self.tile_maps)
        if not 0 <= dead < n_ctx:
            raise ValueError(f"context {dead} out of range 0..{n_ctx - 1}")
        if n_ctx < 2:
            raise CapacityError(
                "remap_context: no surviving context to drain onto")
        moved = [i for i, c in enumerate(self.contexts) if c == dead]
        if not moved:
            return self
        survivors = [c for c in range(n_ctx) if c != dead]
        extra = {c: TileAllocator(self.cfg.tile_rows, self.cfg.tile_cols)
                 for c in survivors}
        contexts = list(self.contexts)
        for i in moved:
            st = self.states[i]
            ctx = min(survivors,
                      key=lambda c: self.tile_maps[c].n_tiles + extra[c].n_tiles)
            for j in range(st.instances):
                inst = (self.names[i] if st.instances == 1
                        else f"{self.names[i]}[{j}]")
                extra[ctx].map_matrix(inst, st.k, st.n)
            contexts[i] = ctx
        tile_maps = []
        for c in range(n_ctx):
            tm = self.tile_maps[c]
            if c == dead:
                tile_maps.append(dataclasses.replace(
                    tm, placements=(), n_tiles=0))
            elif extra[c].n_tiles:
                new = extra[c].finalize()
                shifted = tuple(dataclasses.replace(p, tile_id=p.tile_id
                                                    + tm.n_tiles)
                                for p in new.placements)
                tile_maps.append(dataclasses.replace(
                    tm, placements=tm.placements + shifted,
                    n_tiles=tm.n_tiles + new.n_tiles))
            else:
                tile_maps.append(tm)
        return AimcProgram(self.states, self.names, self.cfg, tuple(contexts),
                           tuple(tile_maps), t_programmed=self.t_programmed)

    def reprogram_counts(self, names) -> isa.CmCounts:
        """CM_INITIALIZE for reprogramming just ``names`` — the extra device
        writes a hot recalibration charges on top of `initialize_counts`."""
        return isa.total(
            isa.initialize_counts(st.k, st.n).scaled(st.instances)
            for n, st in zip(self.names, self.states) if n in set(names))

    # -- CM_* accounting (static: shapes fully determine the counts) --------
    def initialize_counts(self) -> isa.CmCounts:
        """CM_INITIALIZE for the whole program — paid once per session."""
        return isa.total(
            isa.initialize_counts(st.k, st.n).scaled(st.instances)
            for st in self.states)

    def mvm_counts(self, times: int = 1) -> isa.CmCounts:
        """Queue/process/dequeue counts for `times` token vectors pushed
        through the whole program (every mapped instance fires once each)."""
        return isa.total(
            isa.mvm_counts(st.k, st.n, self.cfg.tile_rows).scaled(st.instances)
            for st in self.states).scaled(times)

    # -- placement stats ----------------------------------------------------
    @property
    def n_matrices(self) -> int:
        return sum(st.instances for st in self.states)

    @property
    def n_tiles(self) -> int:
        return sum(tm.n_tiles for tm in self.tile_maps)

    @property
    def utilization(self) -> float:
        used = sum(p.rows * p.cols for tm in self.tile_maps
                   for p in tm.placements)
        total = self.n_tiles * self.cfg.tile_rows * self.cfg.tile_cols
        return used / total if total else 0.0

    def summary(self) -> str:
        init = self.initialize_counts()
        per_fwd = self.mvm_counts()
        return (f"AimcProgram: {len(self.names)} weights "
                f"({self.n_matrices} crossbar tenants) on {self.n_tiles} "
                f"tiles across {len(self.tile_maps)} context(s), "
                f"utilization {self.utilization:.0%}; "
                f"CM_INITIALIZE {init.initialize} (once), per token vector "
                f"queue/process/dequeue {per_fwd.queue}/{per_fwd.process}/"
                f"{per_fwd.dequeue}")

    def __repr__(self) -> str:
        return f"<{self.summary()}>"


# ---------------------------------------------------------------------------
# program_model — the one-call front door
# ---------------------------------------------------------------------------

def program_model(params, plan: MappingPlan | None, cfg: AimcConfig,
                  key: jax.Array | None = None, *,
                  pool: TilePool | None = None,
                  label: str = "") -> AimcProgram:
    """CM_INITIALIZE an entire model: program every plan-selected weight.

    ``params`` is any parameter pytree (raw float weights, or the int8
    ``{"q", "s"}`` serving format — codes are dequantized before
    programming). Leading stack dims (scanned layers, vmapped experts) are
    programmed per instance with independent noise draws. Returns the
    `AimcProgram`; pair with ``program.install(params)`` for execution.

    ``pool`` co-programs this model into a shared `TilePool` under
    ``label`` — the pool's contexts and capacity cap supersede the plan's
    ``n_contexts``/``tiles_per_context``, and the capacity check covers
    every program already resident (multi-tenant serving, DESIGN.md §12).
    """
    plan = plan or MappingPlan()
    builder = ProgramBuilder(cfg, n_contexts=plan.n_contexts,
                             tiles_per_context=plan.tiles_per_context,
                             pool=pool, label=label)
    for pkey, w, idx in iter_mapped_leaves(params, plan):
        sub = jax.random.fold_in(key, idx) if key is not None else None
        builder.add(pkey, w, sub)
    return builder.build()


def iter_mapped_leaves(params, plan: MappingPlan | None):
    """Yield ``(path, weight, fold_index)`` for every plan-selected leaf, in
    the exact walk order `program_model` programs them.

    This IS the key-derivation contract: matrix i's programming key is
    ``fold_in(key, fold_index_i)``. `runtime.health.Recalibrator` replays
    this walk over the RAW parameter tree to capture reference weights and
    per-matrix keys, so a hot reprogram reproduces the original
    `program_stacked` output bit-for-bit."""
    plan = plan or MappingPlan()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_is_quantized_leaf)
    idx = 0
    for path, leaf in flat:
        w = _as_matrix(leaf)
        if w is None:
            continue
        pkey = _path_key(path)
        if not plan.selects(pkey, tuple(w.shape)):
            continue
        yield pkey, w, idx
        idx += 1


# ---------------------------------------------------------------------------
# tree-path helpers
# ---------------------------------------------------------------------------

def _is_quantized_leaf(x) -> bool:
    """Treat the int8 serving format {"q": codes, "s": scales} as one leaf."""
    return isinstance(x, dict) and "q" in x and "s" in x


def _is_installed_or_quantized_leaf(x) -> bool:
    """`install_updates` leaf cut: stop at whole programmed states too, so
    their tree path is the original weight path (not .../w_q, .../s_w)."""
    return isinstance(x, AimcLinearState) or _is_quantized_leaf(x)


def installed_entries(params) -> dict[str, AimcLinearState]:
    """path -> installed state for an already-installed tree — the LIVE
    states serving traffic (drifted / corrupted / repaired), which is what
    `runtime.health.HealthMonitor.probe` measures."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_is_installed_or_quantized_leaf)
    return {_path_key(p): leaf for p, leaf in flat
            if isinstance(leaf, AimcLinearState)}


def _as_matrix(leaf):
    """A float matrix view of a leaf, or None when the leaf is not a weight."""
    if _is_quantized_leaf(leaf):
        return leaf["q"].astype(jnp.float32) * leaf["s"].astype(jnp.float32)
    if isinstance(leaf, AimcLinearState):
        return None
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return None
    if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
        return None
    return leaf


def _path_key(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)
