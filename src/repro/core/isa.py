"""CM_* instruction-level accounting (paper §IV-B, Fig. 3).

The four custom ARMv8 instructions and their static cost/count model. These
records never execute anything — they are the unit of account for the cost
model (`core.costmodel`) and the benchmarks, exactly like gem5's per-
instruction statistics were the unit of account for the paper.

Counts for a [K x N] MVM mapped on tiles of M rows:
  CM_QUEUE    ceil(K/4)            (4 int8 inputs packed per 32-bit register)
  CM_PROCESS  ceil(K/M)            (one per row-block tile activation)
  CM_DEQUEUE  ceil(N/4) * ceil(K/M) (ADC codes fetched per row block)
  CM_INITIALIZE one-off, K*N writes (outside the inference region of interest)

Data-movement *time*, however, is bandwidth-limited (4 GB/s tile SRAM I/O,
paper Table I-C), not instruction-count limited; both views are provided.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class CmCounts:
    queue: int = 0
    process: int = 0
    dequeue: int = 0
    initialize: int = 0
    queue_bytes: int = 0
    dequeue_bytes: int = 0

    def __add__(self, other: "CmCounts") -> "CmCounts":
        return CmCounts(*(a + b for a, b in zip(dataclasses.astuple(self),
                                                dataclasses.astuple(other))))

    def scaled(self, times: int) -> "CmCounts":
        return CmCounts(*(v * times for v in dataclasses.astuple(self)))


def mvm_counts(k: int, n: int, tile_rows: int) -> CmCounts:
    """CM_* counts for one [K x N] AIMC MVM (inference-time instructions)."""
    row_blocks = math.ceil(k / tile_rows)
    return CmCounts(
        queue=math.ceil(k / 4),
        process=row_blocks,
        dequeue=math.ceil(n / 4) * row_blocks,
        initialize=0,
        queue_bytes=k,                      # int8 activations in
        dequeue_bytes=n * row_blocks,       # int8 ADC codes out, per row block
    )


def initialize_counts(k: int, n: int) -> CmCounts:
    return CmCounts(initialize=k * n)


def total(counts) -> CmCounts:
    """Sum an iterable of CmCounts (the per-matrix ledgers of a context)."""
    out = CmCounts()
    for c in counts:
        out = out + c
    return out
