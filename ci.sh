#!/usr/bin/env bash
# Tier-1 CI gate: reproducible verify command with pinned deps.
#
#   ./ci.sh            run the FULL tier-1 test suite (includes the slow
#                      interpret-mode Pallas sweeps and subprocess tests)
#   ./ci.sh --fast     inner-loop tier: skip tests marked pallas/slow
#                      (see [tool.pytest.ini_options].markers), then run the
#                      docs smokes (docs-check + examples/quickstart.py, the
#                      README front door), the engine smokes (single-device
#                      poisson trace + the sharded engine on a forced
#                      2-device host-platform mesh, per-step and with the
#                      k=8 scanned decode chunk), the chaos smoke (mid-trace
#                      corrupt+kill with drain + hot reprogram; fails on a
#                      lost request or ledger drift), the paged-engine smokes
#                      (prefix-cache exactly-once + chunked prefill, verified
#                      via --paged-verify), and the kernel
#                      perf-smoke (bench_kernels in interpret mode, writes
#                      BENCH_kernels.json, fails on check regression)
#   ./ci.sh --install  pip-install pinned deps first (no-op in the baked image)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--install" ]]; then
    python -m pip install -r requirements.txt
    shift
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Hygiene gate: compiled artifacts must never be tracked. A stray .pyc in
# the index silently shadows source edits for anyone importing the package.
if git ls-files -- '*.pyc' '*__pycache__*' | grep -q .; then
    echo "CI FAILURE: compiled python artifacts are tracked in git:" >&2
    git ls-files -- '*.pyc' '*__pycache__*' >&2
    exit 1
fi

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -x -q -m "not pallas and not slow"
    echo "== docs-smoke: file references + README quickstart =="
    python tools/docs_check.py
    python examples/quickstart.py
    echo "== engine smoke: continuous-batching serve (poisson trace) =="
    python -m repro.launch.serve --arch granite-8b --smoke --requests 4 \
        --prompt-len 8 --gen 4 --slots 2 --trace poisson:300 --exec aimc
    echo "== engine smoke: sharded engine on a 2-device host-platform mesh =="
    XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
        python -m repro.launch.serve --arch granite-8b --smoke --requests 4 \
        --prompt-len 8 --gen 4 --slots 2 --trace poisson:300 --exec aimc \
        --cores 2 --mesh data:2,model:1
    echo "== engine smoke: chunked decode (k=8 scan) on the 2-device mesh =="
    XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
        python -m repro.launch.serve --arch granite-8b --smoke --requests 4 \
        --prompt-len 8 --gen 4 --slots 2 --trace poisson:300 --exec aimc \
        --cores 2 --mesh data:2,model:1 --decode-chunk 8
    echo "== chaos smoke: mid-trace corrupt+kill, drain + hot reprogram =="
    # exits nonzero if any in-flight request is lost, a scheduled fault
    # never fires, or the CM_* / recal-CM_INITIALIZE ledgers drift
    # (DESIGN.md §14; launch.serve._verify_resilience)
    python -m repro.launch.serve --arch granite-8b --smoke --requests 6 \
        --prompt-len 8 --gen 6 --slots 3 --trace poisson:300 --exec aimc \
        --cores 2 --decode-chunk 2 --chaos "corrupt:0@1:0.5,kill:1@3"
    echo "== paged smoke: prefix cache, shared span prefilled exactly once =="
    # 8 requests share one 8-token system prompt on the paged engine with
    # the content-hashed prefix cache; --paged-verify exits nonzero unless
    # the shared span was prefilled exactly once, the page ledger
    # reconciles, and nothing recompiled after warmup (DESIGN.md §15)
    python -m repro.launch.serve --arch granite-8b --smoke --requests 8 \
        --prompt-len 12 --gen 6 --slots 4 --exec aimc \
        --page-size 4 --prefix-cache --shared-prefix 8 --paged-verify
    echo "== paged smoke: chunked prefill interleaved with decode =="
    python -m repro.launch.serve --arch granite-8b --smoke --requests 6 \
        --prompt-len 12 --gen 4 --slots 3 --trace poisson:300 --exec aimc \
        --page-size 4 --prefix-cache --prefill-chunk 4 --paged-verify
    echo "== server smoke: two models co-programmed, mixed-tenant trace =="
    # exits nonzero if per-tenant ledgers fail to reconcile or any tenant
    # with requests is starved of all tokens (runtime.server front door)
    python -m repro.launch.serve --smoke \
        --models granite-8b:aimc,xlstm-350m:digital \
        --tenants premium:granite-8b:2,standard:granite-8b:1:sjf,batch:xlstm-350m \
        --requests 8 --prompt-len 8 --gen 4 --slots 2 --trace poisson:200
    echo "== placement smoke: auto split, forced overflow rotation =="
    # budget 2 overflows the smoke model: serving time-multiplexes a
    # 2-state rotation plan; --placement-verify exits nonzero unless all
    # requests are served bit-equal to the all-digital oracle, every
    # rotation state packs within budget, the per-swap CM_INITIALIZE
    # books reconcile, and nothing recompiled after warmup (DESIGN.md §16)
    python -m repro.launch.serve --arch granite-8b --smoke --exec aimc \
        --placement auto:2 --tile-rows 64 --adc-alpha 0.5 --requests 4 \
        --prompt-len 8 --gen 6 --seed 89 --placement-verify
    echo "== perf-smoke: bench_kernels (interpret mode) =="
    exec python -m benchmarks.bench_kernels --json BENCH_kernels.json
fi
exec python -m pytest -x -q
