#!/usr/bin/env bash
# Tier-1 CI gate: reproducible verify command with pinned deps.
#
#   ./ci.sh            run the tier-1 test suite
#   ./ci.sh --install  pip-install pinned deps first (no-op in the baked image)
set -euo pipefail
cd "$(dirname "$0")"

if [[ "${1:-}" == "--install" ]]; then
    python -m pip install -r requirements.txt
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q
