"""Property tests for the page allocator + prefix cache (hypothesis).

Random alloc/retain/release/put/evict interleavings must never double-free
or leak a page, and a shared page's refcount must reach zero exactly when
its last sharer lets go. Deterministic API units live in test_pages.py;
this module needs the optional hypothesis dep (importorskip per repo
convention, mirroring test_isa_props.py)."""

import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.runtime.pages import PageAllocator, PrefixCache, page_keys


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                          st.integers(1, 4)), max_size=60),
       st.integers(4, 12))
def test_alloc_release_never_leaks(ops, n_pages):
    """Model-based check: a shadow refcount map tracks every alloc/retain/
    release; the allocator's books must match it after every op, and
    verify() (exact partition) must hold throughout."""
    a = PageAllocator(n_pages, page_size=2)
    model = {}          # pid -> refcount per the shadow model
    handles = []        # pids we hold at least one reference on
    for op, idx, n in ops:
        if op == 0:     # alloc n pages
            pids = a.alloc(n, owner=f"o{idx}")
            if pids is None:
                assert n > n_pages - 1 - len(model)
            else:
                for pid in pids:
                    assert pid not in model
                    model[pid] = 1
                    handles.append(pid)
        elif op == 1 and handles:   # retain an existing handle
            pid = handles[idx % len(handles)]
            a.retain(pid)
            model[pid] += 1
            handles.append(pid)
        elif op == 2 and handles:   # release one reference
            pid = handles.pop(idx % len(handles))
            freed = a.release(pid)
            model[pid] -= 1
            assert freed == (model[pid] == 0)
            if model[pid] == 0:
                del model[pid]
        elif op == 3:   # releasing an unheld pid must raise, not corrupt
            victim = (idx % a.n_pages)
            if victim not in model:
                with pytest.raises(ValueError):
                    a.release(victim)
        assert a.verify()
        assert {p: a.refcount(p) for p in model} == model
        assert a.n_free == n_pages - 1 - len(model)
    # drain: every page must come home
    while handles:
        pid = handles.pop()
        model[pid] -= 1
        a.release(pid)
        if model[pid] == 0:
            del model[pid]
    assert not model and a.n_free == n_pages - 1 and a.verify()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 6), st.lists(st.integers(0, 1), max_size=12),
       st.booleans())
def test_prefix_sharers_drop_to_zero_exactly_at_last_retire(
        n_sharers, order_bits, evict_first):
    """A cached page outlives its producer and every sharer; it is freed
    exactly when the LAST reference (cache eviction included) lets go —
    never earlier (no dangling sharer) and never later (no leak)."""
    a = PageAllocator(16, page_size=4)
    c = PrefixCache(a)
    (key,) = page_keys(list(range(4)), 4)
    (pid,) = a.alloc(1, owner="producer")     # producer's ref
    assert c.put(key, pid)                    # cache's ref
    sharers = []
    for _ in range(n_sharers):                # prefix hits retain
        got = c.lookup([key])
        assert got == [pid]
        a.retain(pid)
        sharers.append(pid)
    assert a.refcount(pid) == 2 + n_sharers
    releases = ["producer"] + ["sharer"] * n_sharers
    # interleave retirement order by the drawn bits
    order = sorted(range(len(releases)),
                   key=lambda i: (order_bits[i % max(1, len(order_bits))]
                                  if order_bits else 0, i))
    for i, j in enumerate(order):
        freed = a.release(pid)
        assert a.verify()
        assert freed is False                 # cache still holds its ref
        assert a.refcount(pid) == 2 + n_sharers - 1 - i
    # only the cache's ref remains: exactly one evictable entry
    assert a.refcount(pid) == 1
    assert c.evictable() == 1
    if evict_first:
        assert c.evict(1) == 1
    else:
        assert c.evict(1, protect=[pid]) == 0  # protected: still resident
        assert c.evict(1) == 1
    assert a.refcount(pid) == 0 and a.n_free == 15 and a.verify()
