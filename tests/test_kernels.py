"""Pallas AIMC crossbar kernel vs the pure-jnp oracle (kernels/ref.py).

Sweeps shapes (including ragged / padded), dtypes, block sizes and noise.
The kernel runs in interpret mode on this CPU container; the math is
identical to what compiles for TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aimc import AimcConfig, program_linear
from repro.core.quant import sym_scale
from repro.kernels import ops, ref
from repro.kernels.aimc_mvm import aimc_matmul_pallas


def _setup(b, k, n, tile_rows, seed=0, noise=False):
    kx, kw, kn = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (b, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    cfg = AimcConfig(tile_rows=tile_rows, impl="ref")
    st = program_linear(w, cfg)
    kb, m, np_ = st.w_q.shape
    xf = jnp.pad(x, ((0, 0), (0, kb * m - k)))
    s_x = sym_scale(xf).reshape(1, 1)
    rn = (jax.random.normal(kn, (kb, b, np_)) * 3.0 if noise
          else jnp.zeros((kb, b, np_), jnp.float32))
    return cfg, st, xf, s_x, rn


@pytest.mark.parametrize("b,k,n,tile_rows", [
    (8, 256, 256, 256),
    (16, 300, 200, 256),      # ragged K and N -> padding path
    (64, 1024, 512, 512),     # multi row-block
    (1, 512, 128, 512),       # decode-like single row
    (128, 512, 2048, 256),    # wide output, 2 row blocks
    (5, 700, 130, 512),       # everything ragged
])
def test_kernel_matches_oracle(b, k, n, tile_rows):
    cfg, st, xf, s_x, rn = _setup(b, k, n, tile_rows)
    y_ref = ref.aimc_matmul_ref(xf, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_kernel_matches_oracle_with_noise():
    cfg, st, xf, s_x, rn = _setup(16, 512, 256, 256, noise=True)
    y_ref = ref.aimc_matmul_ref(xf, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("block_b,block_n", [(8, 128), (32, 256), (128, 512)])
def test_kernel_block_shapes(block_b, block_n):
    """Different BlockSpec tilings must not change the result."""
    cfg, st, xf, s_x, rn = _setup(32, 512, 512, 256)
    y_ref = ref.aimc_matmul_ref(xf, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y = aimc_matmul_pallas(xf, st.w_q, st.s_w, s_x, rn,
                           adc_step=cfg.adc_step, block_b=block_b,
                           block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_kernel_bf16_inputs():
    """bf16 activations are upcast identically by kernel and oracle."""
    cfg, st, xf, s_x, rn = _setup(8, 256, 256, 256)
    xb = xf.astype(jnp.bfloat16)
    y_ref = ref.aimc_matmul_ref(xb.astype(jnp.float32), st.w_q, st.s_w, s_x,
                                rn, adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(xb.astype(jnp.float32), st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# Differential sweep: ragged shapes needing padding, decode shapes, noise
# on/off. The big-shape tail is marked `pallas` (interpret mode is orders of
# magnitude slower than compiled) so `make test-fast` can skip it; the full
# tier and CI run everything.
# ---------------------------------------------------------------------------

DIFF_CASES = [
    (1, 300, 130, 256),      # batch-1 decode, ragged K and N
    (1, 1000, 50, 512),      # decode, 2 row blocks, tiny ragged N
    (3, 513, 257, 512),      # off-by-one ragged in both dims
    (8, 384, 384, 128),      # 3 row blocks, lane-aligned
]


@pytest.mark.parametrize("noise", [False, True], ids=["nonoise", "noise"])
@pytest.mark.parametrize("b,k,n,tile_rows", DIFF_CASES)
def test_diff_sweep_ragged_and_decode(b, k, n, tile_rows, noise):
    cfg, st, xf, s_x, rn = _setup(b, k, n, tile_rows, seed=b + k, noise=noise)
    y_ref = ref.aimc_matmul_ref(xf, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    assert y_pal.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


@pytest.mark.pallas
@pytest.mark.parametrize("noise", [False, True], ids=["nonoise", "noise"])
@pytest.mark.parametrize("b,k,n,tile_rows", [
    (128, 1024, 1024, 512),  # production-ish panel, 2 row blocks
    (16, 2048, 768, 512),    # deep K, 4 row blocks
])
def test_diff_sweep_large(b, k, n, tile_rows, noise):
    cfg, st, xf, s_x, rn = _setup(b, k, n, tile_rows, seed=7, noise=noise)
    y_ref = ref.aimc_matmul_ref(xf, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_adc_clipping_visible():
    """Large activations must saturate the 8-bit ADC in both paths."""
    cfg = AimcConfig(tile_rows=256, impl="ref", adc_alpha=0.05)
    w = jnp.ones((256, 128)) * 0.1
    st = program_linear(w, cfg)
    x = jnp.ones((4, 256)) * 10.0
    s_x = sym_scale(x).reshape(1, 1)
    rn = jnp.zeros((1, 4, 128), jnp.float32)
    y_ref = ref.aimc_matmul_ref(x, st.w_q, st.s_w, s_x, rn,
                                adc_step=cfg.adc_step)
    y_pal = ops.aimc_matmul(x, st.w_q, st.s_w, s_x, rn,
                            adc_step=cfg.adc_step, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)
    # saturated: the ideal product exceeds what the ADC range can express
    ideal = x @ w
    assert float(jnp.max(y_ref)) < float(jnp.max(ideal))
