"""Kernel v2: in-kernel PRNG noise, fused epilogues, gate-fused multi-MVM.

The v2 contract (kernels/aimc_mvm.py + kernels/ops.py + core/aimc.py):

  * read noise comes from a scalar seed expanded in-kernel (counter mode:
    `kernels/cprng.py`) — BIT-identical between the oracle and the
    interpret-mode Pallas kernel, any block shape;
  * the epilogue (bias + relu/sigmoid/tanh) runs on the last row-block grid
    step and equals the separate-op math exactly;
  * a `[G, KB, M, Np]` gate stack runs as one kernel launch, bit-equal to
    per-gate calls (noise via `cprng.stack_seed`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aimc import (AimcConfig, aimc_apply, aimc_apply_stacked,
                             program_linear, program_stacked, stack_states)
from repro.core.noise import NoiseModel, derive_read_seed, read_sigma_lsb
from repro.core.quant import sym_scale
from repro.kernels import cprng, ops, ref

NOISY = NoiseModel(sigma_read=0.005)


def _setup(b, k, n, tile_rows, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.05
    cfg = AimcConfig(tile_rows=tile_rows, impl="ref")
    st = program_linear(w, cfg)
    kb, m, np_ = st.w_q.shape
    xf = jnp.pad(x, ((0, 0), (0, kb * m - k)))
    s_x = sym_scale(xf).reshape(1, 1)
    return cfg, st, x, xf, s_x


# ---------------------------------------------------------------------------
# In-kernel PRNG: oracle/kernel parity + statistical moments
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n,tile_rows", [
    (16, 512, 256, 256),
    (8, 300, 130, 256),       # ragged -> padding path
    (64, 1024, 512, 512),     # multi row-block
])
def test_in_kernel_noise_matches_oracle(b, k, n, tile_rows):
    """Counter-mode noise: the kernel draws per tile, the oracle in bulk —
    identical values, so outputs agree to f32 accumulation order."""
    cfg, st, x, xf, s_x = _setup(b, k, n, tile_rows)
    seed = jnp.uint32(0xC0FFEE)
    sigma = read_sigma_lsb(tile_rows, NOISY)
    y_ref = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                               adc_step=cfg.adc_step, sigma=sigma, impl="ref")
    y_pal = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                               adc_step=cfg.adc_step, sigma=sigma,
                               impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_in_kernel_noise_blockshape_invariant():
    """The counter addresses the LOGICAL tensor: different BlockSpec tilings
    draw the same noise bit for bit."""
    from repro.kernels.aimc_mvm import aimc_matmul_pallas_v2
    cfg, st, x, xf, s_x = _setup(32, 512, 512, 256)
    seed = jnp.uint32(7)
    ys = [aimc_matmul_pallas_v2(xf, st.w_q, st.s_w, s_x, seed,
                                adc_step=cfg.adc_step, sigma=20.0,
                                block_b=bb, block_n=bn, interpret=True)
          for bb, bn in ((8, 128), (32, 256), (32, 640))]
    for y in ys[1:]:
        assert bool(jnp.all(y == ys[0]))


def test_counter_noise_moments():
    """Seeded in-kernel PRNG vs the noise model: standard-normal moments."""
    z = cprng.read_noise_array(jnp.uint32(123), 8, 64, 512)   # 256k draws
    assert abs(float(z.mean())) < 0.01
    assert abs(float(z.std()) - 1.0) < 0.01
    # two seeds decorrelate
    z2 = cprng.read_noise_array(jnp.uint32(124), 8, 64, 512)
    corr = float(jnp.mean(z * z2) / (z.std() * z2.std()))
    assert abs(corr) < 0.01


def test_apply_noise_determinism_and_key_sensitivity():
    cfg = AimcConfig(tile_rows=256, impl="ref", noise=NOISY)
    st = program_linear(jax.random.normal(jax.random.PRNGKey(0), (256, 128))
                        * 0.05, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    y_a = aimc_apply(st, x, cfg, k1)
    y_b = aimc_apply(st, x, cfg, k1)
    y_c = aimc_apply(st, x, cfg, k2)
    assert bool(jnp.all(y_a == y_b))          # same key -> bit-reproducible
    assert not bool(jnp.all(y_a == y_c))      # different key -> new draw
    assert derive_read_seed(k1) != derive_read_seed(k2)


def test_no_noise_operand_in_v2_jaxpr():
    """The acceptance criterion made structural: no [KB, B, Np]-shaped
    value exists ANYWHERE in the lowered computation (nested jaxprs
    included) when noise is ON under v2."""
    from benchmarks.bench_kernels import jaxpr_materializes_shape
    cfg, st, x, xf, s_x = _setup(16, 512, 256, 256)
    kb, m, np_ = st.w_q.shape
    b = xf.shape[0]
    sigma = read_sigma_lsb(256, NOISY)

    def trace(impl):
        return jax.make_jaxpr(
            lambda xv, seed: ops.aimc_matmul_v2(
                xv, st.w_q, st.s_w, s_x, seed, adc_step=cfg.adc_step,
                sigma=sigma, impl=impl))(xf, jnp.uint32(1))

    assert not jaxpr_materializes_shape(trace("pallas_interpret").jaxpr,
                                        (kb, b, np_))
    # negative control: the oracle DOES materialize the bulk noise tensor,
    # and the recursive scan sees it through the jit wrapper
    assert jaxpr_materializes_shape(trace("ref").jaxpr, (kb, b, np_))


# ---------------------------------------------------------------------------
# Fused epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("activation", ["none", "relu", "sigmoid", "tanh"])
@pytest.mark.parametrize("with_bias", [False, True], ids=["nobias", "bias"])
def test_fused_epilogue_equals_unfused(activation, with_bias):
    """cfg.fuse_epilogue toggles WHERE the epilogue runs, never the values
    (noise off, exact equality)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (300, 200)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 300))
    bias = (jax.random.normal(jax.random.PRNGKey(2), (200,))
            if with_bias else None)
    for impl in ("ref", "pallas_interpret"):
        cfg_f = AimcConfig(tile_rows=256, impl=impl, fuse_epilogue=True)
        cfg_u = AimcConfig(tile_rows=256, impl=impl, fuse_epilogue=False)
        st = program_linear(w, cfg_f)
        y_f = aimc_apply(st, x, cfg_f, bias=bias, activation=activation)
        y_u = aimc_apply(st, x, cfg_u, bias=bias, activation=activation)
        assert bool(jnp.all(y_f == y_u)), (impl, activation, with_bias)


def test_fused_epilogue_matches_separate_ops():
    """Fused bias+relu == the v1-style separate bias add + relu ops."""
    cfg, st, x, xf, s_x = _setup(16, 512, 384, 256)
    np_ = st.w_q.shape[-1]
    bias = jax.random.normal(jax.random.PRNGKey(5), (np_,))
    y_f = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, None, bias,
                             adc_step=cfg.adc_step, activation="relu",
                             impl="pallas_interpret")
    y_sep = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x,
                               adc_step=cfg.adc_step, impl="pallas_interpret")
    y_sep = jnp.maximum(y_sep + bias[None, :], 0.0)
    assert bool(jnp.all(y_f == y_sep))


# ---------------------------------------------------------------------------
# Gate-fused multi-MVM stack
# ---------------------------------------------------------------------------

def test_stacked_bit_equal_per_gate_noise_off():
    cfg = AimcConfig(tile_rows=256, impl="pallas_interpret")
    w = jax.random.normal(jax.random.PRNGKey(0), (300, 200)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 300))
    sts = [program_linear(w * s, cfg) for s in (1.0, 0.6, 0.3, 0.1)]
    acts = ("sigmoid", "sigmoid", "tanh", "sigmoid")
    y = aimc_apply_stacked(stack_states(sts), x, cfg, activations=acts)
    for g, (st, a) in enumerate(zip(sts, acts)):
        y_g = aimc_apply(st, x, cfg, activation=a)
        assert bool(jnp.all(y[g] == y_g)), g


def test_stacked_bit_equal_per_gate_with_noise():
    """With noise on, slice g of the stack == a per-gate kernel call seeded
    with `stack_seed(seed, g)` — bit for bit."""
    cfg, st, x, xf, s_x = _setup(8, 512, 256, 256)
    g_ = 3
    w_q = jnp.stack([st.w_q] * g_)
    s_w = jnp.stack([st.s_w] * g_)
    seed, sigma = jnp.uint32(42), 15.0
    y = ops.aimc_matmul_stacked(xf, w_q, s_w, s_x, seed,
                                adc_step=cfg.adc_step, sigma=sigma,
                                impl="pallas_interpret")
    for g in range(g_):
        y_g = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x,
                                 cprng.stack_seed(seed, g),
                                 adc_step=cfg.adc_step, sigma=sigma,
                                 impl="pallas_interpret")
        assert bool(jnp.all(y[g] == y_g)), g
    # and the stacked oracle agrees with the stacked kernel
    y_ref = ops.aimc_matmul_stacked(xf, w_q, s_w, s_x, seed,
                                    adc_step=cfg.adc_step, sigma=sigma,
                                    impl="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_lstm_gate_stack_equals_side_by_side():
    """The fused f/i/g/o stack (per-gate in-kernel epilogues) reproduces the
    §VIII-D side-by-side mapping bit for bit (noise off)."""
    from repro.models import paper_nets as pn
    nh = 100
    params = pn.lstm_init(jax.random.PRNGKey(0), nh)
    xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 50))
    cfg = AimcConfig(tile_rows=256)
    y_concat, _ = pn.lstm_forward_aimc(params, xs, nh, cfg)
    y_fused, ctx = pn.lstm_forward_aimc(params, xs, nh, cfg, fuse_gates=True)
    assert bool(jnp.all(y_concat == y_fused))
    # fused CM_* accounting matches the side-by-side profile
    kin = nh + 50
    import repro.core.isa as isa
    per_step = isa.mvm_counts(kin, 4 * nh, cfg.tile_rows)
    assert ctx._counts["cell"].queue == 3 * per_step.queue


def test_program_stacked_gate_stack_applies():
    """program_stacked on [G, K, N] weights feeds aimc_apply_stacked."""
    cfg = AimcConfig(tile_rows=128, impl="pallas_interpret")
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 150, 60)) * 0.1
    stack = program_stacked(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 150))
    y = aimc_apply_stacked(stack, x, cfg)
    assert y.shape == (4, 5, 60)
    for g in range(4):
        st_g = program_linear(w[g], cfg)
        assert bool(jnp.all(aimc_apply(st_g, x, cfg) == y[g]))


# ---------------------------------------------------------------------------
# Decode (B=1) padding path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 3, 5])
def test_decode_padding_with_noise(b):
    """Batch padding must not shift the noise counters: padded rows are
    sliced off and real rows match the unpadded oracle exactly."""
    cfg, st, x, xf, s_x = _setup(b, 700, 130, 512, seed=b)
    seed, sigma = jnp.uint32(99), 25.0
    y_ref = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                               adc_step=cfg.adc_step, sigma=sigma, impl="ref")
    y_pal = ops.aimc_matmul_v2(xf, st.w_q, st.s_w, s_x, seed,
                               adc_step=cfg.adc_step, sigma=sigma,
                               impl="pallas_interpret")
    assert y_pal.shape == y_ref.shape == (b, st.w_q.shape[-1])
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=0, atol=1e-5)


def test_decode_apply_path_b1():
    cfg = AimcConfig(tile_rows=512, impl="pallas_interpret", noise=NOISY)
    cfg_ref = AimcConfig(tile_rows=512, impl="ref", noise=NOISY)
    st = program_linear(
        jax.random.normal(jax.random.PRNGKey(0), (1000, 50)) * 0.05, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 1000))
    key = jax.random.PRNGKey(3)
    y_p = aimc_apply(st, x, cfg, key)
    y_r = aimc_apply(st, x, cfg_ref, key)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# ops-level contract fixes
# ---------------------------------------------------------------------------

def test_block_n_never_drops_below_lane_width():
    """Np=640 used to drive `bn //= 2` to 40 (< 128 lanes); the picker now
    steps by whole lanes."""
    from repro.kernels.ops import _pick_blocks
    assert _pick_blocks(8, 640, 128, 512) == (8, 640 // 5)   # 128 divides
    assert _pick_blocks(8, 384, 128, 512) == (8, 384)
    bb, bn = _pick_blocks(128, 128 * 7, 128, 512)
    assert bn % 128 == 0 and (128 * 7) % bn == 0
    with pytest.raises(ValueError):
        _pick_blocks(8, 200, 128, 512)                        # not lane-aligned


def test_v1_entry_requires_explicit_noise_or_none():
    """aimc_matmul(read_noise=None) routes through v2 (no operand) and
    equals the explicit-zeros v1 path."""
    cfg, st, x, xf, s_x = _setup(8, 256, 256, 256)
    kb, m, np_ = st.w_q.shape
    zeros = jnp.zeros((kb, 8, np_), jnp.float32)
    y_v1 = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, zeros,
                           adc_step=cfg.adc_step, impl="pallas_interpret")
    y_v2 = ops.aimc_matmul(xf, st.w_q, st.s_w, s_x, None,
                           adc_step=cfg.adc_step, impl="pallas_interpret")
    assert bool(jnp.all(y_v1 == y_v2))
