"""Program-once/apply-many API (core/program.py).

The paper's deployment split as an invariant: CM_INITIALIZE happens once per
session (outside the inference region of interest) and is INDEPENDENT of how
many tokens are decoded; the apply-only path computes exactly what the
per-call (STE-forward) path computes given the same noise draws.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.aimc import (AimcConfig, AimcLinearState, aimc_apply,
                             aimc_linear_ste, program_linear, program_stacked)
from repro.core.program import (AimcProgram, CapacityError, MappingPlan,
                                program_model)
from repro.models.layers import Execution, linear

CFG = AimcConfig(tile_rows=128, impl="ref")


# ---------------------------------------------------------------------------
# apply-only == STE forward
# ---------------------------------------------------------------------------

def test_programmed_apply_matches_ste_forward_same_key():
    """aimc_linear_ste(key) == program(kp) + apply(kr) for kp,kr=split(key):
    program-once is a pure refactor of the forward math."""
    key = jax.random.PRNGKey(7)
    from repro.core.noise import NoiseModel
    cfg = dataclasses.replace(CFG, noise=NoiseModel(sigma_read=0.003))
    w = jax.random.normal(key, (200, 72)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
    kp, kr = jax.random.split(key)
    st = program_linear(w, cfg, kp)
    y_apply = aimc_apply(st, x, cfg, kr)
    y_ste = aimc_linear_ste(x, w, key, cfg)
    np.testing.assert_allclose(np.asarray(y_apply), np.asarray(y_ste),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("arch_id", ["granite_8b", "olmoe_1b_7b",
                                     "xlstm_350m"])
def test_program_model_matches_ste_forward(arch_id):
    """Whole-model: installed program (apply-only) == on-the-fly STE path
    with noise disabled — the migration changes cost, not math."""
    spec = get_arch(arch_id)
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = (jnp.arange(2 * 16).reshape(2, 16) * 3 + 1) % cfg.vocab

    exe_ste = Execution(mode="aimc", aimc=CFG, compute_dtype="float32")
    h_ste, _ = model.forward(params, toks, cfg, exe_ste, return_hidden=True)

    program = program_model(params, MappingPlan(), CFG)
    installed = program.install(params)
    exe_prog = Execution(mode="aimc", aimc=CFG, compute_dtype="float32",
                         programmed=True)
    h_prog, _ = model.forward(installed, toks, cfg, exe_prog,
                              return_hidden=True)
    np.testing.assert_allclose(np.asarray(h_prog), np.asarray(h_ste),
                               rtol=0, atol=1e-4)


def test_programmed_decode_runs_under_jit():
    """Installed params cross the jit boundary and the KV-cache decode loop
    (states ride through lax.scan as stacked pytree leaves)."""
    spec = get_arch("granite_8b")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = program_model(params, MappingPlan(), CFG)
    installed = program.install(params)
    exe = Execution(mode="aimc", aimc=CFG, compute_dtype="float32",
                    programmed=True)
    toks = (jnp.arange(2 * 8).reshape(2, 8) + 1) % cfg.vocab
    _, cache = model.prefill(installed, toks, cfg, exe, max_seq=12,
                             cache_dtype=jnp.float32)
    decode = jax.jit(lambda pr, ca, tk: model.decode_step(pr, ca, tk, cfg,
                                                          exe))
    tk = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        logits, cache = decode(installed, cache, tk)
        tk = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["len"][0]) == 11


# ---------------------------------------------------------------------------
# CM_* accounting: initialize constant, traffic linear in tokens
# ---------------------------------------------------------------------------

def test_initialize_constant_while_decode_grows():
    spec = get_arch("granite_8b")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = program_model(params, MappingPlan(), CFG)

    init_once = program.initialize_counts()
    assert init_once.initialize > 0
    assert init_once.queue == init_once.process == init_once.dequeue == 0

    for n_tokens in (1, 8, 64):
        roi = program.mvm_counts(times=n_tokens)
        # decode traffic scales with tokens...
        assert roi.queue == program.mvm_counts().queue * n_tokens
        assert roi.dequeue == program.mvm_counts().dequeue * n_tokens
        # ...programming does not: CM_INITIALIZE stays the session constant
        assert roi.initialize == 0
        assert program.initialize_counts() == init_once


def test_program_counts_cover_every_mapped_instance():
    """Stacked layers count as independent crossbar tenants."""
    params = {"blocks": {"wq": jnp.ones((3, 64, 32))}}   # 3 scanned layers
    program = program_model(params, MappingPlan(), CFG)
    assert program.n_matrices == 3
    assert program.initialize_counts().initialize == 3 * 64 * 32


# ---------------------------------------------------------------------------
# MappingPlan selection / placement
# ---------------------------------------------------------------------------

def test_plan_selects_projections_not_infra():
    spec = get_arch("olmoe_1b_7b")            # MoE: router must stay digital
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = program_model(params, MappingPlan(), CFG)
    names = set(program.names)
    assert any(n.endswith("we_gate") for n in names)      # experts mapped
    assert not any(n.endswith("router") for n in names)   # router digital
    assert not any("embed" in n for n in names)           # lookup digital
    assert not any(n.endswith("ln1") for n in names)      # norms digital


def test_plan_predicate_and_patterns():
    params = {"blocks": {"wq": jnp.ones((2, 64, 64)),
                         "wo": jnp.ones((2, 64, 64))}}
    only_wq = program_model(
        params, MappingPlan(include=(r"wq",)), CFG)
    assert only_wq.names == ("blocks/wq",)
    vetoed = program_model(
        params, MappingPlan(predicate=lambda path, shape: "wo" in path), CFG)
    assert vetoed.names == ("blocks/wo",)


def test_plan_capacity_check_and_contexts():
    params = {"a": jnp.ones((256, 256)), "b": jnp.ones((256, 256))}
    plan = MappingPlan(include=(r"[ab]",), n_contexts=2)
    program = program_model(params, plan, CFG)
    assert len(program.tile_maps) == 2
    assert sorted(program.contexts) == [0, 1]             # least-loaded spread
    with pytest.raises(CapacityError):
        program_model(params, MappingPlan(include=(r"[ab]",),
                                          tiles_per_context=1), CFG)


def test_install_roundtrip_and_dispatch():
    params = {"blocks": {"wq": jax.random.normal(jax.random.PRNGKey(0),
                                                 (64, 32)) * 0.05,
                         "ln": jnp.ones((64,))},
              "embed": jnp.ones((16, 64))}
    program = program_model(params, MappingPlan(), CFG)
    installed = program.install(params)
    assert isinstance(installed["blocks"]["wq"], AimcLinearState)
    assert installed["blocks"]["ln"] is params["blocks"]["ln"]
    assert installed["embed"] is params["embed"]
    # linear() dispatches on the state, digital elsewhere
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    exe = Execution(mode="aimc", aimc=CFG, compute_dtype="float32",
                    programmed=True)
    y = linear(x, installed["blocks"]["wq"], exe)
    y_fp = x @ params["blocks"]["wq"]
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05, f"8-bit crossbar should be ~4% of fp32, got {rel}"


def test_program_is_a_pytree():
    """Programs jit/flatten like parameter trees (shardable, donatable)."""
    params = {"wq": jnp.ones((64, 32)) * 0.02}
    program = program_model(params, MappingPlan(include=(r"wq",)), CFG)
    leaves, treedef = jax.tree_util.tree_flatten(program)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, AimcProgram)
    assert rebuilt.names == program.names

    @jax.jit
    def apply(prog, x):
        return aimc_apply(prog["wq"], x, CFG)

    y = apply(program, jnp.ones((2, 64)))
    assert y.shape == (2, 32)


def test_program_stacked_matches_per_slice():
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 96, 40)) * 0.05
    st = program_stacked(w, CFG)
    assert st.stack_shape == (3,) and st.instances == 3
    for i in range(3):
        ref = program_linear(w[i], CFG)
        np.testing.assert_array_equal(np.asarray(st.w_q[i]),
                                      np.asarray(ref.w_q))


# ---------------------------------------------------------------------------
# TilePool — shared crossbar budget across co-programmed models
# ---------------------------------------------------------------------------

def test_pool_contention_raises_capacity_error():
    """Two programs that each fit the capped pool ALONE must fail when
    co-programmed: the second one's placement overflows the shared budget
    with a clear CapacityError naming the resident program — never a
    silent tile overlap."""
    from repro.core.program import TilePool
    full = {"w": jnp.ones((CFG.tile_rows, CFG.tile_cols)) * 0.01}
    plan = MappingPlan(include=(r"w",))
    # each program alone occupies exactly one tile -> fits a 1-tile pool
    solo = TilePool(CFG, tiles_per_context=1)
    program_model(full, plan, CFG, pool=solo, label="a")
    assert solo.n_tiles == 1

    pool = TilePool(CFG, tiles_per_context=1)
    program_model(full, plan, CFG, pool=pool, label="a")
    with pytest.raises(CapacityError, match="co-resident.*a"):
        program_model(full, plan, CFG, pool=pool, label="b")


def test_pool_placements_never_overlap():
    """Co-resident programs pack into disjoint crossbar cell ranges, and
    each program's own tile_maps carry only its label's placements."""
    from repro.core.program import TilePool
    from repro.core.tile import overlapping_placements
    pool = TilePool(CFG)
    pa = program_model({"w": jnp.ones((200, 80)) * 0.01},
                       MappingPlan(include=(r"w",)), CFG,
                       pool=pool, label="a")
    pb = program_model({"w": jnp.ones((150, 120)) * 0.01},
                       MappingPlan(include=(r"w",)), CFG,
                       pool=pool, label="b")
    assert pool.labels == ["a", "b"]
    assert overlapping_placements(pool.placements()) == []
    for prog, label in ((pa, "a"), (pb, "b")):
        own = [p for tm in prog.tile_maps for p in tm.placements]
        assert own and all(p.matrix_id.startswith(f"{label}/") for p in own)


def test_pool_label_collision_raises():
    from repro.core.program import TilePool
    pool = TilePool(CFG)
    params = {"w": jnp.ones((64, 32)) * 0.01}
    plan = MappingPlan(include=(r"w",))
    program_model(params, plan, CFG, pool=pool, label="m")
    with pytest.raises(ValueError, match="already resident"):
        program_model(params, plan, CFG, pool=pool, label="m")


def test_pooled_program_matches_unpooled_math():
    """The pool changes WHERE matrices land, never what they compute: same
    params + key program to identical states and identical CM_* counts."""
    from repro.core.program import TilePool
    params = {"wq": jax.random.normal(jax.random.PRNGKey(3),
                                      (96, 48)) * 0.05}
    plan = MappingPlan(include=(r"wq",))
    key = jax.random.PRNGKey(9)
    plain = program_model(params, plan, CFG, key)
    pooled = program_model(params, plan, CFG, key,
                           pool=TilePool(CFG), label="m")
    assert plain.names == pooled.names
    assert plain.mvm_counts() == pooled.mvm_counts()
    np.testing.assert_array_equal(np.asarray(plain["wq"].w_q),
                                  np.asarray(pooled["wq"].w_q))
