"""int8 weight-stationary serving (the paper's number format; §Perf It.6)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.quant import quantize_params_int8, quantize_weight_int8
from repro.launch.shardings import EXPERT_IN, EXPERT_OUT, IN_PROJ, OUT_PROJ
from repro.models.layers import Execution, as_weight

QUANTIZABLE = IN_PROJ | OUT_PROJ | EXPERT_IN | EXPERT_OUT | {"unembed"}


def test_weight_roundtrip_error_bound():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.1
    packed = quantize_weight_int8(w)
    assert packed["q"].dtype == jnp.int8
    assert packed["s"].shape == (1, 32)
    w_hat = as_weight(packed, jnp.float32)
    # per-channel symmetric int8: error <= scale/2 element-wise
    err = jnp.abs(w_hat - w)
    assert bool(jnp.all(err <= packed["s"][0] * 0.5 + 1e-7))


def test_stacked_weight_scales_per_layer():
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8))
    packed = quantize_weight_int8(w)
    assert packed["q"].shape == (4, 16, 8)
    assert packed["s"].shape == (4, 1, 8)


def test_int8_forward_close_to_bf16():
    """A whole transformer forward with int8-packed weights stays close."""
    spec = get_arch("granite_8b")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = (jnp.arange(2 * 16).reshape(2, 16) * 3 + 1) % cfg.vocab
    exe = Execution(compute_dtype="float32")
    logits_ref, _ = model.forward(params, toks, cfg, exe)
    qparams = quantize_params_int8(params, QUANTIZABLE)
    logits_q, _ = model.forward(qparams, toks, cfg, exe)
    # int8 weights + bf16 non-projections: expect close logits, same top-1
    cos = jnp.sum(logits_ref * logits_q) / (
        jnp.linalg.norm(logits_ref) * jnp.linalg.norm(logits_q) + 1e-9)
    assert float(cos) > 0.99
    agree = jnp.mean((jnp.argmax(logits_ref, -1)
                      == jnp.argmax(logits_q, -1)).astype(jnp.float32))
    assert float(agree) > 0.9


def test_int8_decode_runs():
    spec = get_arch("granite_8b")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = quantize_params_int8(
        model.init(jax.random.PRNGKey(0), cfg), QUANTIZABLE)
    exe = Execution(compute_dtype="float32")
    cache = model.init_cache(cfg, 2, 8, jnp.float32)
    toks = jnp.ones((2, 1), jnp.int32)
    logits, cache = model.decode_step(params, cache, toks, cfg, exe)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["len"][0]) == 1


def test_int8_params_bytes_halved():
    spec = get_arch("granite_8b")
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), spec.smoke_cfg)
    qparams = quantize_params_int8(params, QUANTIZABLE)

    def nbytes(tree):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    # int8 projections + f32 scales + bf16 rest << f32 original
    assert nbytes(qparams) < 0.45 * nbytes(params)
