"""Deterministic CM_* accounting invariants (core/isa.py).

These sweeps always run; `tests/test_isa_props.py` re-states the same
invariants property-based (hypothesis) when the optional dep is present.
"""

import dataclasses

import pytest

from repro.core import isa
from repro.core.tile import n_row_blocks

SAMPLES = [
    isa.CmCounts(),
    isa.CmCounts(queue=3, process=1, dequeue=7, initialize=12,
                 queue_bytes=12, dequeue_bytes=28),
    isa.mvm_counts(256, 128, 256),
    isa.mvm_counts(1000, 50, 512),
    isa.initialize_counts(64, 32),
]


@pytest.mark.parametrize("a", SAMPLES)
def test_add_matches_scaled(a):
    """a + a == a.scaled(2): __add__ and scaled agree field by field."""
    assert a + a == a.scaled(2)
    assert a + a + a == a.scaled(3)


@pytest.mark.parametrize("a", SAMPLES)
@pytest.mark.parametrize("b", SAMPLES[:2])
def test_scaled_distributes_over_add(a, b):
    assert (a + b).scaled(5) == a.scaled(5) + b.scaled(5)
    assert a + b == b + a


@pytest.mark.parametrize("a", SAMPLES)
def test_scaled_identity_and_zero(a):
    assert a.scaled(1) == a
    assert a.scaled(0) == isa.CmCounts()
    assert a + isa.CmCounts() == a


def test_total_sums_fieldwise():
    tot = isa.total(SAMPLES)
    for f in dataclasses.fields(isa.CmCounts):
        assert getattr(tot, f.name) == sum(getattr(s, f.name)
                                           for s in SAMPLES)
    assert isa.total([]) == isa.CmCounts()


@pytest.mark.parametrize("tile_rows", [32, 128, 512, 1024])
def test_mvm_counts_monotone_in_k_and_n(tile_rows):
    """More inputs or outputs never cost fewer instructions."""
    ks = [1, 3, 31, 32, 33, 200, 512, 1025]
    ns = [1, 4, 5, 50, 128, 1000]
    for n in ns:
        prev = isa.CmCounts()
        for k in ks:
            c = isa.mvm_counts(k, n, tile_rows)
            assert c.queue >= prev.queue
            assert c.process >= prev.process
            assert c.dequeue >= prev.dequeue
            prev = c
    for k in ks:
        prev = isa.CmCounts()
        for n in ns:
            c = isa.mvm_counts(k, n, tile_rows)
            assert c.dequeue >= prev.dequeue
            assert c.queue == isa.mvm_counts(k, ns[0], tile_rows).queue
            prev = c


@pytest.mark.parametrize("k", [1, 64, 500, 512, 513, 4096])
def test_row_block_count_vs_tile_rows(k):
    """process == ceil(k / tile_rows) (tile.n_row_blocks) and shrinking the
    word lines never reduces the number of tile activations."""
    prev = None
    for tile_rows in (4096, 1024, 512, 128, 32):
        c = isa.mvm_counts(k, 64, tile_rows)
        assert c.process == n_row_blocks(k, tile_rows)
        if prev is not None:
            assert c.process >= prev
        prev = c.process
        if tile_rows >= k:
            assert c.process == 1


def test_mvm_byte_fields():
    c = isa.mvm_counts(1000, 50, 512)
    assert c.queue_bytes == 1000                 # int8 activations in
    assert c.dequeue_bytes == 50 * 2             # codes out, per row block
    assert c.initialize == 0


def test_initialize_counts_is_devices_written():
    c = isa.initialize_counts(300, 70)
    assert c.initialize == 300 * 70
    assert (c.queue, c.process, c.dequeue) == (0, 0, 0)
