"""Property tests for the DAC/ADC fixed-point math (core/quant.py)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.quant import (QMAX, QMIN, adc_quantize, adc_step_lsb,
                              dequantize, quantize, sym_scale)

floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                   allow_infinity=False, width=32)


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_range(vals):
    x = jnp.asarray(vals, jnp.float32)
    s = sym_scale(x)
    q = quantize(x, s)
    assert q.dtype == jnp.int8
    assert int(q.min()) >= QMIN and int(q.max()) <= QMAX


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bound(vals):
    """|x - dequant(quant(x))| <= scale/2 element-wise (round-to-nearest)."""
    x = jnp.asarray(vals, jnp.float32)
    s = sym_scale(x)
    err = jnp.abs(x - dequantize(quantize(x, s), s))
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_sym_scale_fits(vals):
    x = jnp.asarray(vals, jnp.float32)
    s = sym_scale(x)
    assert float(jnp.max(jnp.abs(x / s))) <= QMAX + 1e-3


def test_sym_scale_axis():
    x = jnp.asarray([[1.0, -2.0], [4.0, 0.5]], jnp.float32)
    s = sym_scale(x, axis=0)
    np.testing.assert_allclose(np.asarray(s).ravel(),
                               [4.0 / QMAX, 2.0 / QMAX], rtol=1e-6)


def test_quantize_negation_symmetry():
    """Symmetric range: quant(-x) == -quant(x) (PCM pair encoding)."""
    x = jnp.linspace(-3, 3, 101)
    s = sym_scale(x)
    np.testing.assert_array_equal(np.asarray(quantize(-x, s)),
                                  -np.asarray(quantize(x, s)))


@given(st.integers(min_value=1, max_value=4096),
       st.floats(min_value=0.1, max_value=4.0))
@settings(max_examples=50, deadline=None)
def test_adc_step_positive_monotone(rows, alpha):
    s = adc_step_lsb(rows, alpha)
    assert s >= 1.0
    assert adc_step_lsb(rows * 4, alpha) >= s  # grows with sqrt(M)


def test_adc_quantize_saturates():
    step = 100.0
    acc = jnp.asarray([1e9, -1e9, 0.0, 150.0])
    codes = adc_quantize(acc, jnp.float32(step))
    np.testing.assert_array_equal(np.asarray(codes), [QMAX, QMIN, 0, 2])
    assert codes.dtype == jnp.int32
