"""Sharding-rule tests: PartitionSpec assignment + divisibility fitting."""

import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.launch.shardings import (fit_spec, get_param_specs, param_spec)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _axis_product(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@given(st.tuples(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64)))
@settings(max_examples=60, deadline=None)
def test_fit_spec_always_divides(shape):
    mesh = make_mesh((1, 1), ("data", "model"))
    # emulate larger mesh axis sizes via a fake mesh-shape mapping
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    spec = P(("pod", "data"), "model", None)
    fitted = fit_spec(spec, shape, FakeMesh())
    for d, entry in enumerate(fitted):
        if entry is None:
            continue
        assert shape[d] % _axis_product(FakeMesh(), entry) == 0


def test_fit_spec_keeps_dividing_prefix():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    # 32 divides pod*data; keep both
    assert fit_spec(P(("pod", "data")), (32,), FakeMesh()) == P(("pod", "data"))
    # 2 divides pod only; keep the prefix
    assert fit_spec(P(("pod", "data")), (2,), FakeMesh()) == P("pod")
    # 3 divides nothing
    assert fit_spec(P(("pod", "data")), (3,), FakeMesh()) == P()
    # vocab 151655 is not divisible by 16
    assert fit_spec(P("data", "model"), (896, 151655), FakeMesh()) == P("data")


def test_param_spec_rules(mesh):
    fsdp = ("data",)
    w = jnp.zeros((4, 128, 256))
    assert param_spec((_K("wq"),), w, fsdp) == P(None, ("data",), "model")
    assert param_spec((_K("wo"),), w, fsdp) == P(None, "model", ("data",))
    e = jnp.zeros((4, 8, 128, 256))
    assert param_spec((_K("we_gate"),), e, fsdp) == \
        P(None, "model", ("data",), None)
    norm = jnp.zeros((4, 128))
    assert param_spec((_K("ln1"),), norm, fsdp) == P(None, None)
    emb = jnp.zeros((1000, 64))
    assert param_spec((_K("embed"),), emb, fsdp) == P("model", ("data",))


class _K:
    def __init__(self, key):
        self.key = key


def test_get_param_specs_tree_matches(mesh):
    params = {"blocks": {"wq": jnp.zeros((2, 8, 8)),
                         "ln1": jnp.zeros((2, 8))},
              "embed": jnp.zeros((100, 8))}
    specs = get_param_specs(params, mesh)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(params)
