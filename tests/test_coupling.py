"""Tight vs loose coupling (core/coupling.py): the two executable paths are
the SAME math (a performance distinction, not a numeric one), and the fused
kernel's HBM-byte advantage is regression-guarded at a recorded floor.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.bench_coupling import HBM_RATIO_FLOOR
from repro.core.aimc import AimcConfig, program_linear
from repro.core.coupling import (hbm_bytes_loose, hbm_bytes_tight,
                                 loose_forward, tight_forward)


@pytest.mark.parametrize("k,n,tile_rows,batch", [
    (256, 128, 256, 8),
    (300, 200, 128, 16),      # ragged K and N, multi row-block
    (1024, 512, 512, 4),
    (700, 130, 512, 1),       # decode-style single vector
])
def test_tight_equals_loose_forward(k, n, tile_rows, batch):
    """HBM staging (optimization barriers) must not change a single bit of
    the DAC -> crossbar -> ADC -> accumulate arithmetic."""
    cfg = AimcConfig(tile_rows=tile_rows, impl="ref")
    w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
    st = program_linear(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, k))
    y_t = tight_forward(st, x, cfg)
    y_l = loose_forward(st, x, cfg)
    assert y_t.shape == (batch, n)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_l),
                               rtol=0, atol=1e-5)


def test_tight_equals_loose_under_jit():
    cfg = AimcConfig(tile_rows=256, impl="ref")
    w = jax.random.normal(jax.random.PRNGKey(2), (512, 256)) * 0.05
    st = program_linear(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 512))
    y_t = jax.jit(lambda v: tight_forward(st, v, cfg))(x)
    y_l = jax.jit(lambda v: loose_forward(st, v, cfg))(x)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_l),
                               rtol=0, atol=1e-5)


def test_hbm_ratio_holds_recorded_floor():
    """Regression guard: the staged path's HBM traffic must stay above the
    recorded multiple of the fused kernel's at the canonical benchmark shape
    (1024x1024, tile 512, batch 128 — 2.21x under kernel v1, 3.49x under
    kernel v2 with the noise operand and epilogue round-trip gone). A drop
    below the floor means someone un-fused the kernel, reintroduced a
    streamed operand, or started spilling analog-domain intermediates."""
    cfg = AimcConfig(tile_rows=512, impl="ref")
    w = jnp.ones((1024, 1024)) * 0.02
    st = program_linear(w, cfg)
    ratio = hbm_bytes_loose(st, 128) / hbm_bytes_tight(st, 128)
    assert ratio >= HBM_RATIO_FLOOR, (
        f"loose/tight HBM ratio {ratio:.2f} fell below the recorded "
        f"{HBM_RATIO_FLOOR}x floor")


@pytest.mark.parametrize("batch", [1, 32, 128])
def test_hbm_gap_present_at_every_batch(batch):
    """The staged round-trips scale with batch, so the gap never closes."""
    cfg = AimcConfig(tile_rows=512, impl="ref")
    st = program_linear(jnp.ones((1024, 512)) * 0.02, cfg)
    assert hbm_bytes_loose(st, batch) > hbm_bytes_tight(st, batch)
