"""Sharded multi-device serving (runtime/engine.ShardedServeEngine).

The contracts pinned here (ISSUE 5 acceptance criteria):
  * the sharded engine's decode output is BIT-EQUAL to the single-device
    `ServeEngine` on the same trace — on a unit mesh in-process, and on a
    forced 2-device host-platform mesh in a subprocess (both the
    data-sharded and the model-column-sharded placements), for a
    transformer AND a recurrent arch;
  * aggregated per-request/per-core CM_* ledgers reconcile exactly against
    ``program.mvm_counts()`` (`batcher.reconcile_cores`);
  * shapes stay jit-stable: warmup compiles each closure once, serving
    never recompiles (committed-buffer discipline included);
  * `CoreSchedule.mesh_placement`/`device_ledgers` fold virtual cores onto
    mesh devices without creating or losing traffic;
  * `serve_engine_param_specs` column-shards only `AimcLinearState` leaves;
  * `launch.serve.parse_mesh` accepts both mesh syntaxes;
  * `benchmarks.run.write_report` refuses to clobber a complete artifact
    with a partial (crashed sub-bench) run.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.core.schedule import CoreSchedule
from repro.launch.mesh import make_mesh
from repro.models.layers import Execution
from repro.runtime.batcher import (poisson_trace, reconcile, reconcile_cores,
                                   request_core_ledgers, synchronized_trace)
from repro.runtime.engine import ServeEngine, ShardedServeEngine

EXE = Execution(compute_dtype="float32")


def _programmed_setup(arch="granite-8b", n_contexts=2):
    spec = get_arch(arch)
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    aimc = AimcConfig(impl="ref", input_scale=0.1)
    exe = Execution(mode="aimc", aimc=aimc, compute_dtype="float32",
                    programmed=True)
    program = program_model(params, MappingPlan(n_contexts=n_contexts), aimc,
                            jax.random.PRNGKey(2))
    return (spec, cfg, model, program.install(params), exe, program,
            CoreSchedule.from_program(program))


# ---------------------------------------------------------------------------
# unit-mesh equivalence (in-process; the mesh machinery with 1 device)
# ---------------------------------------------------------------------------

def test_sharded_equals_plain_on_unit_mesh_programmed():
    spec, cfg, model, params, exe, program, sched = _programmed_setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    kw = dict(n_slots=2, prompt_pad=8, max_seq=20, family=spec.family,
              module=spec.module, program=program, schedule=sched)
    plain = ServeEngine(model, cfg, exe, params, **kw)
    plain.warmup()
    sharded = ShardedServeEngine(model, cfg, exe, params, mesh=mesh, **kw)
    assert sharded.warmup() == {"prefill": 1, "insert": 1, "decode": 1}
    reqs = poisson_trace(6, rate=300.0, seed=6, prompt_len=(3, 8),
                         max_new=(1, 5), vocab=cfg.vocab)
    r1 = plain.serve(list(reqs))
    r2 = sharded.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid), \
            f"req {r.rid} diverged on the unit mesh"
    # serving the ragged trace must not have recompiled anything
    assert sharded.compile_counts() == {"prefill": 1, "insert": 1,
                                        "decode": 1}
    # books close: request ledgers, and their per-core split
    assert r2.observed_vectors == r2.useful_vectors
    led_sum, static = reconcile(program, r2.records, r2.observed_vectors)
    assert led_sum == static
    core_sum, sched_total = reconcile_cores(sched, r2.records,
                                            r2.observed_vectors)
    assert core_sum == sched_total
    assert sched_total == program.mvm_counts().scaled(r2.observed_vectors)


def test_sharded_recurrent_on_unit_mesh():
    spec = get_arch("xlstm-350m")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    kw = dict(n_slots=2, prompt_pad=6, max_seq=16, family=spec.family,
              module=spec.module, cache_dtype=jnp.float32)
    plain = ServeEngine(model, cfg, EXE, params, **kw)
    plain.warmup()
    sharded = ShardedServeEngine(model, cfg, EXE, params, mesh=mesh, **kw)
    sharded.warmup()
    reqs = synchronized_trace(3, prompt_len=6, max_new=5, seed=7,
                              vocab=cfg.vocab)
    r1 = plain.serve(list(reqs))
    r2 = sharded.serve(list(reqs))
    for r in reqs:
        assert r1.tokens(r.rid) == r2.tokens(r.rid)
    assert sharded.compile_counts() == {"prefill": 1, "insert": 1,
                                        "decode": 1}


# ---------------------------------------------------------------------------
# per-core ledger aggregation + mesh placement of schedule cores
# ---------------------------------------------------------------------------

def test_request_core_ledgers_split_and_reconcile():
    spec, cfg, model, params, exe, program, sched = _programmed_setup()
    eng = ServeEngine(model, cfg, exe, params, n_slots=2, prompt_pad=8,
                      max_seq=20, family=spec.family, module=spec.module,
                      program=program, schedule=sched)
    eng.warmup()
    reqs = synchronized_trace(3, prompt_len=8, max_new=4, seed=5,
                              vocab=cfg.vocab)
    report = eng.serve(reqs)
    per_req = request_core_ledgers(sched, report.records)
    per_core_led = {led.core: led.cm for led in sched.ledgers()}
    for rid, rec in report.records.items():
        assert set(per_req[rid]) == set(per_core_led)
        for c, cm in per_req[rid].items():
            assert cm == per_core_led[c].scaled(rec.vectors)
    # engine-level aggregation: summed over cores == program totals
    agg = eng.core_ledgers(report)
    total = None
    for cm in agg.values():
        total = cm if total is None else total + cm
    assert total == program.mvm_counts().scaled(report.useful_vectors)


class _MeshStub:
    """mesh_placement/device_ledgers read only shape + axis_names; a stub
    lets the placement law be tested for D > device_count in-process."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_mesh_placement_and_device_ledgers():
    spec, cfg, model, params, exe, program, sched = _programmed_setup(
        n_contexts=3)
    mesh = _MeshStub(data=1, model=2)
    place = sched.mesh_placement(mesh, "model")
    assert place == {c: c % 2 for c in range(sched.n_cores)}
    devs = sched.device_ledgers(mesh, "model")
    assert set(devs) <= {0, 1}
    # placement never creates or loses traffic
    from repro.core import isa
    assert isa.total(d.cm for d in devs.values()) == sched.ledger_totals()
    assert (sum(d.comm_bytes for d in devs.values())
            == sum(led.comm_bytes for led in sched.ledgers()))
    # a mesh without the axis collapses onto one slot
    assert set(sched.mesh_placement(_MeshStub(data=1),
                                    "model").values()) == {0}


def test_serve_engine_param_specs_shard_only_aimc_states():
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import serve_engine_param_specs

    params = {"blocks": {"wq": jnp.ones((64, 128)) * 0.1,
                         "ln": jnp.ones((64,))}}
    cfg = AimcConfig(tile_rows=128, impl="ref")
    prog = program_model(params, MappingPlan(), cfg)
    installed_shape = jax.eval_shape(lambda: prog.install(params))
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = serve_engine_param_specs(installed_shape, mesh)
    st = specs["blocks"]["wq"]
    assert st.w_q == P(None, None, "model")        # bit lines over model
    assert st.s_w == P(None, "model")
    assert specs["blocks"]["ln"] == P(None)        # digital leaf replicates
    # no model axis on the mesh -> everything replicates
    flat = make_mesh((1,), ("data",))
    specs = serve_engine_param_specs(installed_shape, flat)
    assert specs["blocks"]["wq"].w_q == P(None, None, None)


# ---------------------------------------------------------------------------
# CLI mesh parsing
# ---------------------------------------------------------------------------

def test_parse_mesh_both_syntaxes():
    from repro.launch.serve import parse_mesh
    assert parse_mesh("data:2,model:1") == ((2, 1), ("data", "model"), True)
    assert parse_mesh("model:4") == ((4,), ("model",), True)
    assert parse_mesh("2x1") == ((2, 1), ("data", "model"), False)
    assert parse_mesh("2x4x1") == ((2, 4, 1), ("pod", "data", "model"),
                                   False)
    for bad in ("data:x",                          # malformed size
                "data:2,data:2",                   # duplicate axis
                "data:0",                          # zero-sized axis
                "2",                               # 1 positional size
                "2xa",                             # non-integer positional
                "2x0"):                            # zero positional size
        with pytest.raises(SystemExit):
            parse_mesh(bad)


def test_parse_named_mesh_rejects_positional():
    """The bench/sharded entry points must not let the legacy DxM spelling
    (single-device engine in launch.serve) silently select the sharded
    engine — one spelling, one meaning across CLIs."""
    from repro.launch.serve import force_host_device_count, parse_named_mesh
    assert parse_named_mesh("data:2,model:1") == ((2, 1), ("data", "model"))
    with pytest.raises(SystemExit, match="named"):
        parse_named_mesh("2x1")
    with pytest.raises(SystemExit):
        force_host_device_count("2x1")
    # a unit mesh forces nothing (no XLA_FLAGS mutation needed to test the
    # parse path)
    import os
    before = os.environ.get("XLA_FLAGS")
    assert force_host_device_count("data:1,model:1") == ((1, 1),
                                                         ("data", "model"))
    assert os.environ.get("XLA_FLAGS") == before


@pytest.mark.slow
def test_force_host_device_count_fails_loud_after_late_init():
    """Setting XLA_FLAGS after jax already initialized its backend is a
    silent no-op — the old helper then let a 'data:2' bench run all its
    "sharded" cases on ONE device and report them as a 2-device result.
    The helper must verify the post-init device count and exit nonzero."""
    code = textwrap.dedent("""
        import jax
        assert jax.device_count() == 1, jax.devices()   # backend is up
        from repro.launch.serve import force_host_device_count
        force_host_device_count("data:2,model:1")       # too late: must die
        print("UNREACHABLE")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode != 0
    assert "UNREACHABLE" not in proc.stdout
    assert "device" in proc.stderr         # the message names the problem


# ---------------------------------------------------------------------------
# benchmarks.run artifact discipline
# ---------------------------------------------------------------------------

def test_write_report_refuses_partial_overwrite(tmp_path, capsys):
    import json

    from benchmarks.run import write_report
    path = str(tmp_path / "BENCH_all.json")
    # complete run writes (and is stamped complete)
    assert write_report(path, {"summary": {"passed": 1}}, complete=True)
    assert json.load(open(path))["partial"] is False
    # partial run must NOT clobber the existing complete artifact
    assert not write_report(path, {"summary": {"passed": 0}}, complete=False)
    assert json.load(open(path))["summary"]["passed"] == 1
    # partial run with nothing to lose still writes, stamped partial
    fresh = str(tmp_path / "fresh.json")
    assert write_report(fresh, {"summary": {"passed": 0}}, complete=False)
    assert json.load(open(fresh))["partial"] is True
    # ...and a later partial run may refresh a PARTIAL artifact
    assert write_report(fresh, {"summary": {"passed": 2}}, complete=False)
    assert json.load(open(fresh))["summary"]["passed"] == 2
    # a pre-stamp artifact (no "partial" key) is presumed complete
    legacy = str(tmp_path / "legacy.json")
    json.dump({"summary": {}}, open(legacy, "w"))
    assert not write_report(legacy, {"summary": {}}, complete=False)


# ---------------------------------------------------------------------------
# the acceptance bar: forced 2-device host-platform mesh (subprocess —
# XLA's device count is fixed at backend init)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_engine_bit_equal_across_two_devices():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=2 "
            + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.configs import get_arch
        from repro.core.aimc import AimcConfig
        from repro.core.program import MappingPlan, program_model
        from repro.core.schedule import CoreSchedule
        from repro.launch.mesh import make_mesh
        from repro.models.layers import Execution
        from repro.runtime.batcher import (reconcile, reconcile_cores,
                                           synchronized_trace)
        from repro.runtime.engine import ServeEngine, ShardedServeEngine

        def check(arch, programmed, shape):
            spec = get_arch(arch); cfg = spec.smoke_cfg
            model = spec.model_module()
            params = model.init(jax.random.PRNGKey(0), cfg)
            prog = sched = None
            if programmed:
                aimc = AimcConfig(impl="ref", input_scale=0.1)
                exe = Execution(mode="aimc", aimc=aimc,
                                compute_dtype="float32", programmed=True)
                prog = program_model(params, MappingPlan(n_contexts=2),
                                     aimc, jax.random.PRNGKey(2))
                params = prog.install(params)
                sched = CoreSchedule.from_program(prog)
            else:
                exe = Execution(compute_dtype="float32")
            mesh = make_mesh(shape, ("data", "model"))
            kw = dict(n_slots=2, prompt_pad=8, max_seq=20,
                      family=spec.family, module=spec.module,
                      cache_dtype=jnp.float32, program=prog, schedule=sched)
            e1 = ServeEngine(model, cfg, exe, params, **kw); e1.warmup()
            e2 = ShardedServeEngine(model, cfg, exe, params, mesh=mesh,
                                    **kw)
            assert e2.warmup() == {"prefill": 1, "insert": 1, "decode": 1}
            reqs = synchronized_trace(4, prompt_len=8, max_new=6, seed=1,
                                      vocab=cfg.vocab)
            r1 = e1.serve(list(reqs)); r2 = e2.serve(list(reqs))
            for r in reqs:
                assert r1.tokens(r.rid) == r2.tokens(r.rid), (
                    arch, shape, r.rid)
            assert e2.compile_counts() == {"prefill": 1, "insert": 1,
                                           "decode": 1}, (arch, shape)
            if prog is not None:
                assert r2.observed_vectors == r2.useful_vectors
                ls, st = reconcile(prog, r2.records, r2.observed_vectors)
                assert ls == st
                cs, stot = reconcile_cores(sched, r2.records,
                                           r2.observed_vectors)
                assert cs == stot
                assert stot == prog.mvm_counts().scaled(r2.observed_vectors)

        check("granite-8b", True, (2, 1))    # slots over data
        check("granite-8b", True, (1, 2))    # crossbar bit lines over model
        check("xlstm-350m", False, (2, 1))   # recurrent state over data
        print("SHARDED_BITEQUAL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_BITEQUAL_OK" in proc.stdout
