"""Multi-core scheduler (core/schedule.py).

The load-bearing invariants: column splits are exact, every paper multi-core
mapping executes numerically equal to the single-core programmed path,
per-core CM_* ledgers reconcile with the single-core program totals, the two
dataflow latency laws hold, and the schedule-modeled latency agrees with
`costmodel.evaluate()` on the matching Workload IR (the measured-vs-predicted
consistency the benchmarks report).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isa
from repro.core.aimc import AimcConfig, aimc_apply, program_linear
from repro.core.costmodel import HIGH_POWER, evaluate
from repro.core.program import MappingPlan, program_model
from repro.core.schedule import (CoreSchedule, OverlapRoofline, Shard,
                                 cnn_schedule, lstm_schedule, mlp_schedule,
                                 pipeline_run, pipelined_latency,
                                 select_columns, sequential_latency)
from repro.core.workloads import lstm_workloads, mlp_workloads
from repro.launch.mesh import make_mesh
from repro.models import paper_nets as pn

CFG = AimcConfig(tile_rows=128, impl="ref")


# ---------------------------------------------------------------------------
# select_columns: exactness
# ---------------------------------------------------------------------------

def test_select_columns_contiguous_and_interleaved():
    w = jax.random.normal(jax.random.PRNGKey(0), (300, 200)) * 0.05
    st = program_linear(w, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 300))
    y = aimc_apply(st, x, CFG)
    sub = select_columns(st, [(0, 77)])
    np.testing.assert_array_equal(np.asarray(aimc_apply(sub, x, CFG)),
                                  np.asarray(y[:, :77]))
    gaps = select_columns(st, [(50, 100), (150, 200)])
    np.testing.assert_array_equal(
        np.asarray(aimc_apply(gaps, x, CFG)),
        np.asarray(jnp.concatenate([y[:, 50:100], y[:, 150:200]], -1)))


def test_select_columns_validates():
    st = program_linear(jnp.ones((64, 32)) * 0.1, CFG)
    with pytest.raises(ValueError):
        select_columns(st, [(0, 40)])            # past logical n
    with pytest.raises(ValueError):
        select_columns(st, [(0, 16), (8, 24)])   # overlap


# ---------------------------------------------------------------------------
# paper mappings: multi-core == single-core (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cores", [2, 4])
def test_mlp_multicore_equals_single_core(cores):
    params = pn.mlp_init(jax.random.PRNGKey(0), n=128)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 128))
    y1, _ = pn.mlp_forward_multicore(params, x, CFG, cores=1)
    ym, sched = pn.mlp_forward_multicore(params, x, CFG, cores=cores)
    assert sched.n_cores == cores
    np.testing.assert_array_equal(np.asarray(ym), np.asarray(y1))


@pytest.mark.parametrize("cores", [2, 5])
def test_lstm_multicore_equals_single_core(cores):
    nh = 64
    params = pn.lstm_init(jax.random.PRNGKey(0), nh, x_dim=16, y_dim=12)
    xs = jax.random.normal(jax.random.PRNGKey(1), (3, 2, 16))
    y1, _ = pn.lstm_forward_multicore(params, xs, nh, CFG, cores=1)
    ym, sched = pn.lstm_forward_multicore(params, xs, nh, CFG, cores=cores)
    assert sched.n_cores == cores
    np.testing.assert_array_equal(np.asarray(ym), np.asarray(y1))


def test_cnn_pipeline_equals_single_core_ctx_path():
    params = pn.cnn_init(jax.random.PRNGKey(0), "F", img=64, n_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64, 3))
    y_ctx, _ = pn.cnn_forward(params, x, "F", CFG)
    y_mc, sched = pn.cnn_forward_multicore(params, x, "F", CFG)
    assert sched.pipelined and sched.n_cores == 5
    np.testing.assert_array_equal(np.asarray(y_mc), np.asarray(y_ctx))


def test_multicore_matches_under_jit():
    params = pn.mlp_init(jax.random.PRNGKey(0), n=128)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128))
    _, sched = pn.mlp_forward_multicore(params, x, CFG, cores=4)
    f = jax.jit(lambda v: pn.mlp_forward_multicore(
        params, v, CFG, schedule=sched)[0])
    np.testing.assert_array_equal(
        np.asarray(f(x)),
        np.asarray(pn.mlp_forward_multicore(params, x, CFG, cores=1)[0]))


# ---------------------------------------------------------------------------
# per-core ledgers reconcile with the single-core program totals
# ---------------------------------------------------------------------------

def test_unsplit_ledgers_sum_to_program_totals():
    """Layer-per-core mappings (no column split): per-core CM_* ledgers sum
    EXACTLY to the single-core program's per-vector counts."""
    params = pn.mlp_init(jax.random.PRNGKey(0), n=128)
    prog = pn.mlp_program(params, CFG)
    for cores in (1, 2):
        sched = mlp_schedule(prog, cores)
        assert sched.ledger_totals() == prog.mvm_counts()


def test_from_program_ledgers_sum_to_program_totals():
    params = {"blocks": {"wq": jnp.ones((2, 64, 32)) * 0.1,
                         "wo": jnp.ones((2, 32, 64)) * 0.1}}
    prog = program_model(params, MappingPlan(n_contexts=2), CFG)
    sched = CoreSchedule.from_program(prog)
    assert sched.n_cores == 2
    assert sched.ledger_totals() == prog.mvm_counts()
    # round-robin contexts alternate cores -> the hand-off edge is charged
    assert sum(led.comm_bytes for led in sched.ledgers()) > 0


def test_column_split_ledgers_partition_dequeue_and_duplicate_queue():
    """Column splits partition the bit lines (dequeue sums exactly) but every
    core queues the FULL input vector (queue duplicates by the split factor)
    — the paper's case-4 multi-core queue tax, quantified."""
    params = pn.mlp_init(jax.random.PRNGKey(0), n=128)
    prog = pn.mlp_program(params, CFG)
    sched = mlp_schedule(prog, 4)
    tot, ref = sched.ledger_totals(), prog.mvm_counts()
    assert tot.dequeue == ref.dequeue
    assert tot.dequeue_bytes == ref.dequeue_bytes
    assert tot.queue == 2 * ref.queue            # each layer split 2-ways
    assert tot.process == 2 * ref.process


def test_cnn_ledger_scales_with_positions():
    params = pn.cnn_init(jax.random.PRNGKey(0), "F", img=64, n_classes=10)
    prog = pn.cnn_program(params, "F", CFG)
    sched = cnn_schedule(prog, pn.CNN_SPECS["F"], img=64)
    want = isa.total(
        isa.mvm_counts(prog[sh.name].k, prog[sh.name].n,
                       CFG.tile_rows).scaled(sh.count)
        for sh in sched.shards)
    got = sched.ledger_totals()
    assert (got.queue, got.process, got.dequeue) == (
        want.queue, want.process, want.dequeue)


# ---------------------------------------------------------------------------
# dataflow latency laws
# ---------------------------------------------------------------------------

def test_latency_laws_on_synthetic_stage_times():
    phases = [(3.0, 1.0), (2.0,), (5.0, 4.0, 1.0)]
    assert sequential_latency(phases) == 3.0 + 2.0 + 5.0   # sum of phase maxes
    assert pipelined_latency(phases) == 5.0                # slowest stage
    assert sequential_latency([]) == 0.0
    assert pipelined_latency([()]) == 0.0


def test_overlap_roofline_recovers_exact_constants():
    # synthetic step times generated FROM the law must fit back exactly
    truth = OverlapRoofline(t_step_s=2.0e-3, t_round_s=8.0e-3)
    times = {k: truth.predict_step_s(k) for k in (1, 2, 4, 8)}
    fit = OverlapRoofline.fit(times)
    assert abs(fit.t_step_s - truth.t_step_s) < 1e-12
    assert abs(fit.t_round_s - truth.t_round_s) < 1e-12
    assert abs(fit.predict_step_s(16) - (2.0e-3 + 8.0e-3 / 16)) < 1e-12
    # speedup 1 -> 8: (2+8)/(2+1) ms
    assert abs(fit.speedup(1, 8) - 10.0 / 3.0) < 1e-9
    assert all(r < 1e-9 for r in fit.residuals(times).values())


def test_overlap_roofline_least_squares_and_guards():
    # noisy over-determined system: fit minimizes residuals, stays close
    truth = OverlapRoofline(t_step_s=1.0e-3, t_round_s=4.0e-3)
    noise = {1: 1.02, 2: 0.97, 4: 1.03, 8: 0.99}
    times = {k: truth.predict_step_s(k) * noise[k] for k in noise}
    fit = OverlapRoofline.fit(times)
    assert max(fit.residuals(times).values()) < 0.1
    # monotone: bigger chunks never predict slower steps
    preds = [fit.predict_step_s(k) for k in (1, 2, 4, 8, 16)]
    assert all(a >= b for a, b in zip(preds, preds[1:]))
    with pytest.raises(ValueError):
        OverlapRoofline.fit({4: 1.0e-3})
    with pytest.raises(ValueError):
        fit.predict_step_s(0)
    # a fit tilted negative by noise clamps to 0, never negative time
    neg = OverlapRoofline.fit({1: 1.0e-3, 8: 2.0e-3})
    assert neg.t_step_s >= 0.0 and neg.t_round_s >= 0.0


def test_schedule_latency_uses_the_right_law():
    params = pn.mlp_init(jax.random.PRNGKey(0), n=128)
    prog = pn.mlp_program(params, CFG)
    seq = mlp_schedule(prog, 2)
    times = seq.phase_times(HIGH_POWER)
    assert seq.modeled_latency(HIGH_POWER) == sequential_latency(times)
    pipe = CoreSchedule(prog, seq.shards, pipelined=True)
    assert pipe.modeled_latency(HIGH_POWER) == pipelined_latency(times)
    assert pipe.modeled_latency(HIGH_POWER) <= seq.modeled_latency(HIGH_POWER)


@pytest.mark.parametrize("cores,case", [(1, "ana_case1"), (2, "ana_case3"),
                                        (4, "ana_case4")])
def test_mlp_schedule_latency_matches_costmodel(cores, case):
    """The executable schedule and the hand-written Workload IR are two
    descriptions of ONE mapping: priced through the shared accounting they
    must agree exactly."""
    n = 128
    params = pn.mlp_init(jax.random.PRNGKey(0), n=n)
    prog = pn.mlp_program(params, _tile_cfg(n))
    sched = mlp_schedule(prog, cores)
    want = evaluate(mlp_workloads(n)[case], HIGH_POWER).time_s
    got = sched.modeled_latency(HIGH_POWER)
    assert abs(got - want) <= 1e-9 * want


@pytest.mark.parametrize("cores,case", [(1, "ana_case2"), (2, "ana_case3"),
                                        (5, "ana_case4")])
def test_lstm_schedule_latency_matches_costmodel(cores, case):
    nh = 64
    params = pn.lstm_init(jax.random.PRNGKey(0), nh)
    kin = nh + 50
    prog = pn.lstm_program(params, _tile_cfg(kin + 50))
    sched = lstm_schedule(prog, cores, nh)
    want = evaluate(lstm_workloads(nh)[case], HIGH_POWER).time_s
    got = sched.modeled_latency(HIGH_POWER)
    assert abs(got - want) <= 1e-9 * want


def _tile_cfg(tile_rows: int) -> AimcConfig:
    """AimcConfig whose word lines match a Workload's per-case tile_rows."""
    return AimcConfig(tile_rows=tile_rows, tile_cols=4096, impl="ref")


# ---------------------------------------------------------------------------
# schedule construction validation
# ---------------------------------------------------------------------------

def test_rejects_partial_or_mixed_covers():
    prog = pn.mlp_program(pn.mlp_init(jax.random.PRNGKey(0), n=128), CFG)
    with pytest.raises(ValueError):               # half the columns missing
        CoreSchedule(prog, [Shard("fc1", 0, 0, cols=((0, 64),)),
                            Shard("fc2", 0, 1)])
    with pytest.raises(ValueError):               # full + split mixed
        CoreSchedule(prog, [Shard("fc1", 0, 0, cols=((0, 64),)),
                            Shard("fc1", 1, 0),
                            Shard("fc2", 0, 1)])
    with pytest.raises(KeyError):                 # unmapped matrix
        CoreSchedule(prog, [Shard("nope", 0, 0)])


def test_pipeline_run_preserves_values():
    stages = [lambda x: x + 1.0, lambda x: x * 2.0, lambda x: x - 3.0]
    outs, times = pipeline_run(stages, [jnp.zeros(4), jnp.ones(4)])
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.full(4, (0 + 1) * 2 - 3.0))
    np.testing.assert_array_equal(np.asarray(outs[1]),
                                  np.full(4, (1 + 1) * 2 - 3.0))
    assert len(times) == 3 and all(t >= 0 for t in times)


# ---------------------------------------------------------------------------
# launcher wiring: make_step accepts a CoreSchedule (column-sharded serving)
# ---------------------------------------------------------------------------

def test_make_step_accepts_schedule_and_decodes():
    """The full serving wiring: program with 2 contexts -> CoreSchedule ->
    make_step column-shards the installed states (shard_aimc_states) and the
    jitted decode step runs against them."""
    import dataclasses

    from repro.compat import use_mesh
    from repro.configs import ShapeCell, get_arch
    from repro.launch.shardings import to_named
    from repro.launch.steps import make_step
    from repro.models.layers import Execution

    spec = get_arch("granite_8b")
    spec = dataclasses.replace(spec, model_cfg=spec.smoke_cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    exe = Execution(mode="aimc", aimc=CFG, compute_dtype="float32",
                    programmed=True)
    with use_mesh(mesh):
        model = spec.model_module()
        params = model.init(jax.random.PRNGKey(0), spec.smoke_cfg)
        prog = program_model(params, MappingPlan(n_contexts=2), CFG)
        sched = CoreSchedule.from_program(prog)
        cell = ShapeCell("tiny_dec", seq_len=32, global_batch=2,
                         kind="decode")
        bundle = make_step(spec, cell, mesh, exe, program=sched)
        assert bundle.schedule is sched
        step = jax.jit(bundle.fn,
                       in_shardings=to_named(bundle.in_shardings, mesh),
                       out_shardings=to_named(bundle.out_shardings, mesh))
        cache = model.init_cache(spec.smoke_cfg, 2, 32, jnp.float32)
        toks = jnp.ones((2, 1), jnp.int32)
        for _ in range(2):
            toks, cache = step(prog.install(params), cache, toks)
        assert toks.shape == (2, 1)
        assert int(cache["len"][0]) == 2


def test_shard_aimc_states_rewrites_only_state_leaves():
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import get_param_specs, shard_aimc_states

    params = {"blocks": {"wq": jnp.ones((64, 128)) * 0.1,
                         "ln": jnp.ones((64,))}}
    prog = program_model(params, MappingPlan(), CFG)
    installed_shape = jax.eval_shape(lambda: prog.install(params))
    mesh = make_mesh((1, 1), ("data", "model"))
    pspecs = get_param_specs(installed_shape, mesh)
    sharded = shard_aimc_states(pspecs, installed_shape, mesh)
    st = sharded["blocks"]["wq"]
    assert st.w_q == P(None, None, "model")     # bit lines over model
    assert st.s_w == P(None, "model")
    assert sharded["blocks"]["ln"] == pspecs["blocks"]["ln"]  # untouched


# ---------------------------------------------------------------------------
# mesh execution
# ---------------------------------------------------------------------------

def test_apply_sharded_matches_apply_on_mesh():
    params = pn.mlp_init(jax.random.PRNGKey(0), n=256)
    prog = pn.mlp_program(params, CFG)
    sched = mlp_schedule(prog, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    mesh = make_mesh((1, 1), ("data", "model"))
    y_sh = sched.apply_sharded("fc1", x, mesh, axis="model")
    np.testing.assert_array_equal(np.asarray(y_sh),
                                  np.asarray(sched.apply("fc1", x)))


def test_apply_sharded_rejects_full_shards():
    prog = pn.mlp_program(pn.mlp_init(jax.random.PRNGKey(0), n=128), CFG)
    sched = mlp_schedule(prog, 2)
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        sched.apply_sharded("fc1", jnp.ones((2, 128)), mesh)


@pytest.mark.slow
def test_apply_sharded_across_real_devices():
    """The shard_map path with one core per REAL device: forced 2-device CPU
    in a subprocess (XLA device count is fixed at backend init)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=2 "
            + os.environ.get("XLA_FLAGS", ""))
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.aimc import AimcConfig
        from repro.core.schedule import mlp_schedule
        from repro.launch.mesh import make_mesh
        from repro.models import paper_nets as pn
        assert jax.device_count() == 2, jax.devices()
        cfg = AimcConfig(tile_rows=128, impl="ref")
        params = pn.mlp_init(jax.random.PRNGKey(0), n=256)
        prog = pn.mlp_program(params, cfg)
        sched = mlp_schedule(prog, 4)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
        mesh = make_mesh((1, 2), ("data", "model"))
        y = sched.apply_sharded("fc1", x, mesh, axis="model")
        np.testing.assert_array_equal(np.asarray(y),
                                      np.asarray(sched.apply("fc1", x)))
        print("MULTIDEVICE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        ["src", env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTIDEVICE_OK" in proc.stdout
