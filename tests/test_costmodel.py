"""Analytical cost model invariants + paper-claim regression tests.

The benchmarks print the full tables; these tests pin the claims so a code
change that breaks calibration fails CI.
"""

import pytest

from repro.core.costmodel import (CALIB, HIGH_POWER, LOW_POWER, AimcTileSpec,
                                  Op, Stage, Workload, evaluate, speedup)
from repro.core.workloads import cnn_workloads, lstm_workloads, mlp_workloads


# ---------------------------------------------------------------------------
# generic invariants
# ---------------------------------------------------------------------------

def _mvm_workload(k, n, aimc, coupling="tight"):
    return Workload("t", ((Stage((Op("mvm", k=k, n=n, aimc=aimc),),
                                 weights_bytes=0 if aimc else k * n),),),
                    coupling=coupling, tile_rows=1024)


def test_time_energy_positive():
    for sysc in (HIGH_POWER, LOW_POWER):
        r = evaluate(_mvm_workload(1024, 1024, False), sysc)
        assert r.time_s > 0 and r.energy_j > 0


def test_aimc_beats_digital_on_large_mvm():
    for sysc in (HIGH_POWER, LOW_POWER):
        dig = evaluate(_mvm_workload(2048, 2048, False), sysc)
        ana = evaluate(_mvm_workload(2048, 2048, True), sysc)
        assert ana.time_s < dig.time_s
        assert ana.energy_j < dig.energy_j


def test_loose_slower_than_tight():
    t = evaluate(_mvm_workload(1024, 1024, True, "tight"), HIGH_POWER)
    l = evaluate(_mvm_workload(1024, 1024, True, "loose"), HIGH_POWER)
    assert l.time_s > t.time_s


def test_aimc_constant_time_in_k():
    """CM_PROCESS is O(1) per row block: time grows ~linearly with queue
    length, not quadratically (paper §VII-D)."""
    t1 = evaluate(_mvm_workload(1024, 1024, True), HIGH_POWER).time_s
    t2 = evaluate(_mvm_workload(2048, 2048, True), HIGH_POWER).time_s
    assert t2 / t1 < 3.0          # digital would be ~4x
    d1 = evaluate(_mvm_workload(1024, 1024, False), HIGH_POWER).time_s
    d2 = evaluate(_mvm_workload(2048, 2048, False), HIGH_POWER).time_s
    assert d2 / d1 > 3.5


def test_mvm_energy_scales_with_tile_size():
    spec = AimcTileSpec()
    e_small = spec.mvm_energy_j(256, 256, 1.0)
    e_large = spec.mvm_energy_j(1024, 1024, 1.0)
    assert e_large > e_small
    # 256x256 efficiency figure reproduced: 2*256*256 ops at 12.8 TOp/s/W
    assert e_small == pytest.approx((2 * 256 * 256) / 12.8e12, rel=1e-6)


def test_working_set_stall_kicks_in():
    """Digital weights larger than LLC must add memory-stall time."""
    small = Stage((Op("mvm", k=256, n=256),), weights_bytes=256 * 256)
    big = Stage((Op("mvm", k=4096, n=4096),), weights_bytes=4096 * 4096)
    r_small = evaluate(Workload("s", ((small,),)), HIGH_POWER)
    r_big = evaluate(Workload("b", ((big,),)), HIGH_POWER)
    assert r_big.breakdown["mem_stall"] > r_small.breakdown["mem_stall"]
    assert r_big.llc_mpi > r_small.llc_mpi


# ---------------------------------------------------------------------------
# paper claims (rtol mirrors benchmarks/)
# ---------------------------------------------------------------------------

def test_paper_mlp_headline():
    w = mlp_workloads()
    s, e = speedup(evaluate(w["dig_1c"], HIGH_POWER),
                   evaluate(w["ana_case1"], HIGH_POWER))
    assert s == pytest.approx(12.8, rel=0.15)
    assert e == pytest.approx(12.5, rel=0.15)


def test_paper_mlp_multicore_slower():
    w = mlp_workloads()
    t1 = evaluate(w["ana_case1"], HIGH_POWER).time_s
    t3 = evaluate(w["ana_case3"], HIGH_POWER).time_s
    t4 = evaluate(w["ana_case4"], HIGH_POWER).time_s
    assert t3 > t1 and t4 > t1


def test_paper_lstm_headline():
    w = lstm_workloads(750)
    s, e = speedup(evaluate(w["dig_1c"], HIGH_POWER),
                   evaluate(w["ana_case1"], HIGH_POWER))
    assert s == pytest.approx(9.4, rel=0.15)
    assert e == pytest.approx(9.3, rel=0.15)


def test_paper_lstm_small_net_no_gain():
    w = lstm_workloads(256)
    s, _ = speedup(evaluate(w["dig_1c"], HIGH_POWER),
                   evaluate(w["ana_case1"], HIGH_POWER))
    assert s < 2.5    # paper: 1.0-1.5x band


def test_paper_cnn_headline():
    w = cnn_workloads("S")
    s, e = speedup(evaluate(w["dig"], HIGH_POWER),
                   evaluate(w["ana"], HIGH_POWER))
    assert s == pytest.approx(20.5, rel=0.15)
    assert e == pytest.approx(20.8, rel=0.15)


def test_paper_loose_coupling():
    w = mlp_workloads()
    dig = evaluate(w["dig_1c"], HIGH_POWER)
    tight = evaluate(w["ana_case1"], HIGH_POWER)
    loose = evaluate(w["ana_loose"], HIGH_POWER)
    s_loose, _ = speedup(dig, loose)
    assert s_loose == pytest.approx(4.1, rel=0.15)
    assert loose.time_s / tight.time_s == pytest.approx(3.1, rel=0.2)


def test_paper_cm_process_latency_insensitive():
    """Paper §VII-C: 10x CM_PROCESS latency has minimal impact."""
    w = mlp_workloads()["ana_case1"]
    base = evaluate(w, HIGH_POWER).time_s
    import repro.core.costmodel as cm
    orig = cm.AIMC_TILE
    try:
        cm.AIMC_TILE = AimcTileSpec(latency_s=orig.latency_s * 10)
        slow = evaluate(w, HIGH_POWER).time_s
    finally:
        cm.AIMC_TILE = orig
    assert slow / base < 1.25
