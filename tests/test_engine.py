"""Continuous-batching engine invariants (runtime/engine.py, runtime/batcher.py).

The contracts pinned here (ISSUE 4 acceptance criteria):
  * synchronized arrivals through the engine are BIT-equal to the legacy
    static-batch path;
  * slot reuse after retirement never leaks stale KV/recurrent state;
  * per-request CM_* ledgers sum exactly to the `AimcProgram`'s static
    accounting;
  * shapes are jit-stable: serving a ragged Poisson trace never recompiles
    after warmup;
  * recurrent archs (xlstm, rglru) serve through per-slot state insertion;
  * ``max_new=1`` requests retire at prefill (no 0-step decode loop);
  * transient-vs-terminal failure classification for the decode loop.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution
from repro.runtime.batcher import (Batcher, Request, SlotAllocator,
                                   percentile, poisson_trace, reconcile,
                                   synchronized_trace)
from repro.runtime.engine import ServeEngine, static_generate
from repro.runtime.fault_tolerance import (StragglerMonitor, is_transient,
                                           resilient_step)

EXE = Execution(compute_dtype="float32")


@pytest.fixture(scope="module")
def tfm():
    spec = get_arch("granite-8b")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    return spec, cfg, model, params


def make_engine(tfm, **kw):
    spec, cfg, model, params = tfm
    kw.setdefault("n_slots", 3)
    kw.setdefault("prompt_pad", 8)
    kw.setdefault("max_seq", 24)
    kw.setdefault("family", spec.family)
    kw.setdefault("module", spec.module)
    return ServeEngine(model, cfg, EXE, kw.pop("params", params), **kw)


# ---------------------------------------------------------------------------
# bit-equality vs the legacy static-batch path
# ---------------------------------------------------------------------------

def test_sync_arrivals_bit_equal_static(tfm):
    spec, cfg, model, params = tfm
    eng = make_engine(tfm, n_slots=3)
    eng.warmup()
    reqs = synchronized_trace(3, prompt_len=8, max_new=6, seed=1,
                              vocab=cfg.vocab)
    report = eng.serve(reqs)
    prompts = jnp.asarray([r.prompt for r in reqs], jnp.int32)
    gen, _ = static_generate(model, cfg, EXE, params, prompts, 6, max_seq=24)
    for r in reqs:
        assert report.tokens(r.rid) == [int(t) for t in gen[r.rid]], \
            f"req {r.rid} diverged from the static path"


def test_gen1_requests_are_prefill_only(tfm):
    eng = make_engine(tfm)
    eng.warmup()
    reqs = synchronized_trace(4, prompt_len=6, max_new=1, seed=2, vocab=64)
    report = eng.serve(reqs)
    assert report.n_steps == 0                  # no 0-step decode loop
    assert report.n_prefills == 4
    for rec in report.records.values():
        assert len(rec.tokens) == 1
        assert rec.finish_reason == "length"
        assert rec.decode_vectors == 0
        assert rec.prefill_vectors == 6


# ---------------------------------------------------------------------------
# slot reuse / stale state
# ---------------------------------------------------------------------------

def test_slot_reuse_never_leaks_stale_kv(tfm):
    spec, cfg, model, params = tfm
    # 5 staggered requests through 2 slots: slots are retired and refilled
    # mid-stream. Every request's tokens must equal the same request served
    # through a FRESH engine (identical closure shapes), where no slot ever
    # held another request's state.
    reqs = [Request(rid=i, prompt=tuple(range(2 + i, 10)), max_new=2 + i,
                    arrival=0.0) for i in range(5)]
    eng = make_engine(tfm, n_slots=2)
    eng.warmup()
    report = eng.serve(reqs)
    for r in reqs:
        fresh = make_engine(tfm, n_slots=2)
        fresh.warmup()
        solo = fresh.serve([r])
        assert report.tokens(r.rid) == solo.tokens(r.rid), \
            f"req {r.rid}: slot reuse changed the output"


# ---------------------------------------------------------------------------
# shape stability
# ---------------------------------------------------------------------------

def test_no_recompile_after_warmup_on_ragged_trace(tfm):
    spec, cfg, model, params = tfm
    eng = make_engine(tfm, n_slots=3)
    counts = eng.warmup()
    assert counts == {"prefill": 1, "insert": 1, "decode": 1}
    reqs = poisson_trace(10, rate=400.0, seed=5, prompt_len=(2, 8),
                         max_new=(1, 7), vocab=cfg.vocab)
    report = eng.serve(reqs)
    assert len(report.records) == 10
    assert eng.compile_counts() == {"prefill": 1, "insert": 1, "decode": 1}, \
        "ragged trace recompiled an engine closure after warmup"


# ---------------------------------------------------------------------------
# CM_* ledger reconciliation (programmed AIMC path)
# ---------------------------------------------------------------------------

def test_ledgers_reconcile_with_program(tfm):
    spec, cfg, model, params = tfm
    aimc_cfg = AimcConfig(impl="ref")
    exe = Execution(mode="aimc", aimc=aimc_cfg, compute_dtype="float32",
                    programmed=True)
    program = program_model(params, MappingPlan(), aimc_cfg,
                            jax.random.PRNGKey(3))
    eng = ServeEngine(model, cfg, exe, program.install(params), n_slots=2,
                      prompt_pad=8, max_seq=20, family=spec.family,
                      module=spec.module, program=program)
    eng.warmup()
    reqs = poisson_trace(6, rate=300.0, seed=6, prompt_len=(3, 8),
                         max_new=(1, 5), vocab=cfg.vocab)
    report = eng.serve(reqs)
    # per-request ledger = per-vector counts x that request's vectors
    per_vec = program.mvm_counts()
    ledgers = eng.ledgers(report)
    for rid, rec in report.records.items():
        assert ledgers[rid] == per_vec.scaled(rec.vectors)
    # the device loop's own vector count (prompt lengths at prefill calls,
    # busy lanes at decode calls) must agree with the per-request books —
    # two independent countings, so a double-/under-count breaks this
    assert report.observed_vectors == report.useful_vectors
    # and the books close exactly against the program's static accounting
    ledger_sum, static = reconcile(program, report.records,
                                   report.observed_vectors)
    assert ledger_sum == static
    assert static == per_vec.scaled(report.useful_vectors)


# ---------------------------------------------------------------------------
# EOS retirement
# ---------------------------------------------------------------------------

def test_eos_retires_early(tfm):
    spec, cfg, model, params = tfm
    base = make_engine(tfm, n_slots=1)
    base.warmup()
    req = Request(rid=0, prompt=tuple(range(1, 9)), max_new=8)
    ref = base.serve([req]).tokens(0)
    assert len(ref) == 8
    eos = ref[2]                                 # force an early EOS
    eng = make_engine(tfm, n_slots=1, eos_id=eos)
    eng.warmup()
    report = eng.serve([req])
    rec = report.records[0]
    # the EOS is control, not payload: delivered tokens stop BEFORE it,
    # but its decode vector stays in the CM_* books (tokens holds the
    # prefill token plus decode_vectors - 1 delivered decode tokens)
    assert rec.tokens == ref[:2]
    assert rec.decode_vectors == len(rec.tokens)
    assert rec.finish_reason == "eos"


# ---------------------------------------------------------------------------
# recurrent archs serve through per-slot state insertion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-9b"])
def test_recurrent_arch_serves_ragged_trace(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(model, cfg, EXE, params, n_slots=2, prompt_pad=6,
                      max_seq=16, family=spec.family, module=spec.module,
                      cache_dtype=jnp.float32)
    eng.warmup()
    reqs = poisson_trace(5, rate=500.0, seed=7, prompt_len=(2, 6),
                         max_new=(1, 5), vocab=cfg.vocab)
    report = eng.serve(reqs)
    assert len(report.records) == 5
    assert eng.compile_counts() == {"prefill": 1, "insert": 1, "decode": 1}
    assert report.observed_vectors == report.useful_vectors
    for rec in report.records.values():
        assert 1 <= len(rec.tokens) <= rec.request.max_new
        assert rec.vectors == (len(rec.request.prompt)
                               + len(rec.tokens) - 1)


def test_recurrent_engine_matches_manual_decode_loop():
    spec = get_arch("xlstm-350m")
    cfg = spec.smoke_cfg
    model = spec.model_module()
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompt = tuple(range(1, 7))
    eng = ServeEngine(model, cfg, EXE, params, n_slots=1, prompt_pad=6,
                      max_seq=16, family=spec.family, module=spec.module,
                      cache_dtype=jnp.float32)
    eng.warmup()
    got = eng.serve([Request(rid=0, prompt=prompt, max_new=5)]).tokens(0)
    # reference: feed the prompt token by token, then greedy-decode
    cache = model.init_cache(cfg, 1, 16, jnp.float32)
    toks = jnp.asarray(prompt, jnp.int32)[None]
    for t in range(len(prompt)):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                          cfg, EXE)
    ref = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(4):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[ref[-1]]], jnp.int32), cfg, EXE)
        ref.append(int(jnp.argmax(logits[0, -1])))
    assert got == ref


# ---------------------------------------------------------------------------
# transformer ragged decode == lockstep decode at equal lengths
# ---------------------------------------------------------------------------

def test_ragged_decode_matches_lockstep(tfm):
    spec, cfg, model, params = tfm
    toks = jax.random.randint(jax.random.PRNGKey(4), (3, 8), 1, cfg.vocab)
    _, cache = model.prefill(params, toks, cfg, EXE, max_seq=16,
                             cache_dtype=jnp.float32)
    nxt = jnp.ones((3, 1), jnp.int32)
    l_lock, c_lock = model.decode_step(params, cache, nxt, cfg, EXE)
    l_rag, c_rag = model.decode_step(params, cache, nxt, cfg, EXE,
                                     ragged=True)
    assert jnp.array_equal(l_lock, l_rag)
    assert all(jnp.array_equal(c_lock[k], c_rag[k]) for k in c_lock)


# ---------------------------------------------------------------------------
# batcher mechanics
# ---------------------------------------------------------------------------

def test_batcher_admission_and_slots():
    reqs = [Request(rid=0, prompt=(1,), arrival=0.5),
            Request(rid=1, prompt=(1,), arrival=0.0, max_new=9),
            Request(rid=2, prompt=(1,), arrival=0.0, max_new=2)]
    q = Batcher(reqs, policy="fifo")
    assert q.pop_ready(0.0).rid == 1             # arrival order, rid tiebreak
    assert q.pop_ready(0.0).rid == 2
    assert q.pop_ready(0.0) is None              # rid 0 hasn't arrived yet
    assert q.next_arrival() == 0.5
    assert q.pop_ready(0.5).rid == 0
    assert len(q) == 0

    q = Batcher(reqs, policy="sjf")
    assert q.pop_ready(0.0).rid == 2             # shortest max_new first
    # budget-first even under staggered arrivals: rid 0 (max_new=8) arrived
    # later than rid 1 (max_new=9) but is still admitted first
    assert q.pop_ready(0.5).rid == 0
    assert q.pop_ready(0.5).rid == 1

    slots = SlotAllocator(2)
    a, b = slots.alloc(10), slots.alloc(11)
    assert {a, b} == {0, 1} and slots.n_free == 0
    assert slots.release(a) == 10
    assert slots.alloc(12) == a                  # freed slot is reused

    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([5.0], 99) == 5.0


def test_request_validation():
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(), max_new=4)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(1,), max_new=0)


def test_prompt_longer_than_pad_rejected(tfm):
    eng = make_engine(tfm, n_slots=1, prompt_pad=4)
    eng.warmup()
    with pytest.raises(ValueError, match="exceeds"):
        eng.serve([Request(rid=0, prompt=tuple(range(1, 9)), max_new=2)])


# ---------------------------------------------------------------------------
# fault tolerance: transient vs terminal classification
# ---------------------------------------------------------------------------

def test_is_transient_classification():
    # infrastructure flakes retry
    assert is_transient(RuntimeError("UNAVAILABLE: connection reset"))
    assert is_transient(RuntimeError("DEADLINE_EXCEEDED: collective"))
    assert is_transient(OSError("stale file handle"))
    # deterministic failures are terminal — retrying replays the failure
    assert not is_transient(RuntimeError(
        "RESOURCE_EXHAUSTED: out of memory allocating 32.0GiB"))
    assert not is_transient(OSError("RESOURCE_EXHAUSTED: disk full"))
    assert not is_transient(RuntimeError("INVALID_ARGUMENT: shape mismatch"))
    # an unrecognized RuntimeError is a bug, not a flake
    assert not is_transient(RuntimeError("list index out of range"))
    assert not is_transient(ValueError("UNAVAILABLE"))   # wrong type


def test_resilient_step_raises_terminal_immediately():
    calls = []

    def oom(x):
        calls.append(x)
        raise RuntimeError("RESOURCE_EXHAUSTED: out of memory")

    wrapped = resilient_step(oom, max_retries=3)
    with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
        wrapped(0)
    assert len(calls) == 1                       # no retry of an OOM


def test_resilient_step_does_not_retry_plain_bugs():
    calls = []

    def buggy(x):
        calls.append(x)
        raise RuntimeError("object has no attribute 'foo'")

    wrapped = resilient_step(buggy, max_retries=3)
    with pytest.raises(RuntimeError):
        wrapped(0)
    assert len(calls) == 1


def test_resilient_step_still_retries_flakes():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("UNAVAILABLE: transient link error")
        return x + 1

    wrapped = resilient_step(flaky, max_retries=3)
    assert wrapped(1) == 2
    assert len(calls) == 3


def test_straggler_monitor_flags_slow_step_inside_warmup_window():
    # regression: the first sample alone used to seed the EWMA, so a slow
    # step at position 2..warmup could never be flagged
    mon = StragglerMonitor(threshold=2.0, alpha=0.1, warmup=3)
    assert not mon.record(1, 0.010)
    assert not mon.record(2, 0.010)
    assert not mon.record(3, 0.012)   # window full: median(0.010..0.012) seeds
    assert mon.record(4, 0.100)       # first post-seed sample CAN be flagged
    assert mon.flagged and mon.flagged[0][0] == 4


def test_straggler_monitor_slow_first_step_does_not_poison_baseline():
    # regression: a slow FIRST sample used to become the baseline, hiding
    # every later straggler behind an inflated EWMA
    mon = StragglerMonitor(threshold=2.0, alpha=0.1, warmup=3)
    mon.record(1, 0.500)              # slow outlier lands first
    mon.record(2, 0.010)
    mon.record(3, 0.010)
    assert abs(mon.ewma - 0.010) < 1e-12   # median seed ignores the outlier
    assert mon.record(4, 0.030)            # 3x the real baseline -> flagged
    # and the seeded baseline keeps tracking normal steps
    assert not mon.record(5, 0.011)
