"""Multi-tenant model server (runtime/server.py, DESIGN.md §12).

The acceptance bars, pinned: two models co-programmed in one process serve
an interleaved trace with per-tenant slot quotas enforced (no tenant
starves; saturated shares track weights exactly); summed per-tenant CM_*
ledgers reconcile EXACTLY against each model's ``program.mvm_counts()``;
and single-model serving through the server is BIT-EQUAL to the PR-4
`ServeEngine.serve` loop on the same engine object.
"""

import pytest

from repro.configs import get_arch
from repro.runtime.batcher import Request, synchronized_trace
from repro.runtime.server import ModelServer, ModelSpec, build_server
from repro.runtime.tenancy import (TenantPolicy, TenantRequest, jains_index,
                                   mixed_poisson_trace, reconcile_tenants)

SPECS = [ModelSpec("granite_8b", "granite-8b", "aimc"),
         ModelSpec("xlstm_350m", "xlstm-350m", "digital")]
TENANTS = [TenantPolicy("premium", "granite_8b", weight=2.0),
           TenantPolicy("standard", "granite_8b", weight=1.0,
                        admission="sjf"),
           TenantPolicy("batch", "xlstm_350m", weight=1.0)]
N_SLOTS, PAD, MAX_SEQ = 3, 8, 22


@pytest.fixture(scope="module")
def server():
    srv = build_server(SPECS, TENANTS, smoke=True, n_slots=N_SLOTS,
                       prompt_pad=PAD, max_seq=MAX_SEQ)
    srv.warmup()
    return srv


def _vocab_of():
    return {s.name: get_arch(s.arch).smoke_cfg.vocab for s in SPECS}


# ---------------------------------------------------------------------------
# co-programming / registry
# ---------------------------------------------------------------------------

def test_two_models_share_one_pool(server):
    assert server.pool is not None
    assert server.pool.labels == ["granite_8b"]     # only the AIMC member
    assert server.engines["granite_8b"].program is not None
    assert server.engines["xlstm_350m"].program is None
    assert 0.0 < server.pool.utilization <= 1.0


def test_registry_validation(server):
    eng = server.engines["granite_8b"]
    with pytest.raises(ValueError, match="unregistered model"):
        ModelServer({"granite_8b": eng},
                    [TenantPolicy("t", "nonexistent")])
    with pytest.raises(ValueError, match="duplicate tenant"):
        ModelServer({"granite_8b": eng},
                    [TenantPolicy("t", "granite_8b"),
                     TenantPolicy("t", "granite_8b")])
    with pytest.raises(ValueError, match="at least one"):
        ModelServer({}, [TenantPolicy("t", "granite_8b")])
    with pytest.raises(ValueError, match="exec_mode"):
        ModelSpec("m", "granite-8b", "analog")


def test_trace_validation(server):
    with pytest.raises(ValueError, match="unknown tenant"):
        server.serve([TenantRequest("nobody",
                                    Request(rid=0, prompt=(1, 2)))])
    with pytest.raises(ValueError, match="unique"):
        server.serve([TenantRequest("premium",
                                    Request(rid=0, prompt=(1, 2))),
                      TenantRequest("batch",
                                    Request(rid=0, prompt=(1, 2)))])


# ---------------------------------------------------------------------------
# mixed-trace serving: progress + exact books
# ---------------------------------------------------------------------------

def test_mixed_trace_progress_and_exact_ledgers(server):
    trace = mixed_poisson_trace(TENANTS, 12, 150.0, vocab_of=_vocab_of(),
                                seed=9, prompt_len=(3, PAD),
                                max_new=(2, 8))
    report = server.serve(trace)
    assert sum(len(r.records) for r in report.model_reports.values()) == 12

    stats = report.tenant_stats()
    for name, st in stats.items():
        if st.n_requests:
            assert st.generated_tokens > 0, f"tenant {name} starved"
            assert st.p99_ttft_s >= st.p50_ttft_s >= 0.0

    # books close per model: device-loop count == per-request records, and
    # summed per-tenant ledgers == program.mvm_counts() scaled by it
    for m, rep in report.model_reports.items():
        assert rep.observed_vectors == rep.useful_vectors
    recon = server.reconcile(report)
    assert recon["granite_8b"] is True
    assert recon["xlstm_350m"] is None              # digital: counts only
    prog = server.engines["granite_8b"].program
    rep = report.model_reports["granite_8b"]
    led_sum, static = reconcile_tenants(prog, rep.records, report.tenant_of,
                                        rep.observed_vectors)
    assert led_sum == static

    # interleaved multi-model serving stays shape-stable (no recompiles)
    assert all(c == {"prefill": 1, "insert": 1, "decode": 1}
               for c in server.compile_counts().values())


# ---------------------------------------------------------------------------
# quota enforcement under saturation
# ---------------------------------------------------------------------------

def test_saturated_shares_track_weights(server):
    """Synchronized equal backlogs from both granite tenants, run CUT while
    both still have work: the decode-slot split must be exactly the 2:1
    weight ratio (steady state (2,1) on 3 slots), and weight-normalized
    fairness must be perfect."""
    vocab = get_arch("granite-8b").smoke_cfg.vocab
    trace = []
    for i in range(12):
        trace.append(TenantRequest(
            tenant="premium" if i % 2 == 0 else "standard",
            request=Request(rid=500 + i,
                            prompt=tuple((7 * j + i) % (vocab - 1) + 1
                                         for j in range(6)),
                            max_new=12, arrival=0.0)))
    report = server.serve(trace, max_steps=30)
    shares = {}
    for name in ("premium", "standard"):
        recs = report.tenant_records(name)
        shares[name] = sum(r.decode_vectors for r in recs.values())
    assert shares["standard"] > 0                   # nobody starved
    assert shares["premium"] == 2 * shares["standard"]
    fairness = jains_index([shares["premium"] / 2.0,
                            shares["standard"] / 1.0])
    assert fairness == pytest.approx(1.0)
    # the cut run's books still close exactly (cancelled work is booked)
    assert server.reconcile(report)["granite_8b"] is True


def test_fair_shares_surface(server):
    shares = server.fair_shares("granite_8b")
    assert shares == {"premium": 2.0, "standard": 1.0}
    assert server.fair_shares("xlstm_350m") == {"batch": 3.0}


# ---------------------------------------------------------------------------
# single-model serving through the server == the PR-4 engine loop
# ---------------------------------------------------------------------------

def test_single_model_bit_equal_to_engine(server):
    """Wrapping ONE engine in a single-tenant ModelServer and serving the
    same trace must produce bit-identical tokens to `ServeEngine.serve` —
    the session primitives factor the loop, they never reorder it."""
    eng = server.engines["granite_8b"]
    vocab = get_arch("granite-8b").smoke_cfg.vocab
    reqs = synchronized_trace(5, prompt_len=PAD, max_new=6, seed=13,
                              vocab=vocab)
    direct = eng.serve(reqs)
    solo = ModelServer({"granite_8b": eng},
                       [TenantPolicy("only", "granite_8b")])
    wrapped = solo.serve([TenantRequest("only", r) for r in reqs])
    rep = wrapped.model_reports["granite_8b"]
    assert set(rep.records) == set(direct.records)
    for rid in direct.records:
        assert rep.records[rid].tokens == direct.records[rid].tokens
        assert (rep.records[rid].finish_reason
                == direct.records[rid].finish_reason)
    assert rep.observed_vectors == direct.observed_vectors
