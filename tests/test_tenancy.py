"""Tenant policy layer (runtime/tenancy.py).

The quota scheduler, fairness metric, SLO stats and per-tenant CM_* ledger
aggregation are all host-side pure functions — pinned here without any
device work (the server-level integration lives in tests/test_server.py).
"""

import math

import pytest

from repro.core.isa import CmCounts
from repro.runtime.batcher import Request, RequestRecord
from repro.runtime.tenancy import (TenantPolicy, fair_shares, jains_index,
                                   mixed_poisson_trace, pick_tenant,
                                   reconcile_tenants, tenant_ledgers,
                                   tenant_stats)

POLICIES = {
    "premium": TenantPolicy("premium", "m1", weight=2.0),
    "standard": TenantPolicy("standard", "m1", weight=1.0),
    "batch": TenantPolicy("batch", "m2", weight=1.0),
}


# ---------------------------------------------------------------------------
# policy validation
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError, match="name"):
        TenantPolicy("", "m1")
    with pytest.raises(ValueError, match="model"):
        TenantPolicy("t", "")
    with pytest.raises(ValueError, match="weight"):
        TenantPolicy("t", "m1", weight=0.0)
    with pytest.raises(ValueError, match="admission"):
        TenantPolicy("t", "m1", admission="priority")


# ---------------------------------------------------------------------------
# quota scheduling
# ---------------------------------------------------------------------------

def test_pick_tenant_weighted_deficit():
    """The pick minimizes in_flight/weight: a weight-2 tenant holding one
    slot (ratio 0.5) yields to an idle weight-1 tenant (ratio 0), but beats
    it once both hold one (0.5 < 1.0)."""
    cands = ["premium", "standard"]
    assert pick_tenant(cands, {}, POLICIES) == "premium"      # tie: name order
    assert pick_tenant(cands, {"premium": 1}, POLICIES) == "standard"
    assert pick_tenant(cands, {"premium": 1, "standard": 1},
                       POLICIES) == "premium"
    assert pick_tenant(cands, {"premium": 2, "standard": 1},
                       POLICIES) == "premium"                 # 1.0 vs 1.0: name
    with pytest.raises(ValueError):
        pick_tenant([], {}, POLICIES)


def test_pick_tenant_converges_to_weighted_shares():
    """Simulated slot churn: admissions via pick_tenant, releases round-
    robin — the admission tally converges to the 2:1 weight split."""
    in_flight = {"premium": 0, "standard": 0}
    admitted = {"premium": 0, "standard": 0}
    held = []
    for step in range(300):
        while sum(in_flight.values()) < 3:          # 3 slots, always backlog
            t = pick_tenant(list(in_flight), in_flight, POLICIES)
            in_flight[t] += 1
            admitted[t] += 1
            held.append(t)
        in_flight[held.pop(0)] -= 1                 # oldest admission retires
    ratio = admitted["premium"] / admitted["standard"]
    assert 1.8 <= ratio <= 2.2


def test_fair_shares_partition_slots():
    shares = fair_shares(list(POLICIES.values()), "m1", n_slots=3)
    assert shares == {"premium": 2.0, "standard": 1.0}
    assert "batch" not in shares
    assert math.isclose(sum(shares.values()), 3.0)


def test_jains_index():
    assert jains_index([5, 5, 5]) == pytest.approx(1.0)
    assert jains_index([1, 0, 0]) == pytest.approx(1 / 3)
    assert jains_index([]) == 0.0
    assert jains_index([0, 0]) == 0.0
    # scale invariance
    assert jains_index([1, 2, 3]) == pytest.approx(jains_index([10, 20, 30]))


# ---------------------------------------------------------------------------
# SLO stats
# ---------------------------------------------------------------------------

def _rec(rid, arrival, t_first, t_done, n_tokens, prefill, decode):
    r = RequestRecord(request=Request(rid=rid, prompt=(1,) * prefill,
                                      max_new=max(n_tokens, 1),
                                      arrival=arrival))
    r.t_first, r.t_done = t_first, t_done
    r.tokens = list(range(n_tokens))
    r.prefill_vectors, r.decode_vectors = prefill, decode
    return r


def test_tenant_stats_tpot_and_slo():
    pol = TenantPolicy("t", "m1", slo_ttft_s=0.05, slo_tpot_s=0.02)
    records = {
        0: _rec(0, arrival=0.0, t_first=0.01, t_done=0.05, n_tokens=5,
                prefill=4, decode=4),
        1: _rec(1, arrival=0.0, t_first=0.02, t_done=0.02, n_tokens=1,
                prefill=3, decode=0),           # prefill-only: no TPOT sample
    }
    st = tenant_stats(pol, records, makespan_s=0.1)
    assert st.n_requests == 2 and st.generated_tokens == 6
    assert st.vectors == 4 + 4 + 3
    assert st.tok_s == pytest.approx(60.0)
    # TPOT from req 0 only: (0.05 - 0.01) / 4 = 0.01
    assert st.p50_tpot_s == pytest.approx(0.01)
    assert st.slo_ttft_ok is True and st.slo_tpot_ok is True
    tight = TenantPolicy("t", "m1", slo_ttft_s=0.005)
    assert tenant_stats(tight, records, 0.1).slo_ttft_ok is False
    # no declared target -> no verdict
    assert tenant_stats(TenantPolicy("t", "m1"), records, 0.1).slo_ttft_ok \
        is None


# ---------------------------------------------------------------------------
# per-tenant CM_* ledgers
# ---------------------------------------------------------------------------

class _StubProgram:
    """mvm_counts is the only surface the ledger math touches."""

    def mvm_counts(self):
        return CmCounts(queue=3, process=2, dequeue=1, queue_bytes=12)


def test_tenant_ledgers_sum_exactly():
    records = {
        0: _rec(0, 0, 0, 0, n_tokens=4, prefill=5, decode=3),   # 8 vectors
        1: _rec(1, 0, 0, 0, n_tokens=2, prefill=4, decode=1),   # 5 vectors
        2: _rec(2, 0, 0, 0, n_tokens=1, prefill=2, decode=0),   # 2 vectors
    }
    tenant_of = {0: "a", 1: "b", 2: "a"}
    prog = _StubProgram()
    led = tenant_ledgers(prog, records, tenant_of)
    assert led["a"] == prog.mvm_counts().scaled(10)
    assert led["b"] == prog.mvm_counts().scaled(5)
    total, static = reconcile_tenants(prog, records, tenant_of)
    assert total == static == prog.mvm_counts().scaled(15)
    # an observed count that disagrees with the books must NOT reconcile
    total, static = reconcile_tenants(prog, records, tenant_of,
                                      observed_vectors=14)
    assert total != static


# ---------------------------------------------------------------------------
# mixed traces
# ---------------------------------------------------------------------------

def test_mixed_poisson_trace_deterministic_and_routed():
    pols = list(POLICIES.values())
    vocab_of = {"m1": 64, "m2": 16}
    a = mixed_poisson_trace(pols, 40, 100.0, vocab_of=vocab_of, seed=3)
    b = mixed_poisson_trace(pols, 40, 100.0, vocab_of=vocab_of, seed=3)
    assert a == b                                   # replayable
    rids = [tr.request.rid for tr in a]
    assert rids == sorted(rids) and len(set(rids)) == len(rids)
    arrivals = [tr.request.arrival for tr in a]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0
    assert {tr.tenant for tr in a} <= set(POLICIES)
    for tr in a:
        vocab = vocab_of[POLICIES[tr.tenant].model]
        assert all(1 <= t < vocab for t in tr.request.prompt)
    # weight-proportional assignment (2:1:1 over 40 draws, loose bound)
    n_premium = sum(tr.tenant == "premium" for tr in a)
    assert 10 <= n_premium <= 30


def test_mixed_poisson_trace_validation():
    pols = list(POLICIES.values())
    with pytest.raises(ValueError, match="rate"):
        mixed_poisson_trace(pols, 4, 0.0, vocab_of={"m1": 8, "m2": 8})
    with pytest.raises(ValueError, match="missing models"):
        mixed_poisson_trace(pols, 4, 10.0, vocab_of={"m1": 8})
    with pytest.raises(ValueError, match="policy"):
        mixed_poisson_trace([], 4, 10.0, vocab_of={})
