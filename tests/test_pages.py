"""Page allocator + prefix cache invariants (runtime/pages.py).

Deterministic units pin the API contract; the hypothesis property tests
drive random alloc/retain/release/put/evict interleavings and assert the
exact-partition ledger never drifts: no double free, no leak, and a shared
page's refcount reaches zero exactly when its last sharer lets go."""

import pytest

from repro.runtime.pages import (SCRATCH, PageAllocator, PrefixCache,
                                 page_keys)


# ---------------------------------------------------------------------------
# deterministic units
# ---------------------------------------------------------------------------

def test_scratch_reserved_and_alloc_shapes():
    a = PageAllocator(8, page_size=4)
    assert a.n_free == 7          # page 0 is scratch, never on the free list
    pids = a.alloc(3, owner="r0")
    assert SCRATCH not in pids and len(set(pids)) == 3
    assert a.alloc(5, owner="r1") is None      # all-or-nothing shortage
    assert a.n_free == 4                       # the failed grab left nothing
    assert a.verify()


def test_release_frees_exactly_at_zero():
    a = PageAllocator(4, page_size=2)
    (pid,) = a.alloc(1, owner="r0")
    a.retain(pid)
    assert a.release(pid) is False             # one sharer remains
    assert a.n_free == 2
    assert a.release(pid) is True              # last sharer -> freed
    assert a.n_free == 3
    with pytest.raises(ValueError, match="double free"):
        a.release(pid)
    assert a.verify()


def test_retain_unheld_rejected():
    a = PageAllocator(4, page_size=2)
    with pytest.raises(ValueError):
        a.retain(SCRATCH)
    with pytest.raises(ValueError):
        a.retain(2)


def test_page_keys_chained():
    p = 4
    keys_ab = page_keys(list(range(8)), p)
    keys_ab2 = page_keys(list(range(8)) + [99], p)      # partial page 3rd
    assert len(keys_ab) == 2 and keys_ab == keys_ab2
    # a differing FIRST page changes every downstream key (chained hash)
    keys_cd = page_keys([7] + list(range(1, 8)), p)
    assert keys_cd[0] != keys_ab[0] and keys_cd[1] != keys_ab[1]
    # same page-1 content after a different page 0 must NOT collide
    assert page_keys([0, 0, 0, 0, 4, 5, 6, 7], p)[1] != keys_ab[1]


def test_prefix_cache_put_lookup_evict():
    a = PageAllocator(8, page_size=4)
    c = PrefixCache(a)
    keys = page_keys(list(range(8)), 4)
    pids = a.alloc(2, owner="r0")
    for k, pid in zip(keys, pids):
        assert c.put(k, pid)           # retains: refcount 2 (request+cache)
    assert [a.refcount(p) for p in pids] == [2, 2]
    assert c.lookup(keys) == pids
    assert c.evictable() == 0          # producer still holds both
    for pid in pids:
        a.release(pid)                 # producer retires
    assert c.evictable() == 2
    assert c.evict(1) == 1             # LRU first
    assert a.verify() and a.n_free == 6
    got = c.lookup(keys, peek=True)
    assert got.count(None) == 1


def test_prefix_cache_adopt_takes_callers_ref():
    a = PageAllocator(4, page_size=2)
    c = PrefixCache(a)
    (pid,) = a.alloc(1, owner="cache")
    c.put(b"k", pid, adopt=True)
    assert a.refcount(pid) == 1        # the cache's ref IS the alloc ref
    assert c.evict(1) == 1
    assert a.n_free == 3 and a.verify()


def test_duplicate_put_first_producer_wins():
    a = PageAllocator(8, page_size=2)
    c = PrefixCache(a)
    (p1,) = a.alloc(1, owner="r0")
    (p2,) = a.alloc(1, owner="r1")
    assert c.put(b"k", p1)
    assert not c.put(b"k", p2)         # duplicate: no ref taken
    assert a.refcount(p2) == 1
    assert c.lookup([b"k"]) == [p1]


def test_evict_respects_protect():
    a = PageAllocator(4, page_size=2)
    c = PrefixCache(a)
    (pid,) = a.alloc(1, owner="r0")
    c.put(b"k", pid)
    a.release(pid)                      # cache is sole sharer
    assert c.evictable(protect=[pid]) == 0
    assert c.evict(1, protect=[pid]) == 0
    assert c.evict(1) == 1




# ---------------------------------------------------------------------------
# eviction pressure at pool sizes near one request (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def _pressured_cache():
    """A pool of 4 pages fully tenanted by cache-only entries k0..k3,
    touched in insertion order (k0 is LRU)."""
    a = PageAllocator(5, page_size=2)          # 4 usable + null page
    c = PrefixCache(a)
    for i in range(4):
        (pid,) = a.alloc(1, owner=f"r{i}")
        c.put(f"k{i}".encode(), pid)
        a.release(pid)                         # cache is sole sharer
    return a, c


def test_eviction_order_is_lru_and_deterministic():
    a1, c1 = _pressured_cache()
    a2, c2 = _pressured_cache()
    # identical state -> identical victims, oldest tick first
    assert c1.evict(2) == 2 and c2.evict(2) == 2
    for c in (c1, c2):
        assert b"k0" not in c and b"k1" not in c
        assert b"k2" in c and b"k3" in c
    assert a1.ledger() == a2.ledger()
    # a lookup REFRESHES the tick: the touched entry survives the next wave
    c1.lookup([b"k2"])
    assert c1.evict(1) == 1
    assert b"k2" in c1 and b"k3" not in c1
    assert a1.verify()


def test_full_pool_of_cache_entries_is_fully_reclaimable():
    a, c = _pressured_cache()
    assert a.n_free == 0 and c.evictable() == 4
    # a new request the size of the WHOLE pool gets in after eviction
    assert a.alloc(4, owner="big") is None
    assert c.evict(4) == 4
    pids = a.alloc(4, owner="big")
    assert pids is not None and len(pids) == 4
    assert a.verify() and len(c) == 0
    # books: every page held by the request, none lost to the cache
    led = a.ledger()
    assert led["held"] == 4 and led["free"] == 0
