"""Property-based (hypothesis) tests: CM_* accounting invariants and the
DAC/ADC round-trips the crossbar pipeline relies on.

Deterministic twins of the isa invariants live in `tests/test_isa.py` so
coverage survives without the optional dep.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import isa
from repro.core.quant import (QMAX, QMIN, adc_quantize, dequantize, quantize,
                              sym_scale)

dims = st.integers(min_value=1, max_value=8192)
tiles = st.integers(min_value=1, max_value=4096)
counts = st.builds(
    isa.CmCounts,
    queue=st.integers(0, 10**6), process=st.integers(0, 10**4),
    dequeue=st.integers(0, 10**6), initialize=st.integers(0, 10**8),
    queue_bytes=st.integers(0, 10**7), dequeue_bytes=st.integers(0, 10**7))


# ---------------------------------------------------------------------------
# CmCounts algebra
# ---------------------------------------------------------------------------

@given(counts, counts, st.integers(0, 1000))
@settings(max_examples=100, deadline=None)
def test_add_scaled_consistency(a, b, m):
    """scaled is repeated addition; addition is commutative; scaling
    distributes — the ledger algebra the schedule/benchmarks rely on."""
    assert a + b == b + a
    assert (a + b).scaled(m) == a.scaled(m) + b.scaled(m)
    total = isa.CmCounts()
    for _ in range(min(m, 7)):
        total = total + a
    assert total == a.scaled(min(m, 7))


@given(st.lists(counts, max_size=12))
@settings(max_examples=50, deadline=None)
def test_total_is_left_fold(cs):
    tot = isa.total(cs)
    ref = isa.CmCounts()
    for c in cs:
        ref = ref + c
    assert tot == ref


# ---------------------------------------------------------------------------
# mvm_counts invariants
# ---------------------------------------------------------------------------

@given(dims, dims, dims, tiles)
@settings(max_examples=200, deadline=None)
def test_mvm_counts_monotone(k, k2, n, tile_rows):
    """Instruction counts are monotone in both matrix dimensions."""
    lo, hi = sorted((k, k2))
    a, b = isa.mvm_counts(lo, n, tile_rows), isa.mvm_counts(hi, n, tile_rows)
    assert a.queue <= b.queue
    assert a.process <= b.process
    assert a.dequeue <= b.dequeue
    assert a.queue_bytes <= b.queue_bytes


@given(dims, dims, tiles)
@settings(max_examples=200, deadline=None)
def test_row_block_structure(k, n, tile_rows):
    """process is exactly the row-block count; dequeue scales with it."""
    c = isa.mvm_counts(k, n, tile_rows)
    rb = -(-k // tile_rows)
    assert c.process == rb
    assert c.dequeue == -(-n // 4) * rb
    assert c.queue == -(-k // 4)
    assert c.queue_bytes == k and c.dequeue_bytes == n * rb
    if tile_rows >= k:
        assert c.process == 1


# ---------------------------------------------------------------------------
# quant round-trips (the fixed-point core of CM_QUEUE / CM_DEQUEUE)
# ---------------------------------------------------------------------------

floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                   allow_infinity=False, width=32)


@given(st.lists(st.integers(QMIN, QMAX), min_size=1, max_size=64),
       st.floats(min_value=1e-4, max_value=1e3, allow_nan=False, width=32))
@settings(max_examples=100, deadline=None)
def test_int8_codes_roundtrip_exactly(codes, scale):
    """quantize(dequantize(q)) == q: programmed codes survive a digital
    round-trip bit for bit (weights-stationary determinism)."""
    q = jnp.asarray(codes, jnp.int8)
    s = jnp.float32(scale)
    np.testing.assert_array_equal(
        np.asarray(quantize(dequantize(q, s), s)), np.asarray(q))


@given(st.lists(st.integers(QMIN, QMAX), min_size=1, max_size=64),
       st.floats(min_value=0.5, max_value=1e5, allow_nan=False, width=32))
@settings(max_examples=100, deadline=None)
def test_adc_codes_roundtrip_exactly(codes, step):
    """adc_quantize(c * step, step) == c for in-range codes."""
    c = jnp.asarray(codes, jnp.float32)
    got = adc_quantize(c * jnp.float32(step), jnp.float32(step))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(c, dtype=np.int32))


@given(st.lists(floats, min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_dac_roundtrip_error_within_half_lsb(vals):
    x = jnp.asarray(vals, jnp.float32)
    s = sym_scale(x)
    err = jnp.abs(x - dequantize(quantize(x, s), s))
    assert float(err.max()) <= float(s) * 0.5 + 1e-6
