"""Executable paper networks (models/paper_nets.py) + AIMClib semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aimc import AimcConfig
from repro.core.aimclib import AimcContext
from repro.core import isa
from repro.models import paper_nets

CLEAN = AimcConfig(tile_rows=1024, impl="ref")


def test_mlp_aimc_close_to_digital():
    p = paper_nets.mlp_init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1024))
    y_dig = paper_nets.mlp_forward_digital(p, x)
    y_ana, ctx = paper_nets.mlp_forward_aimc(p, x, CLEAN)
    rel = float(jnp.linalg.norm(y_ana - y_dig)
                / jnp.maximum(jnp.linalg.norm(y_dig), 1e-9))
    assert rel < 0.06
    counts = ctx.instruction_counts()
    # one queue+process+dequeue sweep per layer per inference
    assert counts.process == 2
    assert counts.queue == 2 * (1024 // 4)


def test_lstm_gate_packing_equivalence():
    """map_gates (§VIII-D, one CM_PROCESS for all four gates) must equal the
    four separate MVMs up to quantization granularity."""
    nh, xd = 64, 10
    p = paper_nets.lstm_init(jax.random.PRNGKey(0), nh, xd, 8)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 3, xd))
    y_dig = paper_nets.lstm_forward_digital(p, xs, nh)
    y_ana, ctx = paper_nets.lstm_forward_aimc(p, xs, nh, CLEAN)
    assert y_ana.shape == y_dig.shape
    # softmax outputs: compare distributions
    err = float(jnp.max(jnp.abs(y_ana - y_dig)))
    assert err < 0.2
    top_match = float(jnp.mean((jnp.argmax(y_ana, -1)
                                == jnp.argmax(y_dig, -1)).astype(jnp.float32)))
    assert top_match > 0.7


def test_cnn_im2col_equals_conv():
    """The crossbar conv (im2col x weight-matrix) == jax.lax conv."""
    p = paper_nets.cnn_init(jax.random.PRNGKey(0), "F", img=64, n_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    w = p["convs"][0]                      # [11, 11, 3, 64] stride 4
    patches, ho, wo = paper_nets._im2col(x, 11, 4, 0)
    y_mat = (patches.reshape(-1, 11 * 11 * 3) @ w.reshape(-1, 64))
    y_mat = y_mat.reshape(2, ho, wo, 64)
    y_conv = jax.lax.conv_general_dilated(
        x, w, (4, 4), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y_mat), np.asarray(y_conv),
                               rtol=1e-4, atol=1e-4)


def test_cnn_forward_shapes_digital_vs_aimc():
    p = paper_nets.cnn_init(jax.random.PRNGKey(0), "F", img=64, n_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64, 3))
    y_dig = paper_nets.cnn_forward(p, x, "F", None)
    y_ana, ctx = paper_nets.cnn_forward(p, x, "F", CLEAN,
                                        key=jax.random.PRNGKey(2))
    assert y_dig.shape == y_ana.shape == (2, 10)
    assert np.allclose(np.asarray(jnp.sum(y_dig, -1)), 1.0, atol=1e-4)
    # conv layers mapped -> 5 matrices on the context
    assert len(ctx.tile_map().blocks_for("conv0")) >= 1


def test_aimclib_instruction_flow():
    ctx = AimcContext(AimcConfig(tile_rows=128, impl="ref"))
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 32)) * 0.1
    ctx.map_matrix("fc", w)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
    ctx.queue_vector("fc", x)
    ctx.process("fc")
    y = ctx.dequeue_vector("fc")
    assert y.shape == (4, 32)
    with pytest.raises(RuntimeError):
        ctx.dequeue_vector("fc")           # double dequeue
    with pytest.raises(KeyError):
        ctx.linear("nope", x)


def test_isa_counts():
    c = isa.mvm_counts(1024, 1024, 512)
    assert c.process == 2                  # two row blocks
    assert c.queue == 256                  # 1024/4 packed registers
    assert c.dequeue == 2 * 256
    assert c.queue_bytes == 1024
    total = c + c.scaled(2)
    assert total.process == 6
