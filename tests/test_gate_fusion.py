"""Gate-fused multi-MVM routing in the model zoo (kernel v2).

`fuse_gate_stacks` (models/xlstm.py, models/transformer.py) rewrites an
install()ed parameter tree so same-shape projection groups sharing an input
(QKV, up/gate FFN pairs) run as ONE stacked weight-stationary kernel launch.
Fusion is a performance transform: forward/decode outputs must stay
bit-equal (noise off) to the per-projection path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.aimc import AimcConfig, AimcLinearState
from repro.core.program import MappingPlan, program_model
from repro.models.layers import Execution

CFG = AimcConfig(tile_rows=128, impl="ref")


def _programmed(arch_id):
    spec = get_arch(arch_id)
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    program = program_model(params, MappingPlan(), CFG)
    installed = program.install(params)
    exe = Execution(mode="aimc", aimc=CFG, compute_dtype="float32",
                    programmed=True)
    return model, cfg, installed, exe


def test_xlstm_fuse_gate_stacks_rewrites_tree():
    model, cfg, installed, exe = _programmed("xlstm_350m")
    fused = model.fuse_gate_stacks(installed)
    mp = fused["pairs"]["mlstm"]
    assert isinstance(mp["w_ug"], AimcLinearState)
    assert isinstance(mp["w_qkv"], AimcLinearState)
    assert "w_up" not in mp and "w_q" not in mp
    # gates stack INSIDE the layer dim so lax.scan slices to [G, ...]
    n_pairs = cfg.n_pairs
    assert mp["w_qkv"].stack_shape[:2] == (n_pairs, 3)
    assert fused["pairs"]["slstm"]["w_ff_gu"].stack_shape[:2] == (n_pairs, 2)


def test_xlstm_fused_forward_bit_equal():
    model, cfg, installed, exe = _programmed("xlstm_350m")
    toks = (jnp.arange(2 * 16).reshape(2, 16) * 5 + 2) % cfg.vocab
    h_ref, _ = model.forward(installed, toks, cfg, exe, return_hidden=True)
    fused = model.fuse_gate_stacks(installed)
    h_fused, _ = model.forward(fused, toks, cfg, exe, return_hidden=True)
    np.testing.assert_array_equal(np.asarray(h_fused), np.asarray(h_ref))


def test_xlstm_fused_decode_bit_equal():
    model, cfg, installed, exe = _programmed("xlstm_350m")
    fused = model.fuse_gate_stacks(installed)
    toks = jnp.array([[3], [5]])
    cache_a = model.init_cache(cfg, 2)
    cache_b = model.init_cache(cfg, 2)
    la, cache_a = model.decode_step(installed, cache_a, toks, cfg, exe)
    lb, cache_b = model.decode_step(fused, cache_b, toks, cfg, exe)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    la2, _ = model.decode_step(installed, cache_a, toks + 1, cfg, exe)
    lb2, _ = model.decode_step(fused, cache_b, toks + 1, cfg, exe)
    np.testing.assert_array_equal(np.asarray(la2), np.asarray(lb2))


def _mha_arch():
    """An MHA member of the zoo (QKV widths equal -> stackable)."""
    for arch_id in ("granite_8b", "llama32_1b", "qwen15_110b", "glm4_9b"):
        try:
            spec = get_arch(arch_id)
        except KeyError:
            continue
        cfg = spec.smoke_cfg
        if cfg.n_heads == cfg.n_kv_heads:
            return arch_id
    return None


def test_transformer_fuse_gate_stacks():
    arch_id = _mha_arch()
    model, cfg, installed, exe = _programmed(arch_id or "granite_8b")
    fused = model.fuse_gate_stacks(installed)
    blocks = fused["blocks"]
    if arch_id is None:
        # GQA-only zoo: QKV can't stack; the FFN pair still can (dense archs)
        assert "wqkv" not in blocks
    toks = (jnp.arange(2 * 8).reshape(2, 8) * 7 + 3) % cfg.vocab
    h_ref, _ = model.forward(installed, toks, cfg, exe, return_hidden=True)
    h_fused, _ = model.forward(fused, toks, cfg, exe, return_hidden=True)
    np.testing.assert_array_equal(np.asarray(h_fused), np.asarray(h_ref))


def test_transformer_ffn_pair_fuses_for_dense_arch():
    model, cfg, installed, exe = _programmed("granite_8b")
    fused = model.fuse_gate_stacks(installed)
    blocks = fused["blocks"]
    assert isinstance(blocks["w_gu"], AimcLinearState)
    assert "w_gate" not in blocks and "w_up" not in blocks


def test_fuse_is_noop_on_digital_params():
    """Unprogrammed (raw float) trees pass through unchanged."""
    spec = get_arch("xlstm_350m")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    fused = model.fuse_gate_stacks(params)
    assert "w_q" in fused["pairs"]["mlstm"]
    assert "w_qkv" not in fused["pairs"]["mlstm"]
