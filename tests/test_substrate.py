"""Substrate-layer tests: data pipeline, optimizers, checkpointing,
fault tolerance, schedules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.data.pipeline import DataConfig, DataIterator, host_batch
from repro.optim import make_optimizer
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           elastic_mesh_shapes,
                                           resilient_step)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

CFG = DataConfig(vocab=1000, seq_len=64, global_batch=8)


def test_data_deterministic():
    b1 = host_batch(CFG, step=3, shard=0, n_shards=2)
    b2 = host_batch(CFG, step=3, shard=0, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_step_and_shard_vary():
    b0 = host_batch(CFG, 0, 0, 2)
    b1 = host_batch(CFG, 1, 0, 2)
    b0s1 = host_batch(CFG, 0, 1, 2)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    assert not np.array_equal(b0["tokens"], b0s1["tokens"])


def test_data_shapes_and_labels():
    b = host_batch(CFG, 0, 0, 2)
    assert b["tokens"].shape == (4, 64)      # global 8 / 2 shards
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert b["tokens"].max() < CFG.vocab


def test_iterator_restart_exact():
    it = DataIterator(CFG, n_shards=2, shard=1)
    batches = [next(it) for _ in range(3)]
    state = it.state()
    it2 = DataIterator(CFG, n_shards=2, shard=1)
    it2.restore(state)
    b3a = next(it)
    b3b = next(it2)
    np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
    assert state == {"step": 3}


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    init, update, _ = make_optimizer(name, lr=5e-2)
    w_true = jnp.asarray([1.0, -2.0, 3.0])
    # nonzero start: Adafactor's relative step size scales with RMS(param)
    params = {"w": jnp.ones((3,)), "m": 0.1 * jnp.ones((2, 3))}
    state = init(params)

    def loss(p):
        return jnp.sum((p["w"] - w_true) ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, metrics = update(g, state, params)
    assert float(loss(params)) < 0.2 * l0
    assert "grad_norm" in metrics


def test_adamw_moment_dtype():
    init, update, _ = make_optimizer("adamw", moment_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4))}
    state = init(params)
    assert state.mu["w"].dtype == jnp.bfloat16


def test_adafactor_factored_shapes():
    init, _, _ = make_optimizer("adafactor")
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    state = init(params)
    leaves = {"/".join(str(getattr(k, "key", k)) for k in p): v.shape
              for p, v in jax.tree_util.tree_flatten_with_path(state)[0]}
    # factored second moment: row + col vectors, not the full matrix
    assert any(v == (8,) for v in leaves.values())
    assert any(v == (16,) for v in leaves.values())


def test_warmup_cosine_shape():
    assert float(warmup_cosine(jnp.asarray(0))) == 0.0
    peak = float(warmup_cosine(jnp.asarray(200)))
    assert peak == pytest.approx(1.0, rel=1e-3)
    end = float(warmup_cosine(jnp.asarray(10000)))
    assert end == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 7, t, extra={"loss": 1.5})
    assert checkpoint.latest_step(str(tmp_path)) == 7
    restored, extra = checkpoint.restore(str(tmp_path), 7, t)
    assert extra == {"loss": 1.5}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomic_no_partial(tmp_path):
    """A stray .tmp dir (simulated crash) must not count as a checkpoint."""
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc_keeps_three(tmp_path):
    t = _tree()
    for s in range(5):
        checkpoint.save(str(tmp_path), s, t)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3
    assert checkpoint.latest_step(str(tmp_path)) == 4


def test_checkpoint_restore_latest_empty(tmp_path):
    step, tree, extra = checkpoint.restore_latest(str(tmp_path), _tree())
    assert step is None and tree is None


def test_checkpoint_shape_mismatch_raises(tmp_path):
    t = _tree()
    checkpoint.save(str(tmp_path), 1, t)
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": t["params"]["b"]},
           "step": t["step"]}
    with pytest.raises(ValueError, match="shape mismatch"):
        checkpoint.restore(str(tmp_path), 1, bad)


def test_checkpoint_save_async(tmp_path):
    t = _tree()
    th = checkpoint.save_async(str(tmp_path), 3, t)
    th.join(timeout=30)
    assert checkpoint.latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_resilient_step_retries():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return x + 1

    wrapped = resilient_step(flaky, max_retries=3)
    assert wrapped(1) == 2
    assert len(calls) == 3


def test_resilient_step_gives_up():
    def broken(x):
        raise RuntimeError("permanent")

    wrapped = resilient_step(broken, max_retries=1)
    with pytest.raises(RuntimeError):
        wrapped(0)


def test_straggler_monitor():
    flagged = []
    m = StragglerMonitor(threshold=2.0, warmup=2,
                         on_straggler=lambda s, dt, ew: flagged.append(s))
    for i in range(6):
        m.record(i, 1.0)
    assert m.record(6, 5.0) is True            # 5x the EWMA
    assert flagged == [6]
    ew_before = m.ewma
    m.record(7, 5.0)
    assert m.ewma == ew_before                 # stragglers don't poison EWMA


def test_heartbeat_roundtrip(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    hb.beat(42, loss=3.2)
    got = hb.read()
    assert got["step"] == 42 and got["loss"] == 3.2


def test_elastic_mesh_shapes():
    shapes = elastic_mesh_shapes(128, 16)
    assert (8, 16) in shapes and (128, 1) in shapes
    for d, m in shapes:
        assert d * m == 128
