"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture instantiates its REDUCED same-family config and
runs one forward pass + one training-style grad step on CPU, asserting
output shapes and absence of NaNs. Serving paths (prefill + decode vs
full forward) are cross-validated for every family that decodes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs
from repro.models.layers import Execution

EXE = Execution(compute_dtype="float32")
ARCHS = list_archs()


def _smoke_batch(spec, b=2, s=32):
    cfg = spec.smoke_cfg
    key = jax.random.PRNGKey(0)
    if spec.family == "audio":
        tgt = 16
        return {"frames": jax.random.normal(key, (b, s, cfg.d_model)),
                "tokens": jnp.ones((b, tgt), jnp.int32),
                "labels": jnp.ones((b, tgt), jnp.int32)}
    out = {"tokens": jnp.arange(b * s).reshape(b, s) % cfg.vocab,
           "labels": jnp.ones((b, s), jnp.int32)}
    if spec.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model))
    return out


def _forward(model, spec, params, batch, rng=None):
    cfg = spec.smoke_cfg
    if spec.family == "audio":
        return model.forward(params, batch, cfg, EXE, rng, return_hidden=True)
    if spec.family == "vlm":
        return model.forward(params, batch["tokens"], cfg, EXE, rng,
                             patch_embeds=batch["patch_embeds"],
                             return_hidden=True)
    return model.forward(params, batch["tokens"], cfg, EXE, rng,
                         return_hidden=True)


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_smoke(arch_id):
    spec = get_arch(arch_id)
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(spec)
    h, aux = _forward(model, spec, params, batch)
    assert h.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(h)))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_grad_smoke(arch_id):
    """One grad step: finite loss, finite nonzero grads, shapes preserved."""
    spec = get_arch(arch_id)
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(spec)

    def loss_fn(p):
        h, aux = _forward(model, spec, p, batch)
        unemb = model.unembed_matrix(p, cfg)
        logits = h.astype(jnp.float32) @ unemb.astype(jnp.float32)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return jnp.mean(lse - gold) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    for p, g in zip(jax.tree.leaves(params), jax.tree.leaves(grads)):
        assert p.shape == g.shape


@pytest.mark.parametrize("arch_id", ARCHS)
def test_aimc_execution_mode(arch_id):
    """The paper's technique as an execution mode: AIMC forward stays close
    to the digital forward for every architecture family."""
    from repro.core.aimc import AimcConfig
    spec = get_arch(arch_id)
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(spec)
    h_dig, _ = _forward(model, spec, params, batch)
    exe_aimc = Execution(mode="aimc", compute_dtype="float32",
                         aimc=AimcConfig(tile_rows=128, impl="ref"))
    rng = jax.random.PRNGKey(1)
    if spec.family == "audio":
        h_ana, _ = model.forward(params, batch, cfg, exe_aimc, rng,
                                 return_hidden=True)
    elif spec.family == "vlm":
        h_ana, _ = model.forward(params, batch["tokens"], cfg, exe_aimc, rng,
                                 patch_embeds=batch["patch_embeds"],
                                 return_hidden=True)
    else:
        h_ana, _ = model.forward(params, batch["tokens"], cfg, exe_aimc, rng,
                                 return_hidden=True)
    assert bool(jnp.all(jnp.isfinite(h_ana)))
    cos = jnp.sum(h_dig * h_ana) / (jnp.linalg.norm(h_dig)
                                    * jnp.linalg.norm(h_ana) + 1e-9)
    assert float(cos) > 0.9, f"AIMC forward diverged: cos={float(cos):.3f}"


# ---------------------------------------------------------------------------
# decode-vs-forward consistency (KV cache / recurrent state correctness)
# ---------------------------------------------------------------------------

def _decode_match(spec, atol, s=12):
    import dataclasses as _dc
    model = spec.model_module()
    cfg = spec.smoke_cfg
    if getattr(cfg, "n_experts", 0):
        # exact fwd/decode agreement needs drop-free routing: the capacity
        # competition differs between a 1-token decode and a full sequence
        cfg = _dc.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = model.init(jax.random.PRNGKey(0), cfg)
    b = 2
    toks = (jnp.arange(b * s).reshape(b, s) * 7 + 1) % cfg.vocab

    logits_full, _ = model.forward(params, toks, cfg, EXE)

    if spec.module == "transformer":
        prefill_kwargs = {}
        if spec.family == "vlm":
            pe = jax.random.normal(jax.random.PRNGKey(1),
                                   (b, cfg.n_patches, cfg.d_model))
            logits_full, _ = model.forward(params, toks, cfg, EXE,
                                           patch_embeds=pe)
            prefill_kwargs["patch_embeds"] = pe
        _, cache = model.prefill(params, toks[:, :-1], cfg, EXE,
                                 max_seq=s, cache_dtype=jnp.float32,
                                 **prefill_kwargs)
        logits_step, _ = model.decode_step(params, cache, toks[:, -1:],
                                           cfg, EXE)
        got = logits_step[:, -1]
    else:  # recurrent: feed tokens one by one through decode_step
        cache = model.init_cache(cfg, b, s, jnp.float32)
        for t in range(s):
            logits_step, cache = model.decode_step(params, cache,
                                                   toks[:, t:t + 1], cfg, EXE)
        got = logits_step[:, -1]

    want = logits_full[:, -1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)


@pytest.mark.parametrize("arch_id", ["granite_8b", "olmoe_1b_7b",
                                     "internvl2_1b"])
def test_transformer_decode_matches_forward(arch_id):
    _decode_match(get_arch(arch_id), atol=2e-3)


def test_xlstm_decode_matches_forward():
    """Chunkwise-parallel mLSTM == stepwise recurrence (algebraic identity)."""
    _decode_match(get_arch("xlstm_350m"), atol=5e-3, s=16)


def test_rglru_decode_matches_forward():
    _decode_match(get_arch("recurrentgemma_9b"), atol=5e-3)


def test_encdec_decode_matches_forward():
    spec = get_arch("seamless_m4t_large_v2")
    model = spec.model_module()
    cfg = spec.smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    b, src, tgt = 2, 16, 6
    frames = jax.random.normal(jax.random.PRNGKey(1), (b, src, cfg.d_model))
    toks = (jnp.arange(b * tgt).reshape(b, tgt) * 5 + 1) % cfg.vocab
    batch = {"frames": frames, "tokens": toks, "labels": toks}
    logits_full, _ = model.forward(params, batch, cfg, EXE)
    _, cache = model.prefill(params, frames, toks[:, :-1], cfg, EXE,
                             max_seq=tgt, cache_dtype=jnp.float32)
    logits_step, _ = model.decode_step(params, cache, toks[:, -1:], cfg, EXE)
    np.testing.assert_allclose(np.asarray(logits_step[:, -1]),
                               np.asarray(logits_full[:, -1]), atol=2e-3)


def test_shape_cells_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    from repro.configs import all_cells
    cells = all_cells()
    # 10 archs x 4 shapes - 8 long_500k skips (only rglru + xlstm run it)
    assert len(cells) == 32
    longs = [a for (a, s) in cells if s == "long_500k"]
    assert sorted(longs) == ["recurrentgemma_9b", "xlstm_350m"]
