"""Property tests for the cost-model placer (hypothesis).

Randomized layer sets drive the packing/feasibility/rotation machinery
the deterministic suite (test_placement.py) pins on the smoke model:

  * `pack_contexts` is deterministic and prefix-monotone (no tile
    conservation law exists — see the NOTE below);
  * `_feasible_prefix_len` is monotone in the budget, its prefix always
    packs within the budget, and one more layer never does;
  * `_build_rotation` never emits a state over budget, partitions the
    candidate set exactly (hot / rotating groups / permanently digital —
    nothing silently dropped), and classifies as permanently digital
    exactly the layers that cannot fit even alone;
  * `plan_placement` on synthetic parameter trees honors the cap, is
    monotone non-worsening in budget, and never loses to all-digital.

Deterministic API units live in test_placement.py; this module needs the
optional hypothesis dep (importorskip per repo convention, mirroring
test_isa_props.py)."""

import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dep
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.aimc import AimcConfig
from repro.core.placement import (LayerCost, _build_rotation,
                                  _feasible_prefix_len, _packmax,
                                  plan_placement)
from repro.core.program import MappingPlan
from repro.core.tile import pack_contexts

CFG = AimcConfig(impl="ref", tile_rows=64, tile_cols=64)


def _tiles(k, n, inst):
    """Standalone packed tile count (the shelf packer, not a ceil formula —
    the packer shares tiles across column spans)."""
    return sum(pack_contexts([("x", k, n, inst)], 1,
                             CFG.tile_rows, CFG.tile_cols))


# strategy: a list of layers as (k, n, instances); names are positional
layers_st = st.lists(st.tuples(st.integers(1, 300), st.integers(1, 300),
                               st.integers(1, 3)),
                     min_size=1, max_size=8)


def _costs(layers, savings_sign=None):
    """Synthesize a LayerCost tuple; savings_sign[i] > 0 makes layer i a
    candidate (t_digital > t_analog), else it prefers digital."""
    out = []
    for i, (k, n, inst) in enumerate(layers):
        pos = True if savings_sign is None else savings_sign[i]
        t_a = 1e-6 * (i + 1)
        t_d = t_a * (2.0 if pos else 0.5)
        out.append(LayerCost(path=f"l{i}", k=k, n=n, instances=inst,
                             fold_index=i, t_digital=t_d, t_analog=t_a,
                             tiles_alone=_tiles(k, n, inst)))
    return tuple(out)


# ---------------------------------------------------------------------------
# pack_contexts: determinism + prefix monotonicity
# ---------------------------------------------------------------------------
# NOTE deliberately absent: tile "conservation" laws. The shelf packer can
# both SHARE one tile across matrices (joint < standalone sum) and
# FRAGMENT shelves first-fit (joint > standalone sum), so neither
# inequality holds in general. The binding contract — pack_contexts
# reproduces the real ProgramBuilder bit-for-bit — is pinned against a
# real program in test_placement.py.

@settings(max_examples=100, deadline=None)
@given(layers_st, st.integers(1, 4))
def test_pack_contexts_deterministic_and_prefix_monotone(layers,
                                                         n_contexts):
    items = [(f"l{i}", k, n, inst)
             for i, (k, n, inst) in enumerate(layers)]
    per = pack_contexts(items, n_contexts, CFG.tile_rows, CFG.tile_cols)
    assert len(per) == n_contexts
    assert all(c >= 0 for c in per) and max(per) >= 1
    # deterministic: same items -> same packing
    assert per == pack_contexts(items, n_contexts, CFG.tile_rows,
                                CFG.tile_cols)
    # the simulation is sequential (later items cannot change earlier
    # placements): packing any prefix never exceeds the full run
    for i in range(len(items)):
        pre = pack_contexts(items[:i + 1], n_contexts, CFG.tile_rows,
                            CFG.tile_cols)
        assert all(a <= b for a, b in zip(pre, per))


# ---------------------------------------------------------------------------
# feasibility frontier
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(layers_st, st.integers(1, 30))
def test_feasible_prefix_is_tight_and_monotone(layers, budget):
    costs = _costs(layers)
    order = sorted(costs, key=lambda c: (-c.density, c.path))
    m = _feasible_prefix_len(costs, order, budget, 1, CFG)
    chosen = {c.path for c in order[:m]}
    assert _packmax(costs, chosen, 1, CFG) <= budget
    if m < len(order):
        # the frontier is tight: the running max over the NEXT prefix
        # (what the placer actually guards) busts the budget
        grown = max(_packmax(costs, {c.path for c in order[:i + 1]}, 1, CFG)
                    for i in range(m + 1))
        assert grown > budget
    # more budget never shrinks the feasible prefix
    m2 = _feasible_prefix_len(costs, order, budget + 1, 1, CFG)
    assert m2 >= m


# ---------------------------------------------------------------------------
# rotation construction: capped states, exact partition
# ---------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(layers_st, st.integers(1, 12))
def test_rotation_states_capped_and_partition_exact(layers, budget):
    costs = _costs(layers)
    candidates = sorted(costs, key=lambda c: (-c.density, c.path))
    m_res = _feasible_prefix_len(costs, candidates, budget, 1, CFG)
    rot = _build_rotation(costs, candidates, m_res, budget, 1, CFG,
                          swap_every=1)
    # every rotation state fits the cap
    for state in rot.states():
        assert _packmax(costs, set(state), 1, CFG) <= budget
    # hot + groups + permanent-digital is an exact partition of candidates
    rotated = [p for g in rot.groups for p in g]
    everything = list(rot.hot) + rotated + list(rot.digital)
    assert sorted(everything) == sorted(c.path for c in candidates)
    # permanently digital iff the layer cannot fit even alone
    for c in candidates:
        alone = _packmax(costs, {c.path}, 1, CFG) <= budget
        assert (c.path in rot.digital) == (not alone)
    # groups are nonempty and swap cadence survives
    assert all(g for g in rot.groups)
    assert rot.swap_every == 1


# ---------------------------------------------------------------------------
# end-to-end placer law on synthetic parameter trees
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(8, 200), st.integers(8, 200)),
                min_size=1, max_size=4),
       st.integers(1, 6))
def test_plan_placement_cap_monotone_dominates(shapes, budget):
    params = {"blocks": {f"w_l{i}": jnp.ones((k, n), jnp.float32)
                         for i, (k, n) in enumerate(shapes)}}
    res = plan_placement(params, MappingPlan(), CFG,
                         tiles_per_context=budget, n_contexts=1)
    resident = [c.item for c in res.costs if c.path in set(res.analog)]
    per = pack_contexts(resident, 1, CFG.tile_rows, CFG.tile_cols)
    assert max(per, default=0) <= budget
    res2 = plan_placement(params, MappingPlan(), CFG,
                          tiles_per_context=budget + 1, n_contexts=1)
    assert res2.predicted_s <= res.predicted_s + 1e-15
    assert res.predicted_s <= res.predicted_digital_s + 1e-15
    if res.overflow:
        assert res.rotation is not None
        for state in res.rotation.states():
            sn = set(state)
            items = [c.item for c in res.costs if c.path in sn]
            assert max(pack_contexts(items, 1, CFG.tile_rows,
                                     CFG.tile_cols)) <= budget
