"""Property tests for the crossbar tile allocator (AIMClib mapMatrix)."""

import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.tile import TileAllocator, plan_linear, split_matrix


def _no_overlap(tm):
    """No two placements on the same tile may overlap."""
    by_tile = {}
    for p in tm.placements:
        by_tile.setdefault(p.tile_id, []).append(p)
    for ps in by_tile.values():
        for i, a in enumerate(ps):
            for b in ps[i + 1:]:
                sep = (a.row_off + a.rows <= b.row_off or
                       b.row_off + b.rows <= a.row_off or
                       a.col_off + a.cols <= b.col_off or
                       b.col_off + b.cols <= a.col_off)
                if not sep:
                    return False
    return True


dims = st.integers(min_value=1, max_value=3000)


@given(dims, dims, st.integers(min_value=64, max_value=1024),
       st.integers(min_value=64, max_value=1024))
@settings(max_examples=60, deadline=None)
def test_single_matrix_placement(rows, cols, tr, tc):
    tm = plan_linear("w", rows, cols, tr, tc)
    # every element of the matrix is covered exactly once
    covered = sum(p.rows * p.cols for p in tm.placements)
    assert covered == rows * cols
    # placements stay within the tile
    for p in tm.placements:
        assert 0 <= p.row_off and p.row_off + p.rows <= tr
        assert 0 <= p.col_off and p.col_off + p.cols <= tc
    assert _no_overlap(tm)
    assert 0.0 < tm.utilization <= 1.0
    assert tm.devices_used() == 2 * rows * cols   # PCM pair per weight


@given(st.lists(st.tuples(dims, dims), min_size=1, max_size=6),
       st.integers(min_value=128, max_value=1024))
@settings(max_examples=40, deadline=None)
def test_many_matrices_pack(matrices, tile):
    alloc = TileAllocator(tile, tile)
    for i, (r, c) in enumerate(matrices):
        alloc.map_matrix(f"m{i}", r, c)
    tm = alloc.finalize()
    assert _no_overlap(tm)
    covered = sum(p.rows * p.cols for p in tm.placements)
    assert covered == sum(r * c for r, c in matrices)
    # lower bound on tile count: total area / tile area
    import math
    assert tm.n_tiles >= math.ceil(covered / (tile * tile))


def test_split_matrix_tiles_exact():
    blocks = list(split_matrix(1000, 700, 512, 512))
    assert sum(r * c for (_, _, r, c) in blocks) == 1000 * 700
    assert len(blocks) == 2 * 2


def test_lstm_gates_side_by_side():
    """The paper's §VIII-D trick: 4 gates of a 306x256 cell share one tile."""
    alloc = TileAllocator(612, 1074)
    alloc.map_side_by_side([f"g{i}" for i in range(4)], 306, 256)
    tm = alloc.finalize()
    assert tm.n_tiles == 1
    assert _no_overlap(tm)


def test_allocator_rejects_bad_dims():
    with pytest.raises(ValueError):
        TileAllocator(0, 128)
