"""The AIMC tile model: programming, inference, noise, and the STE.

Covers the paper's execution semantics (§III-B/C, §IV-B) as a JAX module:
quantized crossbar MVM fidelity, PCM non-ideality determinism, drift
compensation, and the straight-through estimator used for noise-aware
training.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aimc import (AimcConfig, aimc_apply, aimc_linear_ste,
                             program_linear)
from repro.core.noise import DISABLED, NoiseModel, programming_noise, read_noise


def test_program_shapes_and_padding():
    cfg = AimcConfig(tile_rows=256)
    w = jnp.ones((300, 130)) * 0.01
    st = program_linear(w, cfg)
    assert st.w_q.shape == (2, 256, 256)      # K padded to 2 blocks, N to 128x
    assert st.k == 300 and st.n == 130
    # padded regions are exactly zero codes
    assert int(jnp.abs(st.w_q[1, 44:, :]).max()) == 0
    assert int(jnp.abs(st.w_q[:, :, 130:]).max()) == 0


def test_apply_matches_fp32_within_quant_error():
    cfg = AimcConfig(tile_rows=512)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, (512, 256)) * 0.05
    x = jax.random.normal(k2, (32, 512))
    st = program_linear(w, cfg)
    y = aimc_apply(st, x, cfg)
    y_fp = x @ w
    rel = float(jnp.linalg.norm(y - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.05, f"8-bit crossbar should be ~4% of fp32, got {rel}"


def test_apply_leading_dims():
    cfg = AimcConfig(tile_rows=256)
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 0.1
    st = program_linear(w, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 5, 128))
    y = aimc_apply(st, x, cfg)
    assert y.shape == (2, 3, 5, 64)
    y_flat = aimc_apply(st, x.reshape(-1, 128), cfg)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 64),
                               np.asarray(y_flat), atol=1e-6)


def test_programming_noise_deterministic_and_scaled():
    nm = NoiseModel()
    key = jax.random.PRNGKey(3)
    codes = jnp.linspace(-127, 127, 1000).reshape(10, 100)
    n1 = programming_noise(key, codes, nm)
    n2 = programming_noise(key, codes, nm)
    np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
    # level dependence: large |w| noisier than small |w|
    lo = jnp.std(n1[4:6])    # codes near 0
    hi = jnp.std(jnp.concatenate([n1[:1], n1[-1:]]))
    assert float(hi) > float(lo)


def test_noise_disabled_is_exact():
    cfg_clean = AimcConfig(tile_rows=256, noise=DISABLED)
    cfg_noisy = AimcConfig(tile_rows=256, noise=NoiseModel())
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    key = jax.random.PRNGKey(2)
    st_clean = program_linear(w, cfg_clean, key)
    st_noisy = program_linear(w, cfg_noisy, key)
    assert not np.array_equal(np.asarray(st_clean.w_q),
                              np.asarray(st_noisy.w_q))
    y1 = aimc_apply(st_clean, x, cfg_clean)
    y2 = aimc_apply(st_clean, x, cfg_clean)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_drift_compensation():
    drift = NoiseModel(sigma_prog_min=0.0, sigma_prog_max=0.0, sigma_read=0.0,
                       drift_t_ratio=1e4, drift_compensate=True)
    uncomp = NoiseModel(sigma_prog_min=0.0, sigma_prog_max=0.0, sigma_read=0.0,
                        drift_t_ratio=1e4, drift_compensate=False)
    assert drift.drift_gain() < 1.0
    assert drift.drift_gain() * drift.compensation_gain() == pytest.approx(1.0)
    w = jnp.eye(64) * 0.1
    x = jnp.ones((2, 64))
    cfg_c = AimcConfig(tile_rows=64, noise=drift)
    cfg_u = AimcConfig(tile_rows=64, noise=uncomp)
    y_c = aimc_apply(program_linear(w, cfg_c, jax.random.PRNGKey(0)), x, cfg_c)
    y_u = aimc_apply(program_linear(w, cfg_u, jax.random.PRNGKey(0)), x, cfg_u)
    # uncompensated drift shrinks outputs by (t/t0)^-nu
    ratio = float(jnp.mean(y_u / jnp.maximum(y_c, 1e-9)))
    assert ratio == pytest.approx(drift.drift_gain(), rel=0.05)


def test_read_noise_scales_with_rows():
    nm = NoiseModel()
    k = jax.random.PRNGKey(0)
    n_small = read_noise(k, (1, 64, 64), 64, nm)
    n_large = read_noise(k, (1, 64, 64), 1024, nm)
    assert float(jnp.std(n_large)) > float(jnp.std(n_small)) * 2


def test_ste_gradients_are_dense():
    """Backward of the AIMC linear == backward of x @ W (straight-through)."""
    cfg = AimcConfig(tile_rows=256, noise=NoiseModel())
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (256, 64)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))

    def loss_aimc(x_, w_):
        return jnp.sum(aimc_linear_ste(x_, w_, key, cfg) ** 2) * 0 + \
            jnp.sum(aimc_linear_ste(x_, w_, key, cfg))

    gx, gw = jax.grad(loss_aimc, argnums=(0, 1))(x, w)
    # STE: d/dx sum(xW) = sum over out of W; d/dW = broadcast sum of x
    np.testing.assert_allclose(np.asarray(gx),
                               np.asarray(jnp.sum(w, 1)[None].repeat(8, 0)),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw),
                               np.asarray(jnp.sum(x, 0)[:, None].repeat(64, 1)),
                               rtol=1e-4, atol=1e-6)


def test_ste_trains_through_noise():
    """A tiny regression task must reach low loss with the noisy AIMC fwd."""
    cfg = AimcConfig(tile_rows=64, noise=NoiseModel(sigma_read=0.002))
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (16, 4)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    y = x @ w_true
    w = jnp.zeros((16, 4))
    lr = 0.05
    for i in range(200):
        k = jax.random.fold_in(key, i)

        def loss(wv):
            pred = aimc_linear_ste(x, wv, k, cfg)
            return jnp.mean((pred - y) ** 2)

        w = w - lr * jax.grad(loss)(w)
    final = float(jnp.mean((aimc_linear_ste(x, w, key, cfg) - y) ** 2))
    base = float(jnp.mean(y ** 2))
    assert final < 0.05 * base, f"noise-aware training failed: {final}/{base}"
