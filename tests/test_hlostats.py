"""The while-aware HLO analyzer (launch/hlostats.py) vs XLA's cost_analysis.

The roofline table depends on this module being right: XLA counts scan
bodies once; hlostats must (a) agree with XLA on scan-free programs and
(b) multiply while bodies by their trip counts.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.launch.hlostats import analyze_hlo, parse_module, type_bytes


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scanfree_matches_xla():
    def f(x, w1, w2):
        return jnp.tanh(jnp.maximum(x @ w1, 0) @ w2)

    comp = _compile(f,
                    jax.ShapeDtypeStruct((128, 512), jnp.float32),
                    jax.ShapeDtypeStruct((512, 1024), jnp.float32),
                    jax.ShapeDtypeStruct((1024, 256), jnp.float32))
    xla = cost_analysis(comp)
    mine = analyze_hlo(comp.as_text())
    assert mine["flops"] == pytest.approx(xla["flops"], rel=0.02)
    assert mine["bytes"] == pytest.approx(xla["bytes accessed"], rel=0.10)


def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    comp = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                    jax.ShapeDtypeStruct((256, 256), jnp.float32))
    mine = analyze_hlo(comp.as_text())
    expected = 10 * 2 * 256 ** 3
    assert mine["flops"] == pytest.approx(expected, rel=0.01)
    # XLA undercounts by the trip count — that's the bug we work around
    assert cost_analysis(comp)["flops"] < expected / 5


def test_nested_scans_multiply_through():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    comp = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                    jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mine = analyze_hlo(comp.as_text())
    assert mine["flops"] == pytest.approx(12 * 2 * 128 ** 3, rel=0.01)


def test_tuple_types_with_index_comments_parse():
    """Regression: /*index=N*/ comments inside tuple types broke the
    instruction regex and silently dropped every while edge."""
    line = ("  %while.437 = (s32[], f32[16,1,1024]{2,1,0}, "
            "/*index=5*/s32[4]{0}) while(%tuple.497), condition=%c, "
            "body=%b, backend_config={\"known_trip_count\":{\"n\":\"4\"}}")
    comps, _ = parse_module("ENTRY %main (p: s32[]) -> s32[] {\n"
                            + line + "\n}\n")
    instrs = comps["main"]
    assert len(instrs) == 1 and instrs[0].opcode == "while"


def test_type_bytes():
    assert type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert type_bytes("(s32[], bf16[8,8]{1,0})") == 4 + 128
    assert type_bytes("pred[]") == 1


def test_collectives_counted_with_wire_factors():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under forced host device count)")


def test_dot_general_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    comp = _compile(f, jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                    jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    mine = analyze_hlo(comp.as_text())
    assert mine["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.05)
